"""Actor-critic PPO (the paper's §2.1 PPO formulation, with GAE).

GRPO is the paper's default (critic-free); this module provides the PPO
alternative: a value head on the trunk features, GAE token advantages from
the terminal verifiable reward, and a clipped value loss — selectable via
``TrainerConfig.adv_estimator = "gae"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algos import LossConfig, gae, rl_loss
from repro.models.api import ModelAPI
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
from repro.train.trainer import _unembed_matrix, chunked_token_logprobs


def init_value_head(key, d_model: int):
    return {
        "w": (jax.random.normal(key, (d_model, 1)) * (d_model ** -0.5)
              ).astype(jnp.float32),
        "b": jnp.zeros((1,), jnp.float32),
    }


def value_apply(vh, features):
    """features: (B, S, D) -> values (B, S) fp32."""
    return (features.astype(jnp.float32) @ vh["w"] + vh["b"])[..., 0]


def make_critic_train_step(api: ModelAPI, loss_cfg: LossConfig,
                           opt_cfg: OptConfig, *, gamma: float = 1.0,
                           lam: float = 1.0, vf_coef: float = 0.5,
                           remat: bool = False, moe_mode: str = "ep"):
    """PPO train step with a learned critic.

    State: {"params", "value", "opt", "vopt"}.  The batch carries `rewards`
    (B,) terminal rewards instead of precomputed `advantages`; GAE runs
    inside the step (token reward = terminal reward at the last response
    token).
    """
    cfg = api.cfg

    def train_step(state, batch):
        mask = batch["mask"]
        b = mask.shape[0]
        # terminal token reward: the last response position of each row
        last = jnp.maximum(
            (mask * jnp.arange(mask.shape[1])[None, :]).max(axis=1), 0)
        token_rewards = jnp.zeros_like(mask).at[
            jnp.arange(b), last.astype(jnp.int32)].set(batch["rewards"])

        def loss_fn(params, vh):
            features, aux = api.apply(params, batch, remat=remat,
                                      moe_mode=moe_mode, return_features=True)
            if cfg.family == "vlm":
                features = features[:, cfg.num_image_tokens:]
            head = _unembed_matrix(api, params)
            logprobs = chunked_token_logprobs(features, head, batch["tokens"])
            values = value_apply(vh, features) * mask

            advantages, returns = gae(token_rewards,
                                      jax.lax.stop_gradient(values), mask,
                                      gamma=gamma, lam=lam)
            adv_batch = dict(batch)
            mean = (advantages * mask).sum() / jnp.maximum(mask.sum(), 1.0)
            var = (jnp.square(advantages - mean) * mask).sum() / \
                jnp.maximum(mask.sum(), 1.0)
            adv_batch["advantages"] = (advantages - mean) * \
                jax.lax.rsqrt(var + 1e-8) * mask

            pg_loss, metrics = rl_loss(logprobs, adv_batch, loss_cfg, aux)
            v_loss = (jnp.square(values - returns) * mask).sum() / \
                jnp.maximum(mask.sum(), 1.0)
            metrics["value_loss"] = v_loss
            metrics["explained_value"] = values.sum() / jnp.maximum(mask.sum(), 1.0)
            return pg_loss + vf_coef * v_loss, metrics

        (loss, metrics), (g_p, g_v) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(state["params"], state["value"])
        dtypes = jax.tree_util.tree_map(lambda p: p.dtype, state["params"])
        params, opt, m1 = adamw_update(g_p, state["opt"], opt_cfg, dtypes)
        vdtypes = jax.tree_util.tree_map(lambda p: p.dtype, state["value"])
        value, vopt, _ = adamw_update(g_v, state["vopt"], opt_cfg, vdtypes)
        metrics = dict(metrics, **m1, loss=loss)
        return {"params": params, "value": value, "opt": opt, "vopt": vopt}, metrics

    return train_step


def make_critic_train_state(api: ModelAPI, key):
    k1, k2 = jax.random.split(key)
    params = api.init(k1)
    vh = init_value_head(k2, api.cfg.d_model)
    return {"params": params, "value": vh,
            "opt": init_opt_state(params), "vopt": init_opt_state(vh)}
