from repro.train.optimizer import OptConfig, adamw_update, init_opt_state  # noqa: F401
from repro.train.trainer import (  # noqa: F401
    HostTrainer, TrainerConfig, make_logprob_fn, make_train_state, make_train_step)
