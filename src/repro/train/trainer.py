"""Policy-gradient trainer.

``make_train_step`` builds the pure, pjit-able step used by both the real
trainer and the multi-pod dry-run.  ``HostTrainer`` is the host-side wrapper
the AsyncController drives: it pads Sample batches, computes GRPO advantages
and proximal/reference logprobs, runs (optionally minibatched) train steps,
and serves fresh weights to the LLMProxy on weight sync.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.algos import LossConfig, group_normalized_advantage, rl_loss, token_logprobs
from repro.core.types import Sample
from repro.models.api import ModelAPI
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def make_train_state(api: ModelAPI, key) -> Dict[str, Any]:
    params = api.init(key)
    return {"params": params, "opt": init_opt_state(params)}


_CE_CHUNK = 512


def _unembed_matrix(api: ModelAPI, params):
    if api.cfg.family == "audio":
        return params["lm_head"]
    from repro.models.transformer import unembedding_matrix
    return unembedding_matrix(params, api.cfg)


def chunked_token_logprobs(features, head, tokens, *, chunk: int = _CE_CHUNK):
    """Fused unembed + gather over sequence chunks (§Perf iter 3).

    Never materializes (B, S, V) logits: each chunk's (B, C, V) logits are
    consumed into (B, C) logprobs and rematerialized in the backward pass.
    features: (B, S, D) final-norm hidden states; returns (B, S) logprobs
    aligned with `tokens` (position 0 zero — never a response token).
    """
    b, s, d = features.shape
    x, tg = features[:, :-1], tokens[:, 1:]
    sc = s - 1
    nc = -(-sc // chunk)
    pad = nc * chunk - sc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        tg = jnp.pad(tg, ((0, 0), (0, pad)))
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = tg.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(args):
        xi, ti = args
        logits = (xi @ head).astype(jnp.float32)
        return token_logprobs(logits, ti)

    lp = jax.lax.map(jax.checkpoint(body, prevent_cse=False), (xc, tc))
    lp = lp.transpose(1, 0, 2).reshape(b, nc * chunk)[:, :sc]
    return jnp.pad(lp, ((0, 0), (1, 0)))


def _policy_logprobs(api: ModelAPI, params, batch, *, remat, moe_mode):
    """logprobs (B, S) aligned with batch['tokens'] (position t = logprob of
    token t given <t); position 0 is zero (never a response token)."""
    cfg = api.cfg
    features, aux = api.apply(params, batch, remat=remat, moe_mode=moe_mode,
                              return_features=True)
    if cfg.family == "vlm":
        features = features[:, cfg.num_image_tokens:]
    head = _unembed_matrix(api, params)
    return chunked_token_logprobs(features, head, batch["tokens"]), aux


def make_train_step(api: ModelAPI, loss_cfg: LossConfig, opt_cfg: OptConfig,
                    *, remat: bool = True, moe_mode: str = "ep",
                    microbatches: int = 1):
    """Build the pjit-able train step.

    ``microbatches > 1`` runs gradient accumulation inside the step (scan
    over batch slices, fp32 grad accumulator): same numerics for the mean
    loss, 1/m the activation working set — how the MoE configs fit per-chip
    HBM at global batch 256 (§Perf iter 7b).
    """
    def loss_and_grad(params, batch):
        def loss_fn(p):
            logprobs, aux = _policy_logprobs(api, p, batch,
                                             remat=remat, moe_mode=moe_mode)
            return rl_loss(logprobs, batch, loss_cfg, aux)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state, batch):
        if microbatches > 1:
            m = microbatches

            def split(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def body(acc, mb):
                (loss, metrics), g = loss_and_grad(state["params"], mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(jnp.float32) / m, acc, g)
                return acc, (loss, metrics)

            grads, (losses, metricses) = jax.lax.scan(body, zeros, mbs)
            loss = losses.mean()
            metrics = jax.tree_util.tree_map(jnp.mean, metricses)
        else:
            (loss, metrics), grads = loss_and_grad(state["params"], batch)

        dtypes = jax.tree_util.tree_map(lambda p: p.dtype, state["params"])
        params, opt, opt_metrics = adamw_update(grads, state["opt"], opt_cfg, dtypes)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_logprob_fn(api: ModelAPI, *, moe_mode: str = "ep"):
    def logprob_fn(params, batch):
        lp, _ = _policy_logprobs(api, params, batch, remat=False, moe_mode=moe_mode)
        return lp

    return logprob_fn


# ---------------------------------------------------------------------------
# host-side wrapper: Samples -> padded arrays -> jitted steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    max_seq_len: int = 64
    group_size: int = 8
    minibatches: int = 1           # gradient_accumulation-style splits
    ppo_epochs: int = 1            # sample reuse E
    adv_estimator: str = "grpo"    # grpo (critic-free, paper default) | gae


class HostTrainer:
    def __init__(self, api: ModelAPI, key, loss_cfg: LossConfig,
                 opt_cfg: OptConfig, tcfg: TrainerConfig, *,
                 ref_params=None):
        self.api = api
        self.cfg = api.cfg
        self.loss_cfg = loss_cfg
        self.tcfg = tcfg
        moe_mode = "dense" if self.cfg.is_moe else "ep"
        if tcfg.adv_estimator == "gae":
            from repro.train.critic import (make_critic_train_state,
                                            make_critic_train_step)
            self.state = make_critic_train_state(api, key)
            self._train_step = jax.jit(make_critic_train_step(
                api, loss_cfg, opt_cfg, moe_mode=moe_mode))
        else:
            self.state = make_train_state(api, key)
            self._train_step = jax.jit(make_train_step(
                api, loss_cfg, opt_cfg, remat=False, moe_mode=moe_mode))
        self.ref_params = ref_params  # frozen copy for KL (None = no KL)
        self._logprob_fn = jax.jit(make_logprob_fn(
            api, moe_mode="dense" if self.cfg.is_moe else "ep"))
        self.steps_done = 0
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------- batching
    def build_batch(self, samples: List[Sample]) -> Dict[str, np.ndarray]:
        s_len = self.tcfg.max_seq_len
        n = len(samples)
        tokens = np.zeros((n, s_len), np.int32)
        mask = np.zeros((n, s_len), np.float32)
        old_lp = np.zeros((n, s_len), np.float32)
        for i, s in enumerate(samples):
            p = np.asarray(s.prompt_tokens, np.int32).ravel()
            r = np.asarray(s.response_tokens, np.int32).ravel()
            lp = np.asarray(s.logprobs, np.float32).ravel()
            p = p[-s_len:]
            r = r[: s_len - len(p)]
            lp = lp[: len(r)]
            tokens[i, : len(p)] = p
            tokens[i, len(p): len(p) + len(r)] = r
            mask[i, len(p): len(p) + len(r)] = 1.0
            old_lp[i, len(p): len(p) + len(r)] = lp

        rewards = np.asarray([s.reward or 0.0 for s in samples], np.float32)
        # GRPO: group-normalize within same-prompt groups; fall back to batch
        # norm when groups are ragged (agentic trajectories).
        gids = [s.group_id for s in samples]
        if n % self.tcfg.group_size == 0 and len(set(gids)) == n // self.tcfg.group_size:
            order = np.argsort(gids, kind="stable")
            inv = np.argsort(order)
            adv_sorted = group_normalized_advantage(
                jnp.asarray(rewards[order]), self.tcfg.group_size)
            seq_adv = np.asarray(adv_sorted)[inv]
        else:
            seq_adv = (rewards - rewards.mean()) / (rewards.std() + 1e-6)
        adv = seq_adv[:, None] * mask

        batch = {
            "tokens": tokens, "mask": mask, "advantages": adv.astype(np.float32),
            "rewards": rewards,
            "old_logprobs": old_lp,
            "prox_logprobs": old_lp.copy(),
            "ref_logprobs": np.zeros_like(old_lp),
            "is_positive": (rewards > 0).astype(np.float32),
        }
        if self.cfg.family == "vlm":
            batch["patches"] = np.zeros(
                (n, self.cfg.num_image_tokens, self.cfg.d_model), np.float32)
        if self.cfg.family == "audio":
            batch["frames"] = np.zeros(
                (n, self.cfg.encoder_frames, self.cfg.d_model), np.float32)
        return batch

    # --------------------------------------------------------------- train
    def train_on_samples(self, samples: List[Sample]) -> Dict[str, float]:
        batch_np = self.build_batch(samples)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

        # proximal logprobs: the policy at batch-fetch time (before updates)
        if self.loss_cfg.pg_variant == "decoupled_ppo" or self.tcfg.minibatches > 1:
            batch["prox_logprobs"] = self._logprob_fn(self.state["params"], batch)
        if self.loss_cfg.kl_beta and self.ref_params is not None:
            batch["ref_logprobs"] = self._logprob_fn(self.ref_params, batch)

        n = batch["tokens"].shape[0]
        mb = max(1, self.tcfg.minibatches)
        assert n % mb == 0, (n, mb)
        metrics: Dict[str, float] = {}
        for _ in range(self.tcfg.ppo_epochs):
            for j in range(mb):
                sl = slice(j * n // mb, (j + 1) * n // mb)
                mini = {k: v[sl] for k, v in batch.items()}
                self.state, m = self._train_step(self.state, mini)
                metrics = {k: float(v) for k, v in m.items()}
        self.steps_done += 1
        metrics["reward_mean"] = float(np.mean([s.reward or 0.0 for s in samples]))
        self.history.append(metrics)
        return metrics

    def get_weights(self):
        return self.state["params"]
