"""AdamW in pure JAX with fp32 master weights and global-norm clipping."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 1e-6   # paper appendix A.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0     # paper appendix A.1
    grad_clip: float = 1.0
    warmup_steps: int = 20        # paper appendix A.1


def init_opt_state(params) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree_util.tree_map(f32, params),
        "m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, opt_state, cfg: OptConfig, param_dtypes=None):
    """Returns (new_params_in_model_dtype, new_opt_state, metrics).

    param_dtypes: tree of jnp dtypes matching params (norm scales stay fp32,
    weights bf16). Defaults to bf16 everywhere if not given.
    """
    step = opt_state["step"] + 1
    lr = cfg.learning_rate * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    if param_dtypes is None:
        param_dtypes = jax.tree_util.tree_map(lambda _: jnp.bfloat16,
                                              opt_state["master"])

    # single fused per-leaf pass: chaining whole-tree tree_maps keeps ~6 fp32
    # param-sized trees live simultaneously (§Perf iter 7c — dozens of GiB at
    # 235B scale); per-leaf chains let XLA free each intermediate immediately.
    def upd_leaf(p_master, m_, v_, g, dt):
        gf = g.astype(jnp.float32) * scale
        m2 = b1 * m_ + (1 - b1) * gf
        v2 = b2 * v_ + (1 - b2) * jnp.square(gf)
        new_master = p_master - lr * ((m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
                                      + cfg.weight_decay * p_master)
        return {"master": new_master, "m": m2, "v": v2,
                "param": new_master.astype(dt)}

    fused = jax.tree_util.tree_map(
        upd_leaf, opt_state["master"], opt_state["m"], opt_state["v"], grads,
        param_dtypes, is_leaf=lambda x: isinstance(x, jnp.dtype) or hasattr(x, "shape"))

    def pick(key):
        return jax.tree_util.tree_map(lambda d: d[key], fused,
                                      is_leaf=lambda x: isinstance(x, dict)
                                      and "master" in x)

    new_state = {"step": step, "master": pick("master"),
                 "m": pick("m"), "v": pick("v")}
    return pick("param"), new_state, {"grad_norm": gnorm, "lr": lr}
