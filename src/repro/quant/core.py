"""Quantization primitives: symmetric per-channel INT8 + FP8-E4M3 pytrees.

The quantize-on-sync parameter path: the trainer ships fp32/bf16 weights at
every sync and the rollout engine calls ``quantize_params`` before storing
them — replicas *hold* int8/fp8 tensors on device (the memory/bandwidth
win), and the jitted engine step calls ``dequantize_params`` at trace time
so the dequant multiply fuses into the first matmul consumer (W8A16 style).

Scheme (the FlashRL / vLLM loading recipe):

* matmul weights (ndim >= 2) are quantized **per output channel** — the
  absmax over every non-last axis sets one scale per last-axis column, so
  a stacked block tree ``(L, d_in, d_out)`` gets per-layer, per-column
  scales ``(L, 1, d_out)``.
* embeddings / lm_head / norm gains stay full precision (standard practice:
  their error lands directly on the logits, and they are a small fraction
  of parameter bytes).
* fp8 uses the ml_dtypes ``float8_e4m3fn`` grid (max normal 448) when the
  running jax exposes it, else an exact jnp simulation of the same grid —
  either way results are bit-identical casts, safe on CPU.

A quantized leaf is a ``QuantLeaf`` NamedTuple (codes, scale, dtype token)
— a pytree node, so quantized trees flow through jit / donate / tree_map
like plain parameter trees.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

MODES = ("off", "int8", "fp8")          # weight quantization modes
KV_MODES = ("off", "int8")              # KV-page quantization modes

_INT8_MAX = 127.0
_FP8_MAX = 448.0                        # e4m3fn max normal
_EPS = 1e-12                            # zero-tensor guard for absmax scales

# full-precision islands: tied/untied unembedding + embeddings by name,
# norm gains by leaf key (rmsnorm params are ``{"scale": (..., D)}`` dicts,
# q_norm/k_norm are direct leaves).
_SKIP_KEYS = frozenset({"embed", "lm_head", "scale", "bias"})
_SKIP_SUFFIXES = ("_norm",)


class QuantLeaf(NamedTuple):
    """One quantized tensor: integer/fp8 codes + broadcastable scales.

    ``dtype_token`` is a zero-size array carrying the ORIGINAL leaf dtype so
    dequantization restores it exactly (bf16 weights come back bf16 — the
    downstream matmul dtypes match the unquantized path)."""
    codes: jax.Array        # int8 or float8_e4m3fn, original shape
    scale: jax.Array        # float32, shape (..., 1, d_out)-broadcastable
    dtype_token: jax.Array  # shape (), original dtype


def _fp8_cast(x):
    """Round fp32 onto the e4m3fn grid (and back to fp32)."""
    if hasattr(jnp, "float8_e4m3fn"):
        return x.astype(jnp.float8_e4m3fn)
    # simulated grid: clamp to max normal, round mantissa to 3 bits at the
    # value's binade (subnormals collapse toward 0 — same as the real cast
    # for the magnitudes per-channel scaling produces).
    mag = jnp.clip(jnp.abs(x), 0.0, _FP8_MAX)
    exp = jnp.floor(jnp.log2(jnp.maximum(mag, 2.0 ** -9)))
    ulp = jnp.exp2(exp - 3.0)
    return jnp.sign(x) * jnp.round(mag / ulp) * ulp


def _per_channel_scale(x, qmax: float):
    xf = x.astype(jnp.float32)
    axes = tuple(range(x.ndim - 1))
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    return jnp.maximum(amax, _EPS) / qmax


def quantize_array(x: jax.Array, mode: str) -> QuantLeaf:
    """Symmetric per-output-channel quantization of one weight tensor."""
    xf = x.astype(jnp.float32)
    token = jnp.zeros((), x.dtype)
    if mode == "int8":
        scale = _per_channel_scale(x, _INT8_MAX)
        codes = jnp.clip(jnp.round(xf / scale), -_INT8_MAX, _INT8_MAX)
        return QuantLeaf(codes.astype(jnp.int8), scale, token)
    if mode == "fp8":
        scale = _per_channel_scale(x, _FP8_MAX)
        codes = _fp8_cast(xf / scale)
        return QuantLeaf(codes, scale, token)
    raise ValueError(f"unknown quant mode {mode!r} (expected int8 | fp8)")


def dequantize_array(leaf: QuantLeaf) -> jax.Array:
    """Back to the original dtype; jit-safe (fuses into the consumer)."""
    return (leaf.codes.astype(jnp.float32)
            * leaf.scale).astype(leaf.dtype_token.dtype)


def _skip(key: str, leaf: Any) -> bool:
    if key in _SKIP_KEYS or key.endswith(_SKIP_SUFFIXES):
        return True
    ndim = getattr(leaf, "ndim", 0)
    if ndim < 2:
        return True
    return not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating)


def quantize_params(params: Any, mode: str) -> Any:
    """Quantize every matmul-weight leaf of a parameter pytree.

    ``mode="off"`` returns the tree untouched (the byte-identical path).
    Embeddings, lm_head and norm gains are kept full precision (see module
    docstring); everything else becomes a ``QuantLeaf``."""
    if mode == "off":
        return params
    if mode not in MODES:
        raise ValueError(f"unknown quant mode {mode!r} (expected "
                         "off | int8 | fp8)")

    def rec(node, key):
        if isinstance(node, dict):
            return {k: rec(v, k) for k, v in node.items()}
        if _skip(key, node):
            return node
        return quantize_array(node, mode)

    return rec(params, "")


def _is_leaf(x: Any) -> bool:
    return isinstance(x, QuantLeaf)


def dequantize_params(params: Any) -> Any:
    """Inverse of ``quantize_params``; identity on plain trees.

    Called at the top of the engine's jitted step — for an unquantized tree
    this traces to the exact same jaxpr as passing ``params`` through, so
    ``quant_mode="off"`` stays byte-identical to the pre-quant engine."""
    return jax.tree_util.tree_map(
        lambda leaf: dequantize_array(leaf) if _is_leaf(leaf) else leaf,
        params, is_leaf=_is_leaf)


def is_quantized_tree(params: Any) -> bool:
    """Whether any leaf of ``params`` is a ``QuantLeaf``."""
    found = False

    def check(leaf):
        nonlocal found
        found = found or _is_leaf(leaf)

    jax.tree_util.tree_map(check, params, is_leaf=_is_leaf)
    return found
