"""Quantized rollout subsystem (FlashRL recipe over the paged engine).

Rollout replicas hold INT8/FP8 weights (quantized at weight-sync time)
and optionally int8 KV pages while the trainer stays full-precision; the
resulting engine mismatch is absorbed by the truncated importance-sampling
correction in `repro.algos.off_policy` (``tis_clip``).
"""
from repro.quant.core import (
    KV_MODES,
    MODES,
    QuantLeaf,
    dequantize_array,
    dequantize_params,
    is_quantized_tree,
    quantize_array,
    quantize_params,
)

__all__ = [
    "KV_MODES",
    "MODES",
    "QuantLeaf",
    "dequantize_array",
    "dequantize_params",
    "is_quantized_tree",
    "quantize_array",
    "quantize_params",
]
