"""BaseEnv: the environment interface consumed by EnvManager (§4.2).

Token-level API: observations and actions are int32 token arrays — the
EnvManager never sees text, matching the LLM-centric rollout loop.
"""
from __future__ import annotations

import abc
from typing import Tuple

import numpy as np


class BaseEnv(abc.ABC):
    @abc.abstractmethod
    def reset(self) -> np.ndarray:
        """Start an episode; returns initial observation tokens."""

    @abc.abstractmethod
    def step(self, action_tokens: np.ndarray) -> Tuple[np.ndarray, float, bool, dict]:
        """Apply an action; returns (obs_tokens, reward, done, info)."""

    def close(self) -> None:  # pragma: no cover - optional
        pass
