"""Simulated agentic environments.

* ``LatencyEnv`` — latency-modeled env (Gaussian per-step latency, optional
  fail-slow multiplier and fail-stop hangs) for §5.2 experiments.  The task
  itself is a trivial token-echo so rewards are verifiable.
* ``GridTargetEnv`` — an ALFWorld-flavoured stateful task: the agent must
  emit the token sequence navigating to a target cell; rewards are sparse
  (success only), episodes span multiple turns.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

from repro.envs.base import BaseEnv

# token ids for grid actions
TOK_UP, TOK_DOWN, TOK_LEFT, TOK_RIGHT = 1, 2, 3, 4
_ACTION_DELTA = {TOK_UP: (0, -1), TOK_DOWN: (0, 1), TOK_LEFT: (-1, 0), TOK_RIGHT: (1, 0)}


class LatencyEnv(BaseEnv):
    """Env whose step() sleeps a sampled latency (real seconds, scaled)."""

    def __init__(self, env_id: int, *, mu: float = 0.05, sigma: float = 0.02,
                 max_steps: int = 4, p_fail_slow: float = 0.0,
                 fail_slow_factor: float = 5.0, p_fail_stop: float = 0.0,
                 time_scale: float = 1.0, seed: Optional[int] = None):
        self.env_id = env_id
        self.rng = np.random.default_rng(env_id if seed is None else seed)
        self.mu, self.sigma = mu, sigma
        self.max_steps = max_steps
        self.p_fail_slow = p_fail_slow
        self.fail_slow_factor = fail_slow_factor
        self.p_fail_stop = p_fail_stop
        self.time_scale = time_scale
        self._t = 0
        self._hung = False

    def reset(self) -> np.ndarray:
        self._t = 0
        self._hung = bool(self.p_fail_stop and self.rng.random() < self.p_fail_stop)
        return np.asarray([10 + self.env_id % 50], np.int32)

    def _latency(self) -> float:
        lat = max(0.0, self.rng.normal(self.mu, self.sigma))
        if self.p_fail_slow and self.rng.random() < self.p_fail_slow:
            lat *= self.fail_slow_factor
        return lat * self.time_scale

    def step(self, action_tokens) -> Tuple[np.ndarray, float, bool, dict]:
        if self._hung:
            # fail-stop: hang far longer than any reasonable step budget
            time.sleep(3600 * self.time_scale)
        time.sleep(self._latency())
        self._t += 1
        done = self._t >= self.max_steps
        reward = 1.0 if done and len(action_tokens) > 0 else 0.0
        return np.asarray([10 + self._t], np.int32), reward, done, {}


class GridTargetEnv(BaseEnv):
    """Navigate a 5x5 grid to the target; observation encodes (pos, target)."""

    SIZE = 5

    def __init__(self, env_id: int, *, max_steps: int = 8,
                 latency: float = 0.0, seed: Optional[int] = None):
        self.rng = np.random.default_rng(env_id if seed is None else seed)
        self.max_steps = max_steps
        self.latency = latency
        self.pos = (0, 0)
        self.target = (0, 0)
        self._t = 0

    def _obs(self) -> np.ndarray:
        return np.asarray([
            100 + self.pos[0], 110 + self.pos[1],
            120 + self.target[0], 130 + self.target[1],
        ], np.int32)

    def reset(self) -> np.ndarray:
        self.pos = tuple(self.rng.integers(0, self.SIZE, 2).tolist())
        while True:
            self.target = tuple(self.rng.integers(0, self.SIZE, 2).tolist())
            if self.target != self.pos:
                break
        self._t = 0
        return self._obs()

    def step(self, action_tokens) -> Tuple[np.ndarray, float, bool, dict]:
        if self.latency:
            time.sleep(self.latency)
        self._t += 1
        for tok in np.asarray(action_tokens).ravel():
            d = _ACTION_DELTA.get(int(tok))
            if d is None:
                continue
            self.pos = (int(np.clip(self.pos[0] + d[0], 0, self.SIZE - 1)),
                        int(np.clip(self.pos[1] + d[1], 0, self.SIZE - 1)))
        success = self.pos == self.target
        done = success or self._t >= self.max_steps
        return self._obs(), (1.0 if success else 0.0), done, {"success": success}
