from repro.envs.base import BaseEnv  # noqa: F401
from repro.envs.sim_envs import GridTargetEnv, LatencyEnv  # noqa: F401
