"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t.

Elementwise over the width axis, sequential over time: grid
(batch, width_blocks, time_chunks), time innermost carrying the (1, block_w)
state in VMEM scratch.  Within a chunk, a log2(block_t) Blelloch-style
doubling pass would be possible; the baseline uses the straightforward
fori_loop (the op is bandwidth-bound: 2 loads + 1 store per element, so the
sequential loop already sits at the roofline for realistic widths).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hout_ref, h_scr, *,
                  block_t: int, num_t_blocks: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        h_scr[...] = h0_ref[...]

    def step(t, _):
        h = a_ref[0, t] * h_scr[0] + b_ref[0, t]
        y_ref[0, t] = h
        h_scr[0] = h
        return 0

    jax.lax.fori_loop(0, block_t, step, 0)

    @pl.when(tj == num_t_blocks - 1)
    def _finalize():
        hout_ref[...] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("block_t", "block_w", "interpret"))
def rglru_scan(a, b, h0, *, block_t: int = 256, block_w: int = 512,
               interpret: bool = False):
    """a/b: (B, T, W); h0: (B, W). Returns (hs (B, T, W) fp32, h_last)."""
    bsz, t, w = a.shape
    assert t % block_t == 0, (t, block_t)
    block_w = min(block_w, w)
    assert w % block_w == 0, (w, block_w)
    nt, nw = t // block_t, w // block_w

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    kernel = functools.partial(_rglru_kernel, block_t=block_t, num_t_blocks=nt)
    io_spec = pl.BlockSpec((1, block_t, block_w), lambda bb, wi, tj: (bb, tj, wi))
    h_spec = pl.BlockSpec((1, block_w), lambda bb, wi, tj: (bb, wi))
    hs, h_last = pl.pallas_call(
        kernel,
        grid=(bsz, nw, nt),
        in_specs=[io_spec, io_spec, h_spec],
        out_specs=[io_spec, h_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, w), jnp.float32),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(af, bf, h0.astype(jnp.float32))
    return hs, h_last
