"""Pallas TPU decode attention: one query token vs. a long KV cache.

This is the rollout engine's inner loop — the memory-bandwidth-bound op that
makes decoding unscalable (the paper's motivation for async).  The kernel
streams the KV cache through VMEM in (block_k, d) tiles, online-softmax
accumulating into a (G, d) scratch tile per kv-head (G = GQA group size,
padded to the 8-row sublane minimum).

Grid: (batch, kv_head, kv_blocks) — kv innermost for scratch carry.
Length masking is positional (lengths ref in SMEM), so one compiled kernel
serves every slot fill level of the continuous-batching engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, block_k: int, num_kv_blocks: int, window):
    bi = pl.program_id(0)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)
    d = q.shape[-1]
    length = len_ref[bi]

    logits = jax.lax.dot_general(q * (d ** -0.5), k,
                                 (((1,), (1,)), ((), ())))  # (G, block_k)
    pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = pos < length
    if window is not None:
        mask &= pos >= (length - window)
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p.astype(v.dtype), v)
    m_scr[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, window=None, block_k: int = 512,
                     interpret: bool = False):
    """q: (B, H, D); k/v: (B, S, KV, D); lengths: (B,) int32.
    Returns (B, H, D)."""
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    assert s % block_k == 0, (s, block_k)
    nk = s // block_k
    g_pad = max(8, g)  # sublane minimum

    qg = q.reshape(b, kv, g, d)
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    # (B, S, KV, D) -> (B, KV, S, D) tile-friendly layout
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               num_kv_blocks=nk, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g_pad, d), lambda bb, hh, kj: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, kj: (bb, hh, kj, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, kj: (bb, hh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_pad, d), lambda bb, hh, kj: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out[:, :, :g, :].reshape(b, h, d)
