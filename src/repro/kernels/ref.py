"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """q: (B, H, S, D); k/v: (B, KV, S, D); GQA via H % KV == 0.
    Returns (B, H, S, D), accumulation in fp32."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, g, s, d) * (d ** -0.5)
    logits = jnp.einsum("bkgqd,bktd->bkgqt", qf, k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, window=None):
    """Single-token GQA decode. q: (B, H, D); k/v: (B, S, KV, D);
    lengths: (B,) number of valid cache entries (positions 0..len-1).
    Returns (B, H, D)."""
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, g, d) * (d ** -0.5)
    logits = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32))
    pos = jnp.arange(s)[None, :]
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                               k_scales=None, v_scales=None, softcap=None):
    """Paged single-token GQA decode. q: (B, H, D);
    k_pages/v_pages: (N, page_size, KV, D); block_tables: (B, P) int32
    physical page ids (-1 = unassigned); lengths: (B,) tokens written.
    ``k_scales``/``v_scales``: (N, page_size, KV) fp32 per-(slot, kv-head)
    scales for int8 pages (kv_quant) — the gathered view is dequantized
    before attention.  Returns (B, H, D)."""
    b, h, d = q.shape
    page_size, kv = k_pages.shape[1], k_pages.shape[2]
    g = h // kv
    idx = jnp.maximum(block_tables, 0)
    k = k_pages[idx].reshape(b, -1, kv, d)      # (B, P*page, KV, D)
    v = v_pages[idx].reshape(b, -1, kv, d)
    if k_scales is not None:
        k = k.astype(jnp.float32) * k_scales[idx].reshape(b, -1, kv)[..., None]
        v = v.astype(jnp.float32) * v_scales[idx].reshape(b, -1, kv)[..., None]
    s = k.shape[1]
    qf = q.astype(jnp.float32).reshape(b, kv, g, d) * (d ** -0.5)
    logits = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(s)[None, :]
    mask = (pos < lengths[:, None]) & jnp.repeat(block_tables >= 0, page_size,
                                                 axis=1)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, state):
    """RWKV-6 WKV recurrence. r/k/v/w: (B, T, H, D); u: (H, D);
    state: (B, H, D, D) fp32. Returns (y (B,T,H,D) fp32, new_state)."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp                      # (B, H, D)
        a = kt[..., :, None] * vt[..., None, :]   # (B, H, D, D)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., :, None] * a)
        s = wt[..., :, None] * s + a
        return s, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def rglru_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t. a/b: (B, T, W) fp32; h0: (B, W) fp32.
    Returns (hs (B,T,W), h_last)."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    af = a.astype(jnp.float32).transpose(1, 0, 2)
    bf = b.astype(jnp.float32).transpose(1, 0, 2)
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), (af, bf))
    return hs.transpose(1, 0, 2), h_last
