"""Pallas TPU paged decode attention: one query token vs. a paged KV pool.

The continuous-batching engine keeps KV in a shared page pool
(``(num_pages, page_size, n_kv, d)`` per layer) with per-request block
tables.  This kernel is the decode inner loop on that layout: the block
table and sequence lengths ride in as scalar-prefetch operands
(``PrefetchScalarGridSpec``), so each grid step's K/V tile is DMA'd
straight from the *physical* page the table points at — no dense
gather/copy of the request's KV ever materializes.

Grid: (batch, kv_head, pages_per_seq) — page dim innermost for the online
softmax scratch carry, same structure as ``decode_attention.py``.
Unassigned table entries (−1) are clamped to page 0 for the DMA and masked
out positionally; one compiled kernel serves every fill level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                  page_size: int, pages_per_seq: int, softcap,
                  quantized: bool = False):
    if quantized:
        # int8 KV pages: dequantize in-kernel from the per-(slot, kv-head)
        # fp32 scales riding in as two extra page-indexed operands (the
        # MaxText AQT kv_quant idiom — codes and scales DMA together from
        # the same physical page the block table points at).
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    bi = pl.program_id(0)
    pj = pl.program_id(2)

    @pl.when(pj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (page_size, d)
    v = v_ref[0, 0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, 0][:, None]            # (page_size,) scales
        v = v * vs_ref[0, 0][:, None]
    d = q.shape[-1]
    length = len_ref[bi]
    assigned = bt_ref[bi * pages_per_seq + pj] >= 0

    logits = jax.lax.dot_general(q * (d ** -0.5), k,
                                 (((1,), (1,)), ((), ())))  # (G, page_size)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = pj * page_size + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = (pos < length) & assigned
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p.astype(v.dtype), v)
    m_scr[...] = m_new

    @pl.when(pj == pages_per_seq - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           k_scales=None, v_scales=None,
                           softcap=None, interpret: bool = False):
    """q: (B, H, D); k_pages/v_pages: (N, page_size, KV, D);
    block_tables: (B, P) int32 physical page ids (-1 = unassigned);
    lengths: (B,) int32 tokens written so far.  Returns (B, H, D).

    ``k_scales``/``v_scales`` (both or neither): (N, page_size, KV) fp32
    per-(slot, kv-head) scales for int8 pages — the kernel dequantizes
    each page tile in VMEM right after the DMA (``kv_quant="int8"``)."""
    b, h, d = q.shape
    n, page_size, kv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    p_seq = block_tables.shape[1]
    g = h // kv
    g_pad = max(8, g)  # sublane minimum
    quantized = k_scales is not None

    qg = q.reshape(b, kv, g, d)
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    # (N, page, KV, D) -> (N, KV, page, D) tile-friendly layout
    kt = k_pages.transpose(0, 2, 1, 3)
    vt = v_pages.transpose(0, 2, 1, 3)

    bt_flat = block_tables.reshape(-1).astype(jnp.int32)

    def page_map(bb, hh, pj, bt, ln):
        del ln
        idx = jnp.maximum(bt[bb * p_seq + pj], 0)  # -1 -> garbage page 0
        return (idx, hh, 0, 0)

    def scale_map(bb, hh, pj, bt, ln):
        del ln
        idx = jnp.maximum(bt[bb * p_seq + pj], 0)
        return (idx, hh, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g_pad, d), lambda bb, hh, pj, bt, ln: (bb, hh, 0, 0)),
        pl.BlockSpec((1, 1, page_size, d), page_map),
        pl.BlockSpec((1, 1, page_size, d), page_map),
    ]
    operands = [qg, kt, vt]
    if quantized:
        # (N, page, KV) -> (N, KV, page): same physical-page indexing as
        # the code tiles, one (1, 1, page_size) fp32 block per grid step.
        in_specs += [pl.BlockSpec((1, 1, page_size), scale_map),
                     pl.BlockSpec((1, 1, page_size), scale_map)]
        operands += [k_scales.transpose(0, 2, 1).astype(jnp.float32),
                     v_scales.transpose(0, 2, 1).astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, p_seq),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g_pad, d),
                               lambda bb, hh, pj, bt, ln: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, page_size=page_size,
                               pages_per_seq=p_seq, softcap=softcap,
                               quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g_pad, d), q.dtype),
        interpret=interpret,
    )(bt_flat, lengths.astype(jnp.int32), *operands)
    return out[:, :, :g, :].reshape(b, h, d)
