"""Jit'd kernel entry points with backend dispatch.

``use_pallas`` selects the Pallas TPU kernels (interpret=True on CPU —
the kernel bodies execute in Python for correctness validation); the
default XLA path is what pjit lowers in the dry-run (Pallas kernels do not
lower on the CPU placeholder backend, and on a real TPU fleet you would
flip the flag per-op after profiling).
"""
from __future__ import annotations


import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.rglru_scan import rglru_scan as _rglru_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    use_pallas=False, block_q=128, block_k=128):
    if use_pallas:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             softcap=softcap, block_q=block_q, block_k=block_k,
                             interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)


def decode_attention(q, k, v, lengths, *, window=None, use_pallas=False,
                     block_k=512):
    if use_pallas:
        return _decode_pallas(q, k, v, lengths, window=window,
                              block_k=min(block_k, k.shape[1]),
                              interpret=not _on_tpu())
    return ref.decode_attention_ref(q, k, v, lengths, window=window)


def rwkv6_scan(r, k, v, w, u, state, *, use_pallas=False, block_t=128):
    if use_pallas:
        bt = min(block_t, r.shape[1])
        return _rwkv6_pallas(r, k, v, w, u, state, block_t=bt,
                             interpret=not _on_tpu())
    return ref.rwkv6_scan_ref(r, k, v, w, u, state)


def rglru_scan(a, b, h0, *, use_pallas=False, block_t=256, block_w=512):
    if use_pallas:
        return _rglru_pallas(a, b, h0, block_t=min(block_t, a.shape[1]),
                             block_w=block_w, interpret=not _on_tpu())
    return ref.rglru_scan_ref(a, b, h0)
