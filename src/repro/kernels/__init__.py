"""Pallas TPU kernels for the rollout/training hot spots.

Each kernel: <name>.py (pl.pallas_call + BlockSpec VMEM tiling), validated
in interpret mode against the pure-jnp oracle in ref.py; ops.py is the
dispatching jit'd wrapper.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.decode_attention import decode_attention  # noqa: F401
from repro.kernels.paged_decode_attention import paged_decode_attention  # noqa: F401
from repro.kernels.rwkv6_scan import rwkv6_scan  # noqa: F401
from repro.kernels.rglru_scan import rglru_scan  # noqa: F401
