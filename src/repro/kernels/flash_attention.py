"""Pallas TPU flash attention (prefill): GQA + causal + sliding window.

Grid: (batch, q_head, q_blocks, kv_blocks) with kv_blocks innermost so the
online-softmax running state (m, l, acc) lives in VMEM scratch across the
kv sweep for a fixed output tile.  Block shapes are MXU-aligned
(block_q x head_dim and block_k x head_dim, multiples of 128 columns); the
(S, S) score matrix is never materialised — VMEM holds one
(block_q, block_k) tile of logits at a time.

Causal/window masking is positional via broadcasted_iota on the global
indices; fully-masked kv tiles still execute in the baseline (documented
roofline overhead — see EXPERIMENTS.md §Perf for the pruned variant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, window, softcap,
                  num_kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level mask pruning: a fully-masked (qi, kj) tile contributes
    # nothing — skip its two MXU dots entirely.  For causal attention this
    # halves kernel FLOPs; with a sliding window it prunes to the band.
    if causal or window is not None:
        needed = jnp.asarray(True)
        if causal:
            needed = jnp.logical_and(
                needed, kj * block_k <= qi * block_q + block_q - 1)
        if window is not None:
            needed = jnp.logical_and(
                needed, (kj + 1) * block_k - 1 >= qi * block_q - window + 1)
        guard = pl.when(needed)
    else:
        guard = lambda f: f()  # dense attention: every tile is needed

    @guard
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)      # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        d = q.shape[-1]

        logits = jax.lax.dot_general(q * (d ** -0.5), k,
                                     (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap is not None:
            logits_c = softcap * jnp.tanh(logits / softcap)
        else:
            logits_c = logits

        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        logits_m = jnp.where(mask, logits_c, _NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, logits_m.max(axis=-1, keepdims=True))
        p = jnp.exp(logits_m - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p.astype(v.dtype), v)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, S, D); k/v: (B, KV, S, D). Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, softcap=softcap, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qi, kj: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, qi, kj, g=g: (bb, hh // g, kj, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, qi, kj, g=g: (bb, hh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, kj: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
