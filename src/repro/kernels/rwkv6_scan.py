"""Pallas TPU kernel for the RWKV-6 WKV recurrence (data-dependent decay).

The (D, D) per-head state lives in VMEM scratch and is carried across time
chunks; the grid is (batch, head, time_chunks) with time innermost.  Within
a chunk, the recurrence is a fori_loop of rank-1 updates — sequential by
construction (the decay w_t depends on position t's input), which is the
TPU-native adaptation of RWKV's CUDA kernel: instead of one thread per
channel, whole (D, D) outer products ride the VPU per step, and the
sequential axis is chunked so HBM traffic is tiled through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                state_scr, *, block_t: int, num_t_blocks: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0]

    u = u_ref[0]  # (D,)

    def step(t, _):
        rt = r_ref[0, 0, t]            # (D,)
        kt = k_ref[0, 0, t]
        vt = v_ref[0, 0, t]
        wt = w_ref[0, 0, t]
        s = state_scr[...]             # (D, D)
        a = kt[:, None] * vt[None, :]  # rank-1 update
        y = ((s + u[:, None] * a) * rt[:, None]).sum(axis=0)
        y_ref[0, 0, t] = y
        state_scr[...] = wt[:, None] * s + a
        return 0

    jax.lax.fori_loop(0, block_t, step, 0)

    @pl.when(tj == num_t_blocks - 1)
    def _finalize():
        sout_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, state, *, block_t: int = 128,
               interpret: bool = False):
    """r/k/v/w: (B, T, H, D); u: (H, D); state: (B, H, D, D) fp32.
    Returns (y (B, T, H, D) fp32, new_state)."""
    b, t, h, d = r.shape
    assert t % block_t == 0, (t, block_t)
    nt = t // block_t

    # (B, T, H, D) -> (B, H, T, D)
    rt, kt, vt, wt = (x.transpose(0, 2, 1, 3).astype(jnp.float32)
                      for x in (r, k, v, w))
    u2 = u.astype(jnp.float32)

    kernel = functools.partial(_wkv_kernel, block_t=block_t, num_t_blocks=nt)
    io_spec = pl.BlockSpec((1, 1, block_t, d), lambda bb, hh, tj: (bb, hh, tj, 0))
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b, h, nt),
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, d), lambda bb, hh, tj: (hh, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bb, hh, tj: (bb, hh, 0, 0)),
        ],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, d, d), lambda bb, hh, tj: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u2, state.astype(jnp.float32))
    return y.transpose(0, 2, 1, 3), s_out
