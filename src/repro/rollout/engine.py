"""Slot-based continuous-batching decode engine (the TPU-native vLLM).

TPUs demand static shapes, so instead of paged KV blocks the engine holds a
fixed number of decode *slots*, each owning one row of a statically shaped
KV cache / recurrent state.  ADD claims a free slot (prefilling the prompt
into that row); every `step()` advances ALL active slots by one token in a
single jitted call; finish/ABORT releases the slot.  This is exactly the
LLMProxy's step-wise inference contract (§4.2): one engine step per event-
loop iteration, completed requests surfacing immediately.

Implements `repro.core.llm_proxy.InferenceEngine`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import GenerationResult
from repro.models.api import ModelAPI
from repro.quant import core as quant
from repro.rollout.sampler import sample_tokens


@dataclasses.dataclass
class _SlotState:
    request_id: int
    tokens: List[int]
    logprobs: List[float]
    remaining: int


def _batch_axis(path) -> int:
    return 0 if any(getattr(k, "key", None) == "tail" for k in path) else 1


def _insert_slot(cache, slot_cache, slot: int):
    """Write a single-request cache (batch=1) into the engine cache row."""
    def one(path, big, small):
        ax = _batch_axis(path)
        idx = [0] * big.ndim
        idx[ax] = slot
        # Pad trailing dims (e.g. a shorter prefill seq axis) up to the
        # engine cache — but NEVER the batch axis: the update block must stay
        # batch=1 so dynamic_update_slice writes exactly one slot row.
        # (Padding the batch axis makes XLA clamp the start index to 0 and
        # silently overwrite every slot — cross-request corruption.)
        pad_width = [(0, max(0, b - s_)) if i != ax else (0, 0)
                     for i, (s_, b) in enumerate(zip(small.shape, big.shape,
                                                     strict=True))]
        if any(p != (0, 0) for p in pad_width):
            fill = -1 if small.dtype == jnp.int32 else 0
            small = jnp.pad(small, pad_width, constant_values=fill)
        assert small.shape[ax] == 1, (small.shape, ax)
        return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), tuple(idx))

    return jax.tree_util.tree_map_with_path(one, cache, slot_cache)


class DecodeEngine:
    def __init__(self, api: ModelAPI, params, *, num_slots: int = 8,
                 max_total_len: int = 128, eos_id: int = 2,
                 temperature: float = 1.0, top_k: int = 0,
                 pad_id: int = 0, seed: int = 0,
                 prefill_bucket: Optional[int] = 16,
                 quant_mode: str = "off"):
        cfg = api.cfg
        if quant_mode not in quant.MODES:
            raise ValueError(f"unknown quant_mode {quant_mode!r} "
                             f"(expected {' | '.join(quant.MODES)})")
        self.api = api
        # quantize-on-sync (same scheme as the paged engine): the slot
        # engine holds int8/fp8 codes and dequantizes inside its jits.
        self.quant_mode = quant_mode
        self.params = quant.quantize_params(params, quant_mode)
        self.num_slots = num_slots
        self.max_total_len = max_total_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self.top_k = top_k
        # recurrent state ingests every fed position: exact-length prefill
        self.prefill_bucket = None if cfg.family in ("ssm", "hybrid") else prefill_bucket
        if cfg.sliding_window is not None and cfg.sliding_window < max_total_len:
            raise ValueError("engine requires cache >= max_total_len "
                             "(enlarge window or shorten sequences)")
        self._key = jax.random.PRNGKey(seed)
        self.cache = api.init_cache(num_slots, max_total_len)
        self.cur_token = jnp.full((num_slots,), pad_id, jnp.int32)
        self.pos = jnp.zeros((num_slots,), jnp.int32)
        self.active = np.zeros((num_slots,), bool)
        self.slots: Dict[int, _SlotState] = {}      # slot -> state
        self.req_to_slot: Dict[int, int] = {}
        self.total_decode_steps = 0
        self.total_tokens_decoded = 0

        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # ----------------------------------------------------------- jit bodies
    def _decode_impl(self, params, cache, cur_token, pos, key):
        params = quant.dequantize_params(params)  # identity when "off"
        logits, cache = self.api.decode_step(params, cur_token, pos, cache)
        tok, lp = sample_tokens(key, logits, temperature=self.temperature,
                                top_k=self.top_k)
        return tok.astype(jnp.int32), lp, cache

    def _prefill_impl(self, params, tokens, valid):
        params = quant.dequantize_params(params)  # identity when "off"
        cache = self.api.init_cache(1, self.max_total_len)
        logits, cache = self.api.prefill(
            params, {"tokens": tokens, "valid": valid}, cache)
        return logits, cache

    # ------------------------------------------------------------ protocol
    @property
    def num_free_slots(self) -> int:
        return self.num_slots - len(self.slots)

    @property
    def active_request_ids(self) -> List[int]:
        return list(self.req_to_slot)

    def set_quant_mode(self, mode: str) -> None:
        """Change quantization mid-run; applies at the next update_weights
        (the held tree is already lossily quantized)."""
        if mode not in quant.MODES:
            raise ValueError(f"unknown quant_mode {mode!r} "
                             f"(expected {' | '.join(quant.MODES)})")
        self.quant_mode = mode

    def update_weights(self, params) -> None:
        self.params = quant.quantize_params(params, self.quant_mode)

    def add_request(self, request_id: int, prompt_tokens, max_new_tokens: int) -> None:
        assert self.num_free_slots > 0, "no free slot"
        slot = next(i for i in range(self.num_slots) if not self.active[i])
        prompt = np.asarray(prompt_tokens, np.int32).ravel()
        plen = len(prompt)
        assert plen + max_new_tokens <= self.max_total_len, "sequence budget"

        if self.prefill_bucket:
            padded = int(np.ceil(plen / self.prefill_bucket) * self.prefill_bucket)
        else:
            padded = plen
        toks = np.full((1, padded), self.pad_id, np.int32)
        toks[0, :plen] = prompt
        valid = np.zeros((1, padded), bool)
        valid[0, :plen] = True

        logits, slot_cache = self._prefill(self.params, jnp.asarray(toks),
                                           jnp.asarray(valid))
        self.cache = _insert_slot(self.cache, slot_cache, slot)

        # prefill returns last-real-position logits directly: (1, V)
        self._key, sub = jax.random.split(self._key)
        tok, lp = sample_tokens(sub, logits,
                                temperature=self.temperature, top_k=self.top_k)
        tok_i, lp_f = int(tok[0]), float(lp[0])

        self.cur_token = self.cur_token.at[slot].set(tok_i)
        self.pos = self.pos.at[slot].set(plen)
        self.active[slot] = True
        st = _SlotState(request_id=request_id, tokens=[tok_i],
                        logprobs=[lp_f], remaining=max_new_tokens - 1)
        self.slots[slot] = st
        self.req_to_slot[request_id] = slot

    def peek_tokens(self, request_id: int, start: int = 0) -> List[int]:
        """Decoded tokens[start:] of an active request (streaming hook)."""
        slot = self.req_to_slot.get(request_id)
        if slot is None:
            return []
        return list(self.slots[slot].tokens[start:])

    def abort(self, request_id: int) -> GenerationResult:
        slot = self.req_to_slot.pop(request_id)
        st = self.slots.pop(slot)
        self.active[slot] = False
        return GenerationResult(
            request_id=request_id, task=None,
            tokens=np.asarray(st.tokens, np.int32),
            logprobs=np.asarray(st.logprobs, np.float32),
            version_started=-1, aborted=True, partial=True)

    def step(self) -> List[Tuple[int, np.ndarray, np.ndarray]]:
        """One decode step for every active slot; returns finished requests."""
        if not self.slots:
            return []
        finished: List[Tuple[int, np.ndarray, np.ndarray]] = []
        # check eos/budget BEFORE decoding the next token: the last sampled
        # token may already terminate the request.
        for slot in list(self.slots):
            st = self.slots[slot]
            if st.tokens and (st.tokens[-1] == self.eos_id or st.remaining <= 0):
                finished.append(self._finish(slot))
        if not self.slots:
            return finished

        self._key, sub = jax.random.split(self._key)
        tok, lp, self.cache = self._decode(self.params, self.cache,
                                           self.cur_token, self.pos, sub)
        self.total_decode_steps += 1
        self.cur_token = tok
        self.pos = self.pos + 1
        tok_np = np.asarray(tok)
        lp_np = np.asarray(lp)
        for slot, st in list(self.slots.items()):
            st.tokens.append(int(tok_np[slot]))
            st.logprobs.append(float(lp_np[slot]))
            st.remaining -= 1
            self.total_tokens_decoded += 1
        return finished

    def _finish(self, slot: int) -> Tuple[int, np.ndarray, np.ndarray]:
        st = self.slots.pop(slot)
        self.req_to_slot.pop(st.request_id, None)
        self.active[slot] = False
        toks = np.asarray(st.tokens, np.int32)
        lps = np.asarray(st.logprobs, np.float32)
        # strip trailing eos from the budget view but keep it in the sample
        return st.request_id, toks, lps
