"""Paged-KV continuous-batching engine: chunked prefill, abort→resume, and
copy-on-write prefix sharing for GRPO prompt groups.

The slot engine (`engine.py`) prefills each admitted prompt at batch=1 in a
single variable-length call — every active request stalls for the whole
prefill, each distinct prompt length compiles a new executable, and an
ABORTed request's KV is lost (resume re-prefills the accumulated prefix).
This engine fixes all three pathologies:

* **Paged KV** — KV lives in a shared page pool with per-request block
  tables (`repro.models.paged`); admission allocates pages, ABORT with
  ``retain=True`` parks them, resume re-attaches them.  No prefix is ever
  recomputed on the abort→resume path (§5.1 queue scheduling + the async
  architecture's abort-under-new-weights).  Behaviour-policy logprobs of
  the retained prefix are kept — they are exactly what the IS-based
  off-policy correctors consume; new-policy logprobs are recomputed by the
  trainer's forward pass where needed, never by the engine.
* **Chunked prefill** — prompts are fed in fixed-size token chunks
  co-scheduled with decode inside the same ``step()``: one chunk of ONE
  prefilling request plus one decode token for EVERY decoding slot.
  Admitting a 32k prompt no longer blocks the batch for a full prefill.
* **COW prefix sharing** — ``submit_group`` admits the G candidates of one
  GRPO prompt as a unit: the prompt is chunk-prefilled ONCE into the
  leader lane, then the group forks — follower block tables alias the
  fully-filled prompt pages (refcount G in the ``PagePool``) and each lane
  privately owns only the partial tail page (copied at fork) plus its
  decode region.  G× less prefill compute, ~(G-1)/G of the prompt KV
  reclaimed; divergence after the fork only ever writes privately owned
  pages, so the Pallas ``paged_decode_attention`` kernel is unchanged —
  only block-table construction knows about sharing.
* **Automatic cross-prompt prefix caching** — with ``prefix_cache=True`` a
  radix tree (`repro.models.paged.RadixCache`) indexes every fully-filled
  KV page of finished/aborted requests by token content; admission aliases
  the longest cached page-aligned prefix into the new block table and
  chunked prefill starts at the first uncached token.  A prefilling slot at
  a page boundary also adopts pages a concurrent request just published, so
  a shared system prompt prefills exactly once per batch.  LRU leaves evict
  under page pressure (the cache never causes admission failure) and the
  whole tree flushes on ``update_weights`` (cached KV is policy-dependent).
* **Static shapes** — ``step()`` is a single jitted call (chunk + decode
  fused, ``lax.cond``-gated) whose shapes never depend on prompt length or
  fill level: exactly ONE executable serves every workload (TPU-friendly;
  the slot engine compiles one prefill per padded prompt length).

Implements `repro.core.llm_proxy.InferenceEngine` plus the retain/resume
and group-submit extensions consumed by `repro.core.scheduler`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import GenerationResult
from repro.models import paged
from repro.models.api import ModelAPI
from repro.quant import core as quant
from repro.rollout.sampler import sample_tokens

_PREFILL = "prefill"
_DECODE = "decode"
_FORKWAIT = "forkwait"   # group follower parked until the leader's prefill


@dataclasses.dataclass
class _SlotState:
    request_id: int
    prompt: np.ndarray
    tokens: List[int]
    logprobs: List[float]
    remaining: int
    phase: str = _PREFILL
    prefill_done: int = 0
    carried_last: Optional[int] = None   # last sampled token of a resumed prefix
    followers: List[int] = dataclasses.field(default_factory=list)
    group_leader: Optional[int] = None   # follower pre-fork: leader's slot
    # token content backing the slot's written KV region: positions
    # [0, len(content_prefix)) hold content_prefix, sampled tokens append
    # after it.  Equals ``prompt`` except for resumed-decode slots, whose
    # written region already includes previously decoded tokens.
    content_prefix: Optional[np.ndarray] = None
    # weight epoch the slot's KV was (first) computed under: pages are only
    # published to the prefix cache while this matches the engine's current
    # epoch — a post-weight-sync abort must not repopulate the flushed
    # cache with old-policy KV.
    epoch: int = 0


@dataclasses.dataclass
class _Retained:
    """A parked request: pages stay allocated (refs held), state frozen."""
    pages: List[int]
    phase: str
    prompt: np.ndarray
    prefill_done: int
    length: int                          # KV positions written (pos value)
    last_token: int
    # full token content of the written region (plus the pending last token
    # for decode-phase records): lets the prefix cache index these pages
    # if the record is released instead of resumed.
    content: Optional[np.ndarray] = None
    epoch: int = 0                       # weight epoch the KV was computed under


class PagedDecodeEngine:
    """Continuous-batching engine over a refcounted paged KV pool.

    ``attn_impl``: "ref" (pure-JAX gather, exact vs the slot engine),
    "kernel" (Pallas paged decode attention) or "kernel_interpret"
    (Pallas interpret mode, for CPU validation).
    """

    supports_retain = True
    supports_group = True

    def __init__(self, api: ModelAPI, params, *, num_slots: int = 8,
                 max_total_len: int = 128, page_size: int = 16,
                 prefill_chunk: int = 16, num_pages: Optional[int] = None,
                 eos_id: int = 2, temperature: float = 1.0, top_k: int = 0,
                 pad_id: int = 0, seed: int = 0, attn_impl: str = "ref",
                 prefix_cache: bool = False, quant_mode: str = "off",
                 kv_quant: str = "off"):
        cfg = api.cfg
        if api.init_paged_cache is None:
            raise ValueError(f"family {cfg.family} has no paged-KV support "
                             "(use the slot DecodeEngine)")
        if cfg.sliding_window is not None and cfg.sliding_window < max_total_len:
            raise ValueError("engine requires cache >= max_total_len "
                             "(enlarge window or shorten sequences)")
        if quant_mode not in quant.MODES:
            raise ValueError(f"unknown quant_mode {quant_mode!r} "
                             f"(expected {' | '.join(quant.MODES)})")
        if kv_quant not in quant.KV_MODES:
            raise ValueError(f"unknown kv_quant {kv_quant!r} "
                             f"(expected {' | '.join(quant.KV_MODES)})")
        self.api = api
        # quantize-on-sync: replicas hold int8/fp8 codes on device (the
        # trainer's tree is quantized HERE, at construction and on every
        # update_weights) and the jitted step dequantizes at trace time.
        self.quant_mode = quant_mode
        self.kv_quant = kv_quant
        self.params = quant.quantize_params(params, quant_mode)
        self.total_weight_syncs_quantized = 0
        self.num_slots = num_slots
        self.max_total_len = max_total_len
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.pages_per_seq = paged.pages_per_seq(max_total_len, page_size)
        if num_pages is None:
            num_pages = 1 + num_slots * self.pages_per_seq  # +1: garbage page
        self.num_pages = num_pages
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self.top_k = top_k
        self.attn_impl = attn_impl
        self._key = jax.random.PRNGKey(seed)

        self.cache = api.init_paged_cache(num_pages, page_size,
                                          kv_quant=kv_quant)
        self.block_tables = jnp.full((num_slots, self.pages_per_seq), -1,
                                     jnp.int32)
        self.cur_token = jnp.full((num_slots,), pad_id, jnp.int32)
        self.pos = jnp.zeros((num_slots,), jnp.int32)
        self.pool = paged.PagePool(num_pages, page_size)
        # automatic cross-prompt prefix caching (radix tree over page
        # contents); None = disabled, every page frees on release.
        self.prefix_cache: Optional[paged.RadixCache] = \
            paged.RadixCache(self.pool) if prefix_cache else None
        self._weight_epoch = 0
        self._slot_pages: Dict[int, List[int]] = {}
        self.slots: Dict[int, _SlotState] = {}
        self.req_to_slot: Dict[int, int] = {}
        self.retained: Dict[int, _Retained] = {}
        self._rr = 0

        self.total_decode_steps = 0
        self.total_tokens_decoded = 0
        self.total_prefill_chunks = 0
        self.total_prefill_tokens = 0
        self.total_groups_forked = 0
        # batched-dispatch accounting: fork tail copies and cross-replica
        # transfers each issue ONE gather/scatter device call per request —
        # ops counters stay O(requests) while page counters grow O(pages).
        self.total_copy_ops = 0          # batched fork-tail device copies
        self.total_pages_copied = 0      # pages moved by those copies
        self.pages_transferred_in = 0    # cross-replica pages imported
        self.pages_transferred_out = 0   # cross-replica pages exported
        self.transfer_bytes_in = 0
        self.transfer_bytes_out = 0
        self.transfer_device_ops = 0     # batched export/import dispatches

        self._step = jax.jit(self._step_impl, donate_argnums=(1,))
        self._copy_pages = jax.jit(paged.copy_pages, donate_argnums=(0,))
        self._import_pages = jax.jit(paged.import_pages, donate_argnums=(0,))

    # ----------------------------------------------------------- jit body
    def _step_impl(self, params, cache, cur_token, pos, decode_tables,
                   chunk_tokens, chunk_valid, chunk_start, chunk_row,
                   do_prefill, do_decode, key):
        """ONE fused engine step: a prefill chunk for one request (cond-gated)
        plus a decode token for every unmasked slot.  All shapes static."""
        cfg = self.api.cfg
        vocab = cfg.vocab_size
        # dequantize quantize-on-sync weights at trace time: the multiply
        # fuses into each matmul consumer (W8A16), and for an unquantized
        # tree this is an identity traversal — the jaxpr is unchanged, so
        # quant_mode="off" stays byte-identical.
        params = quant.dequantize_params(params)

        def run_prefill(c):
            return self.api.prefill_chunk(params, chunk_tokens, chunk_valid,
                                          chunk_start, chunk_row, c)

        def skip_prefill(c):
            return jnp.zeros((1, vocab), jnp.float32), c

        chunk_logits, cache = jax.lax.cond(do_prefill, run_prefill,
                                           skip_prefill, cache)

        def run_decode(c):
            return self.api.decode_paged(params, cur_token, pos, c,
                                         decode_tables,
                                         attn_impl=self.attn_impl)

        def skip_decode(c):
            return jnp.zeros((self.num_slots, vocab), jnp.float32), c

        dec_logits, cache = jax.lax.cond(do_decode, run_decode,
                                         skip_decode, cache)

        kp, kd = jax.random.split(key)
        ptok, plp = sample_tokens(kp, chunk_logits,
                                  temperature=self.temperature, top_k=self.top_k)
        dtok, dlp = sample_tokens(kd, dec_logits,
                                  temperature=self.temperature, top_k=self.top_k)
        # chunk_logits ride along so group forks can sample per-follower
        # first tokens from the final prefill position.
        return (ptok.astype(jnp.int32), plp, dtok.astype(jnp.int32), dlp,
                chunk_logits, cache)

    # ------------------------------------------------------------ protocol
    @property
    def num_free_slots(self) -> int:
        return self.num_slots - len(self.slots)

    @property
    def num_free_pages(self) -> int:
        return self.pool.pages_free

    @property
    def pages_free(self) -> int:
        return self.pool.pages_free

    @property
    def pages_shared(self) -> int:
        return self.pool.pages_shared

    @property
    def pages_private(self) -> int:
        return self.pool.pages_private

    @property
    def peak_pages_in_use(self) -> int:
        return self.pool.peak_pages_in_use

    @property
    def active_request_ids(self) -> List[int]:
        return list(self.req_to_slot)

    # ------------------------------------------------- prefix-cache counters
    @property
    def cache_lookups(self) -> int:
        return self.prefix_cache.lookups if self.prefix_cache else 0

    @property
    def cache_hits(self) -> int:
        return self.prefix_cache.hits if self.prefix_cache else 0

    @property
    def cache_ext_hits(self) -> int:
        """Productive mid-prefill extensions (concurrent-preamble pickups)."""
        return self.prefix_cache.ext_hits if self.prefix_cache else 0

    @property
    def cache_hit_tokens(self) -> int:
        """Prefill tokens skipped by aliasing cached prefix pages."""
        return self.prefix_cache.hit_tokens if self.prefix_cache else 0

    @property
    def cache_evicted_pages(self) -> int:
        return self.prefix_cache.evicted_pages if self.prefix_cache else 0

    @property
    def cache_pages_held(self) -> int:
        return len(self.prefix_cache.held_pages()) if self.prefix_cache else 0

    def set_quant_mode(self, mode: str) -> None:
        """Change the weight-quantization mode mid-run.  Takes effect at the
        NEXT ``update_weights`` — the current tree is already (lossily)
        quantized, so re-quantizing in place would compound error; the next
        sync ships fresh full-precision weights to quantize."""
        if mode not in quant.MODES:
            raise ValueError(f"unknown quant_mode {mode!r} "
                             f"(expected {' | '.join(quant.MODES)})")
        self.quant_mode = mode

    def update_weights(self, params) -> None:
        self.params = quant.quantize_params(params, self.quant_mode)
        if self.quant_mode != "off":
            self.total_weight_syncs_quantized += 1
        # bump the epoch even with the cache off: slot/retained records
        # stamped with an older epoch must never publish their (now
        # stale-policy) KV if the cache is enabled later.
        self._weight_epoch += 1
        if self.prefix_cache is not None:
            # every cached page was computed under the old policy: new
            # admissions must not alias stale KV.  Running requests keep
            # their own references (existing retain/resume semantics), and
            # the epoch stamp keeps their later release/abort/finish from
            # re-inserting old-policy pages into the flushed tree.
            self.prefix_cache.clear()

    def _pages_needed(self, total_len: int) -> int:
        return -(-total_len // self.page_size)

    def _can_cover(self, n: int) -> bool:
        """Whether ``n`` pages can be produced right now: free pages first,
        cache-evictable holds as the fallback — the cache must never cause
        an admission failure.  The free-page check short-circuits so the
        evictability tree walk only runs under actual page pressure."""
        if n <= self.pool.pages_free:
            return True
        if self.prefix_cache is None:
            return False
        return n <= self.pool.pages_free + self.prefix_cache.evictable_pages

    def _alloc(self, n: int) -> List[int]:
        """Pool alloc that evicts LRU cache leaves when free pages run dry."""
        short = n - self.pool.pages_free
        if short > 0 and self.prefix_cache is not None:
            self.prefix_cache.evict(short)
        return self.pool.alloc(n)

    def can_admit(self, prompt_len: int, max_new_tokens: int) -> bool:
        if self.num_free_slots <= 0:
            return False
        return self._can_cover(self._pages_needed(prompt_len + max_new_tokens))

    def can_cover_pages(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Page-only admission check (ignores slots): whether the pages for
        a full-budget request could be produced right now.  The SLO
        preemption path uses this — preempting frees a SLOT, never pages
        (the victim keeps its KV parked), so it must only fire when pages
        already cover the arrival."""
        return self._can_cover(self._pages_needed(prompt_len + max_new_tokens))

    def num_decoded(self, request_id: int) -> int:
        """Decode progress of an active request (0 if unknown) — the SLO
        watchdog's stall/long-tail signal."""
        slot = self.req_to_slot.get(request_id)
        if slot is None:
            return 0
        return len(self.slots[slot].tokens)

    def _set_table_row(self, slot: int, pages: List[int]) -> None:
        row = np.full((self.pages_per_seq,), -1, np.int32)
        row[:len(pages)] = pages
        self.block_tables = self.block_tables.at[slot].set(jnp.asarray(row))

    def _free_slot_id(self) -> int:
        return next(i for i in range(self.num_slots) if i not in self.slots)

    def add_request(self, request_id: int, prompt_tokens,
                    max_new_tokens: int) -> None:
        assert self.num_free_slots > 0, "no free slot"
        prompt = np.asarray(prompt_tokens, np.int32).ravel()
        plen = len(prompt)
        assert plen + max_new_tokens <= self.max_total_len, "sequence budget"
        slot = self._free_slot_id()
        # automatic prefix caching: alias the longest cached page-aligned
        # prefix into the block table and start chunked prefill at the first
        # uncached token.  The match is capped at plen-1 tokens — the final
        # prompt token must always prefill to produce first-sample logits.
        cached: List[int] = []
        if self.prefix_cache is not None and plen > 1:
            cached = self.prefix_cache.match(prompt[:plen - 1])
        pages = cached + self._alloc(
            self._pages_needed(plen + max_new_tokens) - len(cached))
        self._set_table_row(slot, pages)
        self._slot_pages[slot] = pages
        self.slots[slot] = _SlotState(request_id=request_id, prompt=prompt,
                                      tokens=[], logprobs=[],
                                      remaining=max_new_tokens,
                                      prefill_done=len(cached) * self.page_size,
                                      content_prefix=prompt,
                                      epoch=self._weight_epoch)
        self.req_to_slot[request_id] = slot

    # -------------------------------------------------- group (COW) submit
    def _group_page_plan(self, prompt_len: int,
                         max_new_tokens: int) -> Tuple[int, int]:
        """(shared-prefix pages, private pages per lane) for one group lane."""
        total = self._pages_needed(prompt_len + max_new_tokens)
        full = prompt_len // self.page_size
        return full, total - full

    def can_admit_group(self, prompt_len: int, group_size: int,
                        max_new_tokens: int) -> bool:
        full, priv = self._group_page_plan(prompt_len, max_new_tokens)
        return (self.num_free_slots >= group_size
                and self._can_cover(full + group_size * priv))

    def group_fits_pool(self, prompt_len: int, group_size: int,
                        max_new_tokens: int) -> bool:
        """Whether the group could EVER be admitted as a unit (vs the whole
        pool, not current headroom).  The proxy expands never-fitting groups
        into singles instead of letting them block the queue forever."""
        full, priv = self._group_page_plan(prompt_len, max_new_tokens)
        return (group_size <= self.num_slots
                and full + group_size * priv <= self.num_pages - 1)

    def submit_group(self, request_ids: List[int], prompt_tokens,
                     max_new_tokens: int) -> None:
        """Admit the G candidates of ONE prompt as a COW group.

        The first request becomes the prefill leader (a normal chunked
        prefill over its fully allocated block table); the rest park in
        ``forkwait`` holding only their private pages.  When the leader's
        prefill completes, ``_fork_followers`` aliases the fully-filled
        prompt pages into every follower's table (refcount++), copies the
        partial tail page once per follower, and flips them all to decode —
        the prompt is prefilled exactly once for the whole group."""
        g = len(request_ids)
        assert g >= 1
        prompt = np.asarray(prompt_tokens, np.int32).ravel()
        plen = len(prompt)
        assert plen + max_new_tokens <= self.max_total_len, "sequence budget"
        assert self.num_free_slots >= g, "not enough free slots for group"
        full, priv = self._group_page_plan(plen, max_new_tokens)
        assert self._can_cover(full + g * priv), "page pool exhausted"

        leader = self._free_slot_id()
        # the leader's prefill rides the cross-prompt prefix cache just like
        # a single request (matched pages never reach the tail page, so the
        # COW fork below is untouched).
        cached: List[int] = []
        if self.prefix_cache is not None and plen > 1:
            cached = self.prefix_cache.match(prompt[:plen - 1])
        pages = cached + self._alloc(full + priv - len(cached))
        self._set_table_row(leader, pages)
        self._slot_pages[leader] = pages
        lst = _SlotState(request_id=request_ids[0], prompt=prompt,
                         tokens=[], logprobs=[], remaining=max_new_tokens,
                         prefill_done=len(cached) * self.page_size,
                         content_prefix=prompt, epoch=self._weight_epoch)
        self.slots[leader] = lst
        self.req_to_slot[request_ids[0]] = leader

        for rid in request_ids[1:]:
            slot = self._free_slot_id()
            self._slot_pages[slot] = self._alloc(priv)
            self.slots[slot] = _SlotState(
                request_id=rid, prompt=prompt, tokens=[], logprobs=[],
                remaining=max_new_tokens, phase=_FORKWAIT, group_leader=leader,
                content_prefix=prompt, epoch=self._weight_epoch)
            self.req_to_slot[rid] = slot
            lst.followers.append(slot)

    def _fork_followers(self, leader: int, chunk_logits,
                        first_tok: int, first_lp: float) -> None:
        """The COW fork: leader finished prefilling, so alias the prompt's
        fully-filled pages into every follower and copy only the partial
        tail page (one batched device copy).  Each follower samples its own
        first token from the final prefill logits (greedy reuses the
        leader's — bit-identical by construction)."""
        st = self.slots[leader]
        plen = len(st.prompt)
        srcs: List[int] = []
        dsts: List[int] = []
        for fslot in st.followers:
            fst = self.slots[fslot]
            shared, tail_src = self.pool.fork_prefix(
                self._slot_pages[leader], plen)
            priv = self._slot_pages[fslot]
            if tail_src is not None:
                srcs.append(tail_src)
                dsts.append(priv[0])
            pages = shared + priv
            self._slot_pages[fslot] = pages
            self._set_table_row(fslot, pages)
            if self.temperature <= 0.0:
                t0, l0 = first_tok, first_lp
            else:
                self._key, sub = jax.random.split(self._key)
                ftok, flp = sample_tokens(sub, chunk_logits,
                                          temperature=self.temperature,
                                          top_k=self.top_k)
                t0, l0 = int(ftok[0]), float(flp[0])
            fst.phase = _DECODE
            fst.group_leader = None
            fst.tokens.append(t0)
            fst.logprobs.append(l0)
            fst.remaining -= 1
            fst.prefill_done = plen
            self.cur_token = self.cur_token.at[fslot].set(t0)
            self.pos = self.pos.at[fslot].set(plen)
        st.followers = []
        self.total_groups_forked += 1
        if srcs:
            self.cache = self._copy_pages(self.cache, jnp.asarray(srcs),
                                          jnp.asarray(dsts))
            self.total_copy_ops += 1
            self.total_pages_copied += len(srcs)

    def _promote_follower(self, st: _SlotState, leader_pages: List[int]) -> None:
        """The group's prefill leader was aborted before the fork: hand its
        full page allocation (prefilled content intact) to the first waiting
        follower, which becomes the new leader and continues the chunked
        prefill where the old one stopped — no prompt work is repeated."""
        new_leader = st.followers[0]
        nst = self.slots[new_leader]
        self.pool.release(self._slot_pages[new_leader])
        self._slot_pages[new_leader] = leader_pages
        self._set_table_row(new_leader, leader_pages)
        nst.phase = _PREFILL
        nst.group_leader = None
        nst.prefill_done = st.prefill_done
        nst.followers = st.followers[1:]
        for f in nst.followers:
            self.slots[f].group_leader = new_leader

    # ------------------------------------------ content-addressed release
    def _written_content(self, st: _SlotState, slot: int):
        """(token content, written length) of the slot's written KV region.

        Decode phase: ``content_prefix`` + sampled tokens, of which the
        final sampled token's KV is not yet written (written == pos).
        Prefill phase: the prompt up to ``prefill_done``."""
        if st.phase == _DECODE:
            content = np.concatenate(
                [st.content_prefix, np.asarray(st.tokens, np.int32)])
            return content, int(self.pos[slot])
        if st.phase == _PREFILL:
            return st.content_prefix, st.prefill_done
        return st.content_prefix, 0          # forkwait: nothing written yet

    def _release_pages(self, pages: List[int], content, written: int,
                       epoch: int) -> None:
        """Release a request's pages — but first index every fully-written
        page in the prefix cache (the cache takes its own reference, so the
        KV survives this release for future cross-prompt hits).  Pages whose
        KV predates the current weight epoch are NOT published: a
        post-weight-sync abort must not repopulate the flushed cache with
        old-policy KV."""
        if (self.prefix_cache is not None and written >= self.page_size
                and epoch == self._weight_epoch):
            full = written // self.page_size
            self.prefix_cache.insert(content[:full * self.page_size],
                                     pages[:full])
        self.pool.release(pages)

    def peek_tokens(self, request_id: int, start: int = 0) -> List[int]:
        """Decoded tokens[start:] of an active request (streaming hook)."""
        slot = self.req_to_slot.get(request_id)
        if slot is None:
            return []
        return list(self.slots[slot].tokens[start:])

    # --------------------------------------------------- retain / resume
    def abort(self, request_id: int, *, retain: bool = False) -> GenerationResult:
        slot = self.req_to_slot.pop(request_id)
        st = self.slots.pop(slot)
        pages = self._slot_pages.pop(slot)
        self.block_tables = self.block_tables.at[slot].set(-1)
        if st.phase == _FORKWAIT:
            # pre-fork follower: it has no KV yet — nothing to retain.
            leader = self.slots.get(st.group_leader)
            if leader is not None and slot in leader.followers:
                leader.followers.remove(slot)
            self.pool.release(pages)
            retain = False
        elif st.followers:
            # pre-fork group leader: its pages must keep serving the group
            # (the promoted follower continues the prefill in-place), so
            # there is nothing left to park — degrade retain to a plain
            # abort.  Zero tokens have been decoded at this point, so the
            # caller loses only partial prompt prefill.
            self._promote_follower(st, pages)
            retain = False
        elif retain:
            content, length = self._written_content(st, slot)
            self.retained[request_id] = _Retained(
                pages=pages, phase=st.phase, prompt=st.prompt,
                prefill_done=st.prefill_done,
                length=length if st.phase == _DECODE else 0,
                last_token=int(self.cur_token[slot]), content=content,
                epoch=st.epoch)
        else:
            content, written = self._written_content(st, slot)
            self._release_pages(pages, content, written, st.epoch)
        return GenerationResult(
            request_id=request_id, task=None,
            tokens=np.asarray(st.tokens, np.int32),
            logprobs=np.asarray(st.logprobs, np.float32),
            version_started=-1, aborted=True, partial=True, resumable=retain)

    def _resume_pages_needed(self, ret: _Retained, max_new_tokens: int) -> int:
        base = ret.length if ret.phase == _DECODE else len(ret.prompt)
        return self._pages_needed(base + max_new_tokens)

    def can_resume(self, request_id: int, max_new_tokens: int) -> bool:
        ret = self.retained.get(request_id)
        if ret is None or self.num_free_slots == 0:
            return False
        extra = self._resume_pages_needed(ret, max_new_tokens) - len(ret.pages)
        return extra <= 0 or self._can_cover(extra)

    def resume_request(self, request_id: int, new_request_id: int,
                       max_new_tokens: int) -> None:
        """Re-attach a retained request: its pages (the whole decoded prefix's
        KV) come back verbatim — zero prefix recomputation.  A budget larger
        than the original allocation tops the table up from the free pool
        (both phases: a prefill-phase resume still needs decode headroom).
        A forked lane's shared prefix pages re-attach through the refcounts
        its retained record kept holding — siblings finishing or aborting in
        the meantime never invalidates them."""
        ret = self.retained.pop(request_id)
        assert self.num_free_slots > 0, "no free slot"
        base = ret.length if ret.phase == _DECODE else len(ret.prompt)
        assert base + max_new_tokens <= self.max_total_len, "sequence budget"
        slot = self._free_slot_id()
        pages = ret.pages
        need = self._resume_pages_needed(ret, max_new_tokens)
        if need > len(pages):
            pages = pages + self._alloc(need - len(pages))
        self._set_table_row(slot, pages)
        self._slot_pages[slot] = pages
        st = _SlotState(request_id=new_request_id, prompt=ret.prompt,
                        tokens=[], logprobs=[], remaining=max_new_tokens,
                        phase=ret.phase, prefill_done=ret.prefill_done,
                        carried_last=(ret.last_token if ret.phase == _DECODE
                                      else None),
                        content_prefix=(ret.content if ret.content is not None
                                        else ret.prompt),
                        epoch=ret.epoch)
        self.slots[slot] = st
        self.req_to_slot[new_request_id] = slot
        if ret.phase == _DECODE:
            self.cur_token = self.cur_token.at[slot].set(ret.last_token)
            self.pos = self.pos.at[slot].set(ret.length)

    def release_retained(self, request_id: int) -> None:
        ret = self.retained.pop(request_id, None)
        if ret is not None:
            written = ret.length if ret.phase == _DECODE else ret.prefill_done
            content = ret.content if ret.content is not None else ret.prompt
            self._release_pages(ret.pages, content, written, ret.epoch)

    # ------------------------------------------- cross-replica page transfer
    def export_retained(self, request_id: int) -> Optional[dict]:
        """Extract a retained request's pages into a host-side record another
        replica can ``import_retained``.  One batched gather + one device_get
        — no per-page dispatch.  The local record is NOT released: the caller
        releases it only after the import landed, so a failed transfer leaves
        in-place resume intact."""
        ret = self.retained.get(request_id)
        if ret is None:
            return None
        t = paged.export_pages(self.cache, ret.pages)
        self.pages_transferred_out += t.num_pages
        self.transfer_bytes_out += t.nbytes
        self.transfer_device_ops += 1
        return {
            "transfer": t, "phase": ret.phase, "prompt": ret.prompt,
            "prefill_done": ret.prefill_done, "length": ret.length,
            "last_token": ret.last_token, "content": ret.content,
            "epoch": ret.epoch, "home_epoch": self._weight_epoch,
            "kv_quant": self.kv_quant,
        }

    def import_retained(self, request_id: int, record: dict) -> bool:
        """Re-admit an exported retained record into THIS replica's pool via
        one batched scatter, recreating the ``retained`` entry so the normal
        ``can_resume``/``resume_request`` path picks it up — the migrated
        request resumes with zero re-prefill.  Returns False (and imports
        nothing) when the record can't land here: quant-mode mismatch, rid
        collision, or the pool can't cover the pages."""
        t: paged.PageTransfer = record["transfer"]
        if (record.get("kv_quant", "off") != self.kv_quant
                or request_id in self.retained
                or not self._can_cover(t.num_pages)):
            return False
        pages = self._alloc(t.num_pages)
        self.cache = self._import_pages(
            self.cache, jnp.asarray(pages, jnp.int32), t)
        self.pages_transferred_in += t.num_pages
        self.transfer_bytes_in += t.nbytes
        self.transfer_device_ops += 1
        # Epoch translation: the KV is current-policy only if it was current
        # at home AND home and here sit at the same weight epoch.  A stale
        # stamp (never equal to a future epoch) keeps old-policy KV out of
        # the prefix cache on release — it never affects decode itself, so
        # greedy byte-identity is preserved either way.
        current = (record["epoch"] == record["home_epoch"]
                   and record["home_epoch"] == self._weight_epoch)
        self.retained[request_id] = _Retained(
            pages=pages, phase=record["phase"], prompt=record["prompt"],
            prefill_done=record["prefill_done"], length=record["length"],
            last_token=record["last_token"], content=record["content"],
            epoch=self._weight_epoch if current else self._weight_epoch - 1)
        return True

    def export_prefix(self, tokens) -> Optional[dict]:
        """Extract this replica's cached prefix pages for ``tokens`` into a
        host-side record (for a router-directed pull to another replica).
        Like admission, the match is capped at ``len(tokens) - 1`` — the
        final prompt token always prefills to produce first logits."""
        if self.prefix_cache is None or len(tokens) < 2:
            return None
        tokens = np.asarray(tokens, np.int32).ravel()
        path = self.prefix_cache._walk(tokens[:len(tokens) - 1])
        if not path:
            return None
        pages = [n.page for n in path]
        t = paged.export_pages(self.cache, pages)
        self.pages_transferred_out += t.num_pages
        self.transfer_bytes_out += t.nbytes
        self.transfer_device_ops += 1
        covered = tokens[:len(pages) * self.page_size].copy()
        return {"transfer": t, "tokens": covered,
                "home_epoch": self._weight_epoch, "kv_quant": self.kv_quant}

    def import_prefix(self, record: dict) -> int:
        """Admit a pulled prefix record into this replica's radix cache so an
        incoming request prefills only its uncached tail.  Conservative by
        design: a pull never evicts (plain free-page check), never imports
        cross-epoch KV, and dedups against pages already cached here.
        Returns the number of pages imported (0 = skipped, perf-only)."""
        if (self.prefix_cache is None
                or record.get("kv_quant", "off") != self.kv_quant
                or record["home_epoch"] != self._weight_epoch):
            return 0
        t: paged.PageTransfer = record["transfer"]
        tokens = record["tokens"]
        have_nodes = self.prefix_cache._walk(tokens)
        have = len(have_nodes)
        if have >= t.num_pages:
            return 0
        need = t.num_pages - have
        if need > self.pool.pages_free:
            return 0
        sub = paged.PageTransfer(
            k=t.k[:, have:], v=t.v[:, have:],
            k_scales=None if t.k_scales is None else t.k_scales[:, have:],
            v_scales=None if t.v_scales is None else t.v_scales[:, have:])
        pages = self._alloc(need)
        self.cache = self._import_pages(
            self.cache, jnp.asarray(pages, jnp.int32), sub)
        self.pages_transferred_in += need
        self.transfer_bytes_in += sub.nbytes
        self.transfer_device_ops += 1
        # insert() takes the cache's own ref on each new page: the shared
        # prefix [0, have) dedups onto existing nodes and only the tail
        # binds the freshly imported pages.
        full = [n.page for n in have_nodes] + pages
        self.prefix_cache.insert(tokens, full)
        self.pool.release(pages)
        return need

    # ------------------------------------------------------------ auditing
    def audit_pages(self) -> None:
        """Assert the refcount invariant: every page's refcount equals its
        number of appearances across live block tables, retained records and
        prefix-cache holds, and a page is free exactly when its refcount is
        zero."""
        expect = np.zeros((self.num_pages,), np.int64)
        for pages in self._slot_pages.values():
            for p in pages:
                expect[p] += 1
        for ret in self.retained.values():
            for p in ret.pages:
                expect[p] += 1
        if self.prefix_cache is not None:
            for p in self.prefix_cache.held_pages():
                expect[p] += 1
        actual = np.asarray([self.pool.refcount(p)
                             for p in range(self.num_pages)], np.int64)
        assert (expect == actual).all(), \
            f"refcount leak: expected {expect.tolist()} got {actual.tolist()}"
        free = set(self.pool._free)
        assert paged.GARBAGE_PAGE not in free
        for p in range(1, self.num_pages):
            assert (p in free) == (actual[p] == 0), \
                f"page {p}: refcount {actual[p]} vs free={p in free}"

    # --------------------------------------------------------------- step
    def step(self) -> List[Tuple[int, np.ndarray, np.ndarray]]:
        """One fused engine step; returns finished (rid, tokens, logprobs)."""
        if not self.slots:
            return []
        finished: List[Tuple[int, np.ndarray, np.ndarray]] = []
        # finish BEFORE stepping: the last sampled (or carried) token may
        # already terminate the request.
        for slot in list(self.slots):
            st = self.slots[slot]
            if st.phase != _DECODE:
                continue
            last = st.tokens[-1] if st.tokens else st.carried_last
            if last is not None and (last == self.eos_id or st.remaining <= 0):
                finished.append(self._finish(slot))
        if not self.slots:
            return finished

        prefill_slots = [s for s, st in sorted(self.slots.items())
                         if st.phase == _PREFILL]
        decode_slots = [s for s, st in self.slots.items()
                        if st.phase == _DECODE]

        c = self.prefill_chunk
        chunk_slot = None
        n_chunk = 0
        toks = np.full((1, c), self.pad_id, np.int32)
        valid = np.zeros((1, c), bool)
        start = 0
        row = jnp.full((self.pages_per_seq,), -1, jnp.int32)
        if prefill_slots:
            chunk_slot = prefill_slots[self._rr % len(prefill_slots)]
            self._rr += 1
            st = self.slots[chunk_slot]
            if self.prefix_cache is not None:
                self._extend_cached_prefix(chunk_slot, st)
            start = st.prefill_done
            chunk = st.prompt[start:start + c]
            n_chunk = len(chunk)
            toks[0, :n_chunk] = chunk
            valid[0, :n_chunk] = True
            row = self.block_tables[chunk_slot]

        decode_mask = np.zeros((self.num_slots,), bool)
        decode_mask[decode_slots] = True
        mask_j = jnp.asarray(decode_mask)
        masked_tables = jnp.where(mask_j[:, None], self.block_tables, -1)

        self._key, sub = jax.random.split(self._key)
        ptok, plp, dtok, dlp, chunk_logits, self.cache = self._step(
            self.params, self.cache, self.cur_token, self.pos, masked_tables,
            jnp.asarray(toks), jnp.asarray(valid),
            jnp.asarray(start, jnp.int32), row,
            np.bool_(chunk_slot is not None), np.bool_(bool(decode_slots)),
            sub)

        if chunk_slot is not None:
            st = self.slots[chunk_slot]
            st.prefill_done += n_chunk
            self.total_prefill_chunks += 1
            self.total_prefill_tokens += n_chunk
            if (self.prefix_cache is not None
                    and st.epoch == self._weight_epoch):
                # publish freshly completed prompt pages immediately so
                # CONCURRENT same-prefix requests pick them up mid-prefill
                # (lazy extension above) — the shared preamble of a batch
                # prefills exactly once even when everything is admitted
                # together.
                full = st.prefill_done // self.page_size
                if full:
                    self.prefix_cache.insert(
                        st.prompt[:full * self.page_size],
                        self._slot_pages[chunk_slot][:full])
            if st.prefill_done >= len(st.prompt):
                t0, l0 = int(ptok[0]), float(plp[0])
                st.phase = _DECODE
                st.tokens.append(t0)
                st.logprobs.append(l0)
                st.remaining -= 1
                self.cur_token = self.cur_token.at[chunk_slot].set(t0)
                self.pos = self.pos.at[chunk_slot].set(len(st.prompt))
                if st.followers:
                    self._fork_followers(chunk_slot, chunk_logits, t0, l0)

        if decode_slots:
            self.total_decode_steps += 1
            tok_np, lp_np = np.asarray(dtok), np.asarray(dlp)
            self.cur_token = jnp.where(mask_j, dtok, self.cur_token)
            self.pos = jnp.where(mask_j, self.pos + 1, self.pos)
            for s in decode_slots:
                st = self.slots[s]
                st.tokens.append(int(tok_np[s]))
                st.logprobs.append(float(lp_np[s]))
                st.remaining -= 1
                self.total_tokens_decoded += 1
        return finished

    def _extend_cached_prefix(self, slot: int, st: _SlotState) -> None:
        """Mid-prefill cache extension: when a prefilling slot sits at a page
        boundary and the cache meanwhile learned a longer prefix of its
        prompt (e.g. a concurrent request prefilled the shared preamble
        first), swap the slot's unwritten pages for the cached ones and jump
        ``prefill_done`` forward.  The swapped-out pages were never written,
        so this is pure block-table/refcount bookkeeping."""
        if st.prefill_done % self.page_size:
            return                       # mid-page: cannot swap whole pages
        plen = len(st.prompt)
        j = st.prefill_done // self.page_size
        ext = self.prefix_cache.match(st.prompt[:plen - 1], from_page=j,
                                      extend=True)
        if not ext:
            return
        pages = self._slot_pages[slot]
        k = j + len(ext)
        swapped_out = pages[j:k]
        pages[j:k] = ext
        self.pool.release(swapped_out)
        self._set_table_row(slot, pages)
        st.prefill_done = k * self.page_size

    def _finish(self, slot: int) -> Tuple[int, np.ndarray, np.ndarray]:
        st = self.slots.pop(slot)
        self.req_to_slot.pop(st.request_id, None)
        content, written = self._written_content(st, slot)
        self._release_pages(self._slot_pages.pop(slot), content, written,
                            st.epoch)
        self.block_tables = self.block_tables.at[slot].set(-1)
        return (st.request_id, np.asarray(st.tokens, np.int32),
                np.asarray(st.logprobs, np.float32))
