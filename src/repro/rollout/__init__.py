from repro.rollout.engine import DecodeEngine  # noqa: F401
from repro.rollout.paged_engine import PagedDecodeEngine  # noqa: F401
from repro.rollout.sampler import sample_tokens  # noqa: F401
