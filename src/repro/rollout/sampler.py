"""Token sampling.

Paper appendix A.1: rollout uses temperature=1, top_p=1 so the engine emits
the *raw* token distribution — the recorded logprobs are the true behaviour
policy, required by every IS-based off-policy corrector.  Temperature/top-k
are still supported for evaluation-time decoding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(key, logits, *, temperature: float = 1.0, top_k: int = 0,
                  top_p: float = 1.0):
    """logits: (B, V) fp32. Returns (tokens (B,), logprobs (B,)).

    logprobs are of the *untempered* distribution when temperature == 1.0
    and top_p == 1.0 (the paper's raw-logits requirement); otherwise of the
    sampling distribution actually used.
    """
    if temperature <= 0.0:  # greedy
        tokens = jnp.argmax(logits, axis=-1)
        lp = jax.nn.log_softmax(logits, axis=-1)
        return tokens, jnp.take_along_axis(lp, tokens[:, None], axis=-1)[:, 0]

    scaled = logits / temperature
    if top_k and top_k < logits.shape[-1]:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    if top_p < 1.0:
        # nucleus: mask tokens outside the smallest set with cum prob >= p
        sorted_logits = jnp.sort(scaled, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep everything strictly before the cutoff plus the cutoff token
        cutoff_idx = jnp.argmax(cum >= top_p, axis=-1)
        cutoff_logit = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        scaled = jnp.where(scaled < cutoff_logit, -jnp.inf, scaled)
    tokens = jax.random.categorical(key, scaled, axis=-1)
    lp = jax.nn.log_softmax(scaled, axis=-1)
    return tokens, jnp.take_along_axis(lp, tokens[:, None], axis=-1)[:, 0]
