"""Deterministic discrete-event simulator of the ROLL Flash pipeline.

Used by the benchmark suite to reproduce the paper's timing figures
(Fig 1b, 3a, 3b, 7, 8, 9, 10, Table 1) and by property tests to validate
Propositions 1 & 2.  This container has one CPU core, so wall-clock
concurrency measurements are meaningless; the simulator gives seeded,
reproducible timing under the paper's own cost model:

* a generation *worker* is a decode slot (GPUs x slots_per_gpu);
* a sequence occupies one slot for (length x per-token time);
* without prompt replication, a group of G candidates is one request that
  occupies G co-located slots until its *longest* member finishes
  (the paper's "single worker synchronously decodes all n responses");
* training takes B x mu_train / train_gpus + fixed overhead;
* async mode runs disjoint pools with the SampleBuffer freshness gate
  (occupancy <= (1+alpha) x B) and ABORT-continue on version advance.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, List, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Prop-1-level primitives: scheduling a fixed set of durations on K workers
# ---------------------------------------------------------------------------

def simulate_queue_completion(durations: Sequence[float], k: int) -> float:
    """Queue scheduling: task -> earliest-free worker (greedy list schedule)."""
    if not len(durations):
        return 0.0
    free = [0.0] * min(k, len(durations))
    heapq.heapify(free)
    end = 0.0
    for d in durations:
        t0 = heapq.heappop(free)
        t1 = t0 + d
        end = max(end, t1)
        heapq.heappush(free, t1)
    return end


def simulate_static_completion(durations: Sequence[float], k: int) -> float:
    """Batch rollout: round-robin pre-partition, no work stealing."""
    loads = [0.0] * k
    for i, d in enumerate(durations):
        loads[i % k] += d
    return max(loads)


def simulate_group_queue_completion(group_durations: Sequence[Sequence[float]],
                                    k: int) -> float:
    """Queue scheduling WITHOUT prompt replication: each group occupies
    len(group) co-located slots until its longest member completes."""
    free = [0.0] * k
    heapq.heapify(free)
    end = 0.0
    for group in group_durations:
        g = len(group)
        # claim the g earliest-free slots (must be co-located / simultaneous)
        claimed = [heapq.heappop(free) for _ in range(min(g, k))]
        start = max(claimed)
        finish = start + max(group)
        end = max(end, finish)
        for _ in claimed:
            heapq.heappush(free, finish)
    return end


# ---------------------------------------------------------------------------
# Fig 7: queue scheduling + dynamic filtering + redundant prompts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FilteringResult:
    gen_time: float
    groups_generated: int
    groups_kept: int


def simulate_filtered_rollout(
    rng: np.random.Generator,
    *,
    batch_groups: int,            # qualifying groups needed per step
    group_size: int,
    k_slots: int,
    length_sampler: Callable[[np.random.Generator, int], np.ndarray],
    per_token_time: float,
    p_filter: float,              # P(group filtered out: zero reward variance)
    mode: str,                    # "batch" | "queue"
    extra_prompts: int = 0,       # max_additional_running_prompts
) -> FilteringResult:
    """One rollout step under dynamic filtering.

    batch mode: full-batch rounds; rewards/filters only after the whole batch
    completes; insufficient -> another full round.
    queue mode: groups stream; each completion is immediately rewarded and
    filtered; generation stops the moment batch_groups qualify.
    """
    if mode == "batch":
        t, produced, kept = 0.0, 0, 0
        while kept < batch_groups:
            n = batch_groups
            durs = [length_sampler(rng, group_size) * per_token_time for _ in range(n)]
            flat = [d for g in durs for d in g]
            t += simulate_queue_completion(flat, k_slots)
            produced += n
            kept += int(np.sum(rng.random(n) >= p_filter))
        return FilteringResult(t, produced, kept)

    # queue mode: pre-launch batch_groups + extra_prompts groups, stream
    # completions in group-finish order, top up on filtered groups, and stop
    # the moment batch_groups qualify (remaining generations are ABORTed).
    launched = 0
    target_launch = batch_groups + extra_prompts
    free = [0.0] * k_slots
    heapq.heapify(free)
    groups: List[List[float]] = []
    kept_flags: List[bool] = []

    def launch_group():
        nonlocal launched
        lens = length_sampler(rng, group_size) * per_token_time
        ends = []
        for d in lens:
            t0 = heapq.heappop(free)
            t1 = t0 + float(d)
            ends.append(t1)
            heapq.heappush(free, t1)
        groups.append(ends)
        kept_flags.append(bool(rng.random() >= p_filter))
        launched += 1

    for _ in range(target_launch):
        launch_group()

    # stream completions in group-finish order; top-up on filtered groups
    kept, t_done, produced = 0, 0.0, 0
    order = sorted(range(len(groups)), key=lambda i: max(groups[i]))
    i = 0
    while kept < batch_groups:
        if i >= len(order):
            launch_group()
            order = sorted(range(len(groups)), key=lambda i2: max(groups[i2]))
        gi = order[i]
        i += 1
        produced += 1
        if kept_flags[gi]:
            kept += 1
            t_done = max(groups[gi])  # time the batch_groups-th keeper lands
    return FilteringResult(t_done, produced, kept)


# ---------------------------------------------------------------------------
# End-to-end pipeline: sync-naive / sync-queue / async
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineConfig:
    rollout_batch_size: int            # N samples consumed per train step
    group_size: int = 1
    gpus: int = 32
    train_gpus: Optional[int] = None   # async split; sync uses all for both
    infer_gpus: Optional[int] = None
    slots_per_gpu: int = 16
    per_token_time: float = 0.01       # s per decoded token per sequence
    mu_train_per_sample: float = 0.05  # s per sample on ONE gpu (scales /gpus)
    train_overhead: float = 5.0        # model load/offload etc. per step
    weight_sync_time: float = 1.0      # suspend+broadcast+resume
    alpha: float = 1.0
    mode: str = "async"                # sync_naive | sync_queue | async
    prompt_replication: bool = True
    ppo_epochs: float = 1.0


@dataclasses.dataclass
class PipelineResult:
    step_times: List[float]
    makespan: float
    gen_utilization: float             # busy slot-time / total slot-time
    staleness: List[int]               # per consumed sample: version gap
    throughput: float                  # samples / s

    @property
    def mean_step_time(self) -> float:
        return float(np.mean(self.step_times))


def _train_time(cfg: PipelineConfig, train_gpus: int) -> float:
    return (cfg.rollout_batch_size * cfg.ppo_epochs * cfg.mu_train_per_sample
            / max(train_gpus, 1) + cfg.train_overhead)


def simulate_pipeline(rng: np.random.Generator, cfg: PipelineConfig,
                      num_steps: int,
                      length_sampler: Callable[[np.random.Generator, int], np.ndarray],
                      ) -> PipelineResult:
    """Simulate num_steps of RL post-training end-to-end."""
    n = cfg.rollout_batch_size
    if cfg.mode in ("sync_naive", "sync_queue"):
        k = cfg.gpus * cfg.slots_per_gpu
        t = 0.0
        step_times, busy = [], 0.0
        train_t = _train_time(cfg, cfg.gpus)
        for _ in range(num_steps):
            lens = length_sampler(rng, n) * cfg.per_token_time
            busy += float(np.sum(lens))
            if cfg.mode == "sync_naive":
                # batch rollout, groups co-located (no replication)
                g = cfg.group_size
                groups = [lens[i:i + g] for i in range(0, n, g)] if g > 1 else None
                gen = (simulate_group_queue_completion(groups, k) if g > 1
                       else simulate_static_completion(lens, k))
            else:
                gen = simulate_queue_completion(lens, k)
            step = gen + train_t + cfg.weight_sync_time
            step_times.append(step)
            t += step
        util = busy / (k * t) if t else 0.0
        return PipelineResult(step_times, t, util,
                              staleness=[0] * (n * num_steps),
                              throughput=n * num_steps / t)

    # ---------------- async: event-driven producer/consumer -----------------
    assert cfg.train_gpus and cfg.infer_gpus, "async needs an explicit split"
    k = cfg.infer_gpus * cfg.slots_per_gpu
    capacity = int((1 + cfg.alpha) * n)
    train_t = _train_time(cfg, cfg.train_gpus)

    # state
    slot_free = [0.0] * k                  # next-free time per slot (heap)
    heapq.heapify(slot_free)
    completions: List[tuple[float, int]] = []  # (finish_time, version_started)
    buffer: List[tuple[float, int]] = []   # completed (finish_time, v_started)
    inflight = 0
    initiated = 0
    version = 0
    t = 0.0
    busy = 0.0
    step_times: List[float] = []
    staleness: List[int] = []

    def can_start() -> bool:
        # per-sample freshness gate (matches SampleBuffer._admissible):
        # the i-th initiated sample is consumed at version floor(i/N)
        return initiated < (version + cfg.alpha + 1) * n

    def start_one(now: float):
        nonlocal inflight, busy, initiated
        dur = float(length_sampler(rng, 1)[0]) * cfg.per_token_time
        t0 = max(heapq.heappop(slot_free), now)
        t1 = t0 + dur
        heapq.heappush(slot_free, t1)
        heapq.heappush(completions, (t1, version))
        inflight += 1
        initiated += 1
        busy += dur

    # fill the pipeline
    while can_start():
        start_one(0.0)

    for _ in range(num_steps):
        step_start = t
        # wait for n completed samples
        while len(buffer) < n:
            if not completions:
                raise RuntimeError("starved: no in-flight generation")
            ft, v = heapq.heappop(completions)
            t = max(t, ft)
            inflight -= 1
            buffer.append((ft, v))
            while can_start():
                start_one(t)
        # consume oldest-version-first
        buffer.sort(key=lambda x: x[1])
        batch, buffer[:] = buffer[:n], buffer[n:]
        # train + weight sync
        t += train_t + cfg.weight_sync_time
        version += 1
        staleness.extend(version - 1 - v for _, v in batch)
        # ABORT-continue: re-tag in-flight work older than alpha behind;
        # recomputation continues under the new policy (no time penalty,
        # freshness restored) — matches LLMProxy ABORT->reclaim semantics.
        floor_v = version - int(math.floor(cfg.alpha))
        retag = [(ft, max(v, floor_v)) for ft, v in completions]
        completions[:] = retag
        heapq.heapify(completions)
        while can_start():
            start_one(t)
        step_times.append(t - step_start)

    # busy counts launched work; clamp for the in-flight tail at makespan
    util = min(1.0, busy / (k * t)) if t else 0.0
    return PipelineResult(step_times, t, util, staleness,
                          throughput=n * num_steps / t)


# ---------------------------------------------------------------------------
# Agentic: env-level async + redundant environment rollout (Fig 9, 10, 11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AgenticConfig:
    rollout_batch_size: int           # trajectories needed per step
    num_env_groups: int
    group_size: int
    k_slots: int
    turns: int = 5
    gen_time_sampler: Optional[Callable] = None   # (rng)->seconds per turn
    env_latency_mu: float = 10.0
    env_latency_sigma: float = 5.0
    env_async: bool = True            # release slot during env interaction
    p_fail_stop: float = 0.0          # trajectory never completes
    fail_slow_factor: float = 1.0     # latency multiplier for fail-slow envs
    p_fail_slow: float = 0.0


def simulate_agentic_step(rng: np.random.Generator, cfg: AgenticConfig) -> float:
    """One rollout step: collect rollout_batch_size trajectories from
    num_env_groups x group_size concurrent envs (redundant if product >
    batch).  Returns step completion time."""
    total = cfg.num_env_groups * cfg.group_size
    need = cfg.rollout_batch_size

    def gen_time():
        if cfg.gen_time_sampler is not None:
            return float(cfg.gen_time_sampler(rng))
        return float(rng.lognormal(mean=1.0, sigma=0.6))

    def env_latency():
        lat = max(0.05, rng.normal(cfg.env_latency_mu, cfg.env_latency_sigma))
        if cfg.p_fail_slow and rng.random() < cfg.p_fail_slow:
            lat *= cfg.fail_slow_factor
        return float(lat)

    # trajectory state machines scheduled over k generation slots
    slot_free = [0.0] * cfg.k_slots
    heapq.heapify(slot_free)
    finish_times: List[float] = []

    if not cfg.env_async:
        # batch-synchronized rollout: every turn is a barrier — generation for
        # all live trajectories runs as one batch through the slots, then the
        # whole batch waits for the SLOWEST environment interaction before the
        # next turn may start.  (This is the paper's baseline; the speedup of
        # env-level async therefore grows with latency VARIANCE, Fig 9.)
        alive = []
        for _i in range(total):
            hung = bool(cfg.p_fail_stop and rng.random() < cfg.p_fail_stop)
            alive.append(not hung)
        n_alive = sum(alive)
        if n_alive < need:
            raise RuntimeError("too many fail-stop envs to collect the batch")
        t = 0.0
        for turn in range(cfg.turns):
            gens = [gen_time() for _ in range(n_alive)]
            t += simulate_queue_completion(gens, cfg.k_slots)
            lats = sorted(env_latency() for _ in range(n_alive))
            if turn < cfg.turns - 1:
                # barrier on the slowest env still needed: with redundant
                # envs (n_alive > need) the batch can abandon the stragglers
                # beyond the need-th fastest.
                t += lats[min(need, n_alive) - 1]
        return t

    # env-level async: event-driven; during env latency the slot is free
    events: List[tuple[float, int, int]] = []  # (ready_time, traj_id, turn)
    for i in range(total):
        if cfg.p_fail_stop and rng.random() < cfg.p_fail_stop:
            continue  # never produces
        heapq.heappush(events, (0.0, i, 0))
    done: List[float] = []
    while events and len(done) < need:
        ready, traj, turn = heapq.heappop(events)
        t0 = max(heapq.heappop(slot_free), ready)
        t1 = t0 + gen_time()
        heapq.heappush(slot_free, t1)
        if turn + 1 >= cfg.turns:
            done.append(t1)
        else:
            heapq.heappush(events, (t1 + env_latency(), traj, turn + 1))
    if len(done) < need:
        raise RuntimeError("too many fail-stop envs to collect the batch")
    done.sort()
    return done[need - 1]


# ---------------------------------------------------------------------------
# length distributions (calibrated to the paper's setup)
# ---------------------------------------------------------------------------

def lognormal_lengths(mean_tokens: float, sigma: float = 1.0,
                      max_tokens: int = 32_768):
    """Long-tail response lengths: lognormal clipped at max context.

    Paper: Qwen3-8B-Base ~2k mean, Think ~11k mean, 32k max; tails exceed
    the median by >20x."""
    mu = math.log(mean_tokens) - sigma ** 2 / 2.0

    def sample(rng: np.random.Generator, n: int) -> np.ndarray:
        return np.minimum(rng.lognormal(mu, sigma, size=n), max_tokens)

    return sample


def gaussian_latency(mu: float, sigma: float):
    def sample(rng: np.random.Generator) -> float:
        return max(0.05, float(rng.normal(mu, sigma)))

    return sample
