"""ROLL Flash core: the paper's contribution.

Fine-grained parallelism (LLMProxy, queue scheduling, prompt replication,
EnvManager pools, redundant env rollout) + rollout-train decoupling
(SampleBuffer with per-sample asynchronous-ratio freshness, AsyncController
3-phase weight sync), plus the theoretical model (Propositions 1 & 2) and
the discrete-event simulator behind the paper-figure benchmarks.
"""
from repro.core.sample_buffer import SampleBuffer, StaleSampleError  # noqa: F401
from repro.core.llm_proxy import LLMProxy, InferenceEngine  # noqa: F401
from repro.core.rollout_client import (  # noqa: F401
    GenerationHandle, GroupHandle, RolloutClient, Session)
from repro.core.router import MultiEvent, ProxyRouter  # noqa: F401
from repro.core.async_controller import AsyncController, StepStats  # noqa: F401
from repro.core.types import (  # noqa: F401
    GenerationRequest, GenerationResult, RolloutTask, Sample, Trajectory, Turn)
from repro.core import simulator, theory  # noqa: F401
