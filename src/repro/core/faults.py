"""Fault injection for the rollout fleet: crashed replicas as data.

At fleet scale, replica death is a *scheduling event*, not an error
(Laminar's failure-isolated rollout workers; AsyncFlow's stall-tolerant
decoupled stages).  This module provides the machinery the elastic
``ProxyRouter`` is tested and benchmarked against:

* ``FaultyProxy`` — a transparent wrapper speaking the exact ``LLMProxy``
  protocol that can be ``kill()``-ed at any moment.  A killed replica
  behaves like a crashed process: its loop stops mid-flight, every
  callback it would have fired is suppressed (results die with the
  process — delivering them post-mortem would hide real failure modes),
  command submissions raise ``ReplicaDeadError``, and a snapshot of the
  decode progress lost in flight is kept for the router's ``lost_tokens``
  accounting.
* ``FaultInjector`` — seeded chaos: a background thread that fires random
  faults at live replicas while a workload runs (the CI ``faults`` tier),
  bounded by ``max_kills``/``min_alive`` so sweeps terminate.  Beyond
  crashes (``"kill"``) it covers the hang family the SLO watchdog exists
  for: ``"stall"`` freezes a replica's engine loop (detected by the
  router's steps-frozen probe, not by ``healthy()``) and ``"slow"``
  degrades decode throughput (exercises deadline/stall enforcement).

The router detects death through ``healthy()`` (heartbeat/health-probe
hook) or by catching ``ReplicaDeadError`` at dispatch, then fails every
in-flight handle on the dead replica over through the client's existing
abort→resume migration path — see ``ProxyRouter.mark_dead``.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.analysis.sanitizer import new_lock


class ReplicaDeadError(RuntimeError):
    """Raised when a command is submitted to a crashed replica."""


class _ChaosEngine:
    """Engine shim injecting hang-family faults into the decode loop.

    Installed between a ``FaultyProxy`` and the real engine so the proxy's
    own event loop experiences the fault exactly where a real hung/slow
    engine would manifest: inside ``step()``.  A *stalled* engine spins
    (keeping the loop thread alive but making zero progress — the
    ``steps_executed`` counter freezes, which is what the router's stall
    probe watches); a *slowed* engine sleeps before each step.  A dead
    replica's engine executes nothing.
    """

    def __init__(self, inner, owner: "FaultyProxy"):
        self._inner = inner
        self._owner = owner

    def step(self):
        fp = self._owner
        if fp._dead.is_set():
            return []
        slow = fp._slow_s
        if slow > 0:
            time.sleep(slow)
        while (fp._stalled.is_set() and not fp._dead.is_set()
               and not fp.inner._stop.is_set()):
            # concheck: disable=busy-wait — the spin IS the injected fault:
            # a hung engine makes zero progress while its thread stays alive.
            time.sleep(0.002)
        if fp._dead.is_set() or fp.inner._stop.is_set():
            # the spin ended because the replica was killed/stopped, not
            # unstalled: a late step here would deliver post-mortem results
            # racing the router's failover into double resolution.
            return []
        return self._inner.step()

    def __getattr__(self, item):
        return getattr(self._inner, item)


class FaultyProxy:
    """Crash-injectable wrapper around an ``LLMProxy``.

    Every protocol method delegates to the wrapped proxy until ``kill()``;
    afterwards command submissions raise ``ReplicaDeadError``, the inner
    loop is stopped, and callbacks of in-flight requests never fire — the
    router's failover (not the dead replica) must resolve their handles.
    Metric reads keep returning the inner proxy's last (frozen) values so
    observability never throws mid-probe.

    ``kill_after_steps`` arms a self-destruct: the replica dies the first
    time its step counter crosses the threshold (checked on the caller of
    ``step_once`` — lockstep drivers — and by a watchdog when the
    threaded loop is used).
    """

    def __init__(self, inner, *, kill_after_steps: Optional[int] = None):
        self.inner = inner
        self.kill_after_steps = kill_after_steps
        self._dead = threading.Event()
        self._guard_lock = new_lock("FaultyProxy._guard_lock")
        self._decoded_at_death: Dict[int, int] = {}  # guarded-by: _guard_lock
        self._watchdog: Optional[threading.Thread] = None
        self.kills = 0  # guarded-by: _guard_lock — 0 or 1; survives the crash
        # hang-family faults, injected at the engine-step boundary
        self._slow_s = 0.0
        self._stalled = threading.Event()
        self.stalls = 0
        self.slowdowns = 0
        inner.engine = _ChaosEngine(inner.engine, self)

    # ------------------------------------------------------------ lifecycle
    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def engine(self):
        return self.inner.engine

    def healthy(self) -> bool:
        """Health-probe hook: False once killed (or the inner loop died)."""
        return not self._dead.is_set() and self.inner.healthy()

    def kill(self) -> None:
        """Simulate a replica crash NOW: snapshot the decode progress that
        dies with the process, stop the loop, suppress all callbacks."""
        with self._guard_lock:
            if self._dead.is_set():
                return
            # what a real crash loses: tokens decoded for requests that
            # were active on this replica and not yet delivered.
            counts: Dict[int, int] = {}
            peek = getattr(self.inner.engine, "peek_tokens", None)
            for rid in list(self.inner._active):
                try:
                    counts[rid] = len(peek(rid)) if peek is not None else 0
                except Exception:
                    counts[rid] = 0
            self._decoded_at_death = counts
            self._dead.set()
            self.kills = 1
        self.inner.stop()
        self._join_watchdog()

    def decoded_counts(self) -> Dict[int, int]:
        """Per-request decode progress lost at death (empty while alive) —
        the router sums this into its ``lost_tokens`` counter."""
        with self._guard_lock:
            return dict(self._decoded_at_death)

    # ----------------------------------------------------- hang-family faults
    def slow_decode(self, seconds: float) -> None:
        """Degrade decode: every engine step sleeps ``seconds`` first.
        Pass 0 to restore full speed."""
        if seconds > 0:
            self.slowdowns += 1
        self._slow_s = float(seconds)

    def stall(self) -> None:
        """Freeze the engine loop: steps spin without progress.  The replica
        still answers ``healthy()`` — only the router's steps-frozen probe
        (``SLOConfig.replica_stall_s``) can tell it is gone."""
        self.stalls += 1
        self._stalled.set()

    def unstall(self) -> None:
        self._stalled.clear()

    def _join_watchdog(self) -> None:
        w = self._watchdog
        if (w is not None and w.is_alive()
                and w is not threading.current_thread()):
            w.join(timeout=5.0)

    def start(self) -> "FaultyProxy":
        if self._dead.is_set():
            raise ReplicaDeadError(f"{self.name} is dead")
        self.inner.start()
        if self.kill_after_steps is not None and self._watchdog is None:
            self._watchdog = threading.Thread(
                target=self._watch, name=f"{self.name}:watchdog", daemon=True)
            self._watchdog.start()
        return self

    def _watch(self) -> None:
        # also exits when the inner loop is stopped normally — otherwise a
        # never-triggered self-destruct leaks its thread past shutdown
        while not self._dead.is_set() and not self.inner._stop.is_set():
            if self.inner.steps_executed >= self.kill_after_steps:
                self.kill()
                return
            # concheck: disable=busy-wait — chaos-harness watchdog polling a
            # plain step counter; there is no event source to park on.
            time.sleep(0.001)

    def stop(self) -> None:
        # stopping a dead replica is a no-op (the crash already stopped it)
        if not self._dead.is_set():
            self.inner.stop()
        self._join_watchdog()

    def step_once(self) -> bool:
        """Lockstep driving: a dead replica executes nothing.  The armed
        self-destruct fires here for thread-less (deterministic) fleets."""
        if self._dead.is_set():
            return False
        if (self.kill_after_steps is not None
                and self.inner.steps_executed >= self.kill_after_steps):
            self.kill()
            return False
        return self.inner.step_once()

    # ------------------------------------------------------------- commands
    def _check(self) -> None:
        if self._dead.is_set():
            raise ReplicaDeadError(f"replica {self.name} is dead")

    def _guard(self, callback: Callable) -> Callable:
        """Callbacks of a crashed replica must NEVER fire: the results died
        with the process, and a post-mortem delivery would race the
        router's synthesized failover abort into a double resolution."""
        def cb(res):
            if not self._dead.is_set():
                callback(res)
        return cb

    def generate(self, task, version, callback, **kw):
        self._check()
        return self.inner.generate(task, version, self._guard(callback), **kw)

    def generate_group(self, tasks, version, callback):
        self._check()
        return self.inner.generate_group(tasks, version, self._guard(callback))

    def generate_resumed(self, task, version, callback, resume_from, **kw):
        self._check()
        return self.inner.generate_resumed(task, version,
                                           self._guard(callback),
                                           resume_from=resume_from, **kw)

    def abort(self, request_id, retain=False):
        self._check()
        self.inner.abort(request_id, retain=retain)

    def abort_stale(self, min_version, retain=False):
        self._check()
        self.inner.abort_stale(min_version, retain=retain)

    def release_retained(self, request_id):
        self._check()
        self.inner.release_retained(request_id)

    def export_retained(self, request_id):
        self._check()
        return self.inner.export_retained(request_id)

    def generate_transferred(self, task, version, callback, record,
                             resume_from, **kw):
        self._check()
        return self.inner.generate_transferred(
            task, version, self._guard(callback), record=record,
            resume_from=resume_from, **kw)

    def export_prefix(self, tokens, deliver):
        self._check()
        self.inner.export_prefix(tokens, deliver)

    def import_prefix(self, record):
        self._check()
        self.inner.import_prefix(record)

    def suspend(self):
        self._check()
        self.inner.suspend()

    def resume(self):
        self._check()
        self.inner.resume()

    def update_weights(self, params):
        self._check()
        self.inner.update_weights(params)

    def update_weights_async(self, params):
        self._check()
        return self.inner.update_weights_async(params)

    # ------------------------------------------------------------- metrics
    # (delegated reads — frozen post-mortem, never raising)
    def __getattr__(self, item):
        return getattr(self.inner, item)


def wrap_fleet(proxies: List, **kw) -> List[FaultyProxy]:
    """Wrap every replica of a fleet for fault injection."""
    return [p if isinstance(p, FaultyProxy) else FaultyProxy(p, **kw)
            for p in proxies]


class FaultInjector(threading.Thread):
    """Seeded chaos monkey: fire random faults at live replicas while work
    runs.

    ``seed`` makes the victim/delay/mode SEQUENCE reproducible; the
    interleaving with the workload is still real concurrency — chaos tests
    assert outcome invariants (every handle resolves exactly once,
    survivors audit clean), never timing.  ``min_alive`` keeps the fleet
    routable; ``max_kills`` bounds the sweep (it counts every fault fired,
    not just crashes).

    ``modes`` selects the fault repertoire per firing:

    * ``"kill"``  — crash the replica (callbacks suppressed; the router's
      health probe / ``on_kill`` hook drives failover),
    * ``"stall"`` — freeze its engine loop; the replica stays "healthy",
      so only the router's steps-frozen probe rescues its work,
    * ``"slow"``  — degrade decode by a random per-step sleep; the SLO
      watchdog's deadline/stall enforcement is what keeps latency bounded.

    ``min_alive`` applies to the incapacitating modes (kill/stall);
    slowdowns can hit anyone.
    """

    def __init__(self, victims: List[FaultyProxy], *, seed: int = 0,
                 min_delay: float = 0.01, max_delay: float = 0.05,
                 max_kills: int = 1, min_alive: int = 1,
                 modes: tuple = ("kill",),
                 on_kill: Optional[Callable[[int], None]] = None):
        super().__init__(name="fault_injector", daemon=True)
        self.victims = list(victims)
        self.rng = np.random.default_rng(seed)
        self.min_delay = min_delay
        self.max_delay = max_delay
        self.max_kills = max_kills
        self.min_alive = min_alive
        self.modes = tuple(modes)
        self.on_kill = on_kill           # e.g. router.probe_health
        self.killed: List[int] = []
        self.stalled: List[int] = []
        self.slowed: List[int] = []
        # NB: not named _stop — threading.Thread owns that attribute
        self._halt = threading.Event()

    def stop(self) -> None:
        """Halt the sweep and wait for the thread to exit (no leak)."""
        self._halt.set()
        if self.is_alive() and self is not threading.current_thread():
            self.join(timeout=5.0)

    def _fired(self) -> int:
        return len(self.killed) + len(self.stalled) + len(self.slowed)

    def run(self) -> None:
        while not self._halt.is_set() and self._fired() < self.max_kills:
            delay = float(self.rng.uniform(self.min_delay, self.max_delay))
            if self._halt.wait(delay):
                return
            mode = str(self.rng.choice(self.modes))
            # an incapacitated (stalled) replica is not a useful victim either
            alive = [i for i, v in enumerate(self.victims)
                     if v.healthy() and not v._stalled.is_set()]
            if mode in ("kill", "stall") and len(alive) <= self.min_alive:
                continue
            if not alive:
                continue
            idx = int(self.rng.choice(alive))
            victim = self.victims[idx]
            if mode == "kill":
                victim.kill()
                self.killed.append(idx)
                if self.on_kill is not None:
                    self.on_kill(idx)
            elif mode == "stall":
                victim.stall()
                self.stalled.append(idx)
            else:                        # "slow"
                victim.slow_decode(float(self.rng.uniform(0.005, 0.02)))
                self.slowed.append(idx)
