"""EnvManager: per-environment event loop for agentic rollouts (§4.2, §5.2).

Each EnvManager mediates between its BaseEnv and the shared LLMProxy:
reset -> (action <- LLM) -> step -> ... -> reward -> SampleBuffer.  Running
many EnvManagers concurrently against one proxy realizes *environment-level
asynchronous rollout*: while one trajectory waits on its environment, the
decode slots serve other trajectories.

``EnvManagerPool`` implements *redundant environment rollout*:
``num_env_groups x group_size`` managers run concurrently, the pool stops
at ``target_trajectories``, and stragglers/failed envs are abandoned —
fail-slow and fail-stop environments never gate the step.
"""
from __future__ import annotations

import threading
from typing import Callable, List, Optional

import numpy as np

from repro.core.llm_proxy import LLMProxy
from repro.core.sample_buffer import SampleBuffer
from repro.core.types import (GenerationResult, RolloutTask, Trajectory, Turn,
                              next_uid)
from repro.envs.base import BaseEnv


class EnvManager(threading.Thread):
    """One environment's rollout loop.

    ``context_mode``:

    * ``"turn"`` (default) — each LLM call sees only the current
      observation (the seed behaviour; right for envs whose observation is
      already a full state encoding).
    * ``"full"`` — each LLM call resubmits the growing conversation
      (obs₀ action₀ obs₁ ... obsₜ).  On an engine with automatic prefix
      caching this becomes *incremental prefill per turn*: the whole shared
      history is aliased from cached pages and only the new observation
      suffix is prefilled.  ``max_context_tokens`` caps the prompt by
      dropping the oldest turns (a safety valve for the engine's sequence
      budget; it sacrifices cache hits on the dropped prefix).
    """

    def __init__(self, env: BaseEnv, proxy: LLMProxy, pool: "EnvManagerPool",
                 *, env_id: int, group_id: int, max_steps: int,
                 max_new_tokens: int, context_mode: str = "turn",
                 max_context_tokens: Optional[int] = None):
        super().__init__(name=f"env_manager_{env_id}", daemon=True)
        if context_mode not in ("turn", "full"):
            raise ValueError(f"context_mode must be turn|full, got {context_mode!r}")
        if context_mode == "full" and max_context_tokens is None:
            # an uncapped growing conversation would eventually overrun the
            # engine's sequence budget and assert inside the proxy thread —
            # force callers to size the cap (pipeline.py derives it from
            # max_seq_len - max_new_tokens).
            raise ValueError("context_mode='full' requires max_context_tokens")
        self.env = env
        self.proxy = proxy
        self.pool = pool
        self.env_id = env_id
        self.group_id = group_id
        self.max_steps = max_steps
        self.max_new_tokens = max_new_tokens
        self.context_mode = context_mode
        self.max_context_tokens = max_context_tokens
        self._result: Optional[GenerationResult] = None
        self._result_ready = threading.Event()

    def _build_prompt(self, ctx: List[np.ndarray], obs) -> np.ndarray:
        """The turn's LLM prompt: bare observation, or the conversation so
        far + the new observation (``full`` mode)."""
        obs = np.asarray(obs, np.int32)
        if self.context_mode != "full":
            return obs
        parts = list(ctx) + [obs]
        if self.max_context_tokens is not None:
            total = sum(len(p) for p in parts)
            while len(parts) > 1 and total > self.max_context_tokens:
                total -= len(parts.pop(0))   # drop oldest turns first
            if total > self.max_context_tokens:
                parts = [parts[0][-self.max_context_tokens:]]
        return np.concatenate(parts) if len(parts) > 1 else parts[0]

    # LLM call: submit to the shared proxy, park this manager (NOT the GPU —
    # other managers' requests keep the decode slots busy meanwhile).
    def _llm(self, obs_tokens: np.ndarray, version: int) -> Optional[GenerationResult]:
        self._result_ready.clear()
        task = RolloutTask(task_id=next_uid(), prompt_id=self.env_id,
                           replica_idx=0, prompt_tokens=obs_tokens,
                           max_new_tokens=self.max_new_tokens,
                           group_id=self.group_id)

        def cb(res: GenerationResult) -> None:
            self._result = res
            self._result_ready.set()

        self.proxy.generate(task, version, cb)
        while not self._result_ready.wait(timeout=0.1):
            if self.pool.stopped:
                self.proxy.abort(task.task_id)
                return None
        return self._result

    def run(self) -> None:
        while not self.pool.stopped:
            version = self.pool.buffer.begin_generation(timeout=0.1)
            if version is None:
                if self.pool.buffer.closed:
                    return
                continue
            traj = Trajectory(traj_id=next_uid(), env_id=self.env_id,
                              group_id=self.group_id, version_started=version)
            try:
                obs = self.env.reset()
            except Exception:
                traj.failed = True
                self.pool.buffer.reclaim(1)
                continue
            aborted = False
            ctx: List[np.ndarray] = []   # full-context mode: obs/action turns
            for _ in range(self.max_steps):
                prompt = self._build_prompt(ctx, obs)
                res = self._llm(prompt, version)
                if res is None or res.aborted:
                    aborted = True
                    break
                action = np.asarray(res.tokens, np.int32)
                if self.context_mode == "full":
                    ctx.append(np.asarray(obs, np.int32))
                    ctx.append(action)
                try:
                    obs, reward, done, info = self.env.step(action)
                except Exception:
                    traj.failed = True
                    break
                traj.turns.append(Turn(observation_tokens=np.asarray(obs, np.int32),
                                       action_tokens=action,
                                       logprobs=np.asarray(res.logprobs, np.float32)))
                if done:
                    traj.done = True
                    traj.reward = float(reward)
                    break
            if aborted or traj.failed or not traj.done:
                self.pool.buffer.reclaim(1)
                continue
            sample = traj.to_sample()
            try:
                self.pool.buffer.put(sample)
            except Exception:
                self.pool.buffer.reclaim(1)
                continue
            self.pool.on_trajectory(traj)


class EnvManagerPool:
    def __init__(self, make_env: Callable[[int], BaseEnv], proxy: LLMProxy,
                 buffer: SampleBuffer, *, num_env_groups: int, group_size: int,
                 max_steps: int, max_new_tokens: int,
                 target_trajectories: Optional[int] = None,
                 context_mode: str = "turn",
                 max_context_tokens: Optional[int] = None):
        self.buffer = buffer
        self.proxy = proxy
        self.num_env_groups = num_env_groups
        self.group_size = group_size
        self.target = target_trajectories
        self._stop = threading.Event()
        self._count = 0
        self._count_lock = threading.Lock()
        self.managers: List[EnvManager] = []
        eid = 0
        for g in range(num_env_groups):
            for _ in range(group_size):
                env = make_env(eid)
                self.managers.append(EnvManager(
                    env, proxy, self, env_id=eid, group_id=g,
                    max_steps=max_steps, max_new_tokens=max_new_tokens,
                    context_mode=context_mode,
                    max_context_tokens=max_context_tokens))
                eid += 1

    @property
    def total_envs(self) -> int:
        return self.num_env_groups * self.group_size

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()

    @property
    def trajectories_collected(self) -> int:
        with self._count_lock:
            return self._count

    def on_trajectory(self, traj: Trajectory) -> None:
        with self._count_lock:
            self._count += 1
            # redundant env rollout: stop at the target, abandon stragglers
            if self.target is not None and self._count >= self.target:
                self._stop.set()

    def start(self) -> "EnvManagerPool":
        for m in self.managers:
            m.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        if join:
            for m in self.managers:
                m.join(timeout=10)
