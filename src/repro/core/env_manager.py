"""EnvManager: per-environment event loop for agentic rollouts (§4.2, §5.2).

Each EnvManager mediates between its BaseEnv and the shared rollout service
through a first-class ``Session`` (`repro.core.rollout_client`):
reset -> (action <- session.turn) -> step -> ... -> reward -> SampleBuffer.
The session owns the conversation context (``turn``/``full`` modes — the
latter rides the radix prefix cache as incremental prefill per turn) and
version-tags every turn; a turn interrupted by a weight sync is resumed
transparently by the client layer (paged engines re-attach the retained KV
pages), so trajectories survive weight syncs instead of being thrown away.

Running many EnvManagers concurrently against one proxy realizes
*environment-level asynchronous rollout*: while one trajectory waits on its
environment, the decode slots serve other trajectories.

``EnvManagerPool`` implements *redundant environment rollout*:
``num_env_groups x group_size`` managers run concurrently, the pool stops
at ``target_trajectories``, and stragglers/failed envs are abandoned —
fail-slow and fail-stop environments never gate the step.
"""
from __future__ import annotations

import threading
from typing import Callable, List, Optional

import numpy as np

from repro.analysis.sanitizer import new_lock
from repro.core.rollout_client import GenerationHandle, RolloutClient, Session
from repro.core.sample_buffer import SampleBuffer
from repro.core.types import GenerationResult, Trajectory, Turn, next_uid
from repro.envs.base import BaseEnv


class EnvManager(threading.Thread):
    """One environment's rollout loop — a thin consumer of Sessions.

    ``context_mode``/``max_context_tokens`` configure each trajectory's
    Session (see `repro.core.rollout_client.Session`)."""

    def __init__(self, env: BaseEnv, proxy, pool: "EnvManagerPool",
                 *, env_id: int, group_id: int, max_steps: int,
                 max_new_tokens: int, context_mode: str = "turn",
                 max_context_tokens: Optional[int] = None,
                 client: Optional[RolloutClient] = None):
        super().__init__(name=f"env_manager_{env_id}", daemon=True)
        if context_mode not in ("turn", "full"):
            raise ValueError(f"context_mode must be turn|full, got {context_mode!r}")
        if context_mode == "full" and max_context_tokens is None:
            # an uncapped growing conversation would eventually overrun the
            # engine's sequence budget and assert inside the proxy thread —
            # force callers to size the cap (pipeline.py derives it from
            # max_seq_len - max_new_tokens).
            raise ValueError("context_mode='full' requires max_context_tokens")
        self.env = env
        self.pool = pool
        self.env_id = env_id
        self.group_id = group_id
        self.max_steps = max_steps
        self.max_new_tokens = max_new_tokens
        self.context_mode = context_mode
        self.max_context_tokens = max_context_tokens
        self._handle_lock = new_lock("EnvManager._handle_lock")
        self._inflight: Optional[GenerationHandle] = None  # guarded-by: _handle_lock
        if client is None and proxy is not None:
            client = RolloutClient.ensure(
                proxy,
                version_fn=lambda: self.pool.buffer.version,
                resume_gate=lambda: not (self.pool.stopped
                                         or self.pool.buffer.closed))
        self.client = client

    def _new_session(self) -> Session:
        return self.client.session(
            session_id=self.env_id, group_id=self.group_id,
            max_new_tokens=self.max_new_tokens,
            context_mode=self.context_mode,
            max_context_tokens=self.max_context_tokens)

    def _await(self, handle: GenerationHandle) -> Optional[GenerationResult]:
        """Park this manager on the turn's handle (NOT the GPU — other
        managers' requests keep the decode slots busy meanwhile).

        Push-based cancellation: the handle is registered under
        ``_handle_lock`` so ``cancel_inflight`` (pool shutdown / target
        reached) aborts it and the wait wakes immediately — no 0.1 s
        stop-flag polling.  The ordering is race-free because the pool sets
        its stop event *before* sweeping registrations: either we see
        ``stopped`` here, or the sweep sees our registered handle.  The long
        timed wait below is a belt-and-braces fallback, not a poll."""
        with self._handle_lock:
            if self.pool.stopped:
                handle.abort()        # cancel; retained pages are released
                return None
            self._inflight = handle
        try:
            while not handle.wait(timeout=5.0):
                if self.pool.stopped:
                    handle.abort()
                    return None
        finally:
            with self._handle_lock:
                self._inflight = None
        return handle.result(0)

    def cancel_inflight(self) -> None:
        """Abort whatever turn this manager is parked on (idempotent; a
        handle that already resolved ignores the abort)."""
        with self._handle_lock:
            handle = self._inflight
        if handle is not None:
            handle.abort()

    def run(self) -> None:
        while not self.pool.stopped:
            version = self.pool.buffer.begin_generation(timeout=0.1)
            if version is None:
                if self.pool.buffer.closed:
                    return
                continue
            traj = Trajectory(traj_id=next_uid(), env_id=self.env_id,
                              group_id=self.group_id, version_started=version)
            try:
                obs = self.env.reset()
            except Exception:
                traj.failed = True
                self.pool.buffer.reclaim(1)
                continue
            session = self._new_session()
            aborted = False
            for _ in range(self.max_steps):
                res = self._await(session.turn(obs))
                if res is None or res.aborted:
                    aborted = True
                    break
                action = np.asarray(res.tokens, np.int32)
                try:
                    obs, reward, done, info = self.env.step(action)
                except Exception:
                    traj.failed = True
                    break
                traj.turns.append(Turn(observation_tokens=np.asarray(obs, np.int32),
                                       action_tokens=action,
                                       logprobs=np.asarray(res.logprobs, np.float32)))
                if done:
                    traj.done = True
                    traj.reward = float(reward)
                    break
            if aborted or traj.failed or not traj.done:
                self.pool.buffer.reclaim(1)
                continue
            traj.version_finished = session.turn_versions[-1] \
                if session.turn_versions else version
            sample = traj.to_sample()
            try:
                self.pool.buffer.put(sample)
            except Exception:
                self.pool.buffer.reclaim(1)
                continue
            self.pool.on_trajectory(traj)


class EnvManagerPool:
    def __init__(self, make_env: Callable[[int], BaseEnv], proxy,
                 buffer: SampleBuffer, *, num_env_groups: int, group_size: int,
                 max_steps: int, max_new_tokens: int,
                 target_trajectories: Optional[int] = None,
                 context_mode: str = "turn",
                 max_context_tokens: Optional[int] = None):
        self.buffer = buffer
        self.client = RolloutClient.ensure(
            proxy, version_fn=lambda: buffer.version,
            resume_gate=lambda: not (self.stopped or buffer.closed))
        self.proxy = self.client.proxy
        self.num_env_groups = num_env_groups
        self.group_size = group_size
        self.target = target_trajectories
        self._stop = threading.Event()
        self._count_lock = new_lock("EnvManagerPool._count_lock")
        self._count = 0  # guarded-by: _count_lock
        self.managers: List[EnvManager] = []
        eid = 0
        for g in range(num_env_groups):
            for _ in range(group_size):
                env = make_env(eid)
                self.managers.append(EnvManager(
                    env, self.proxy, self, env_id=eid, group_id=g,
                    max_steps=max_steps, max_new_tokens=max_new_tokens,
                    context_mode=context_mode,
                    max_context_tokens=max_context_tokens,
                    client=self.client))
                eid += 1

    @property
    def total_envs(self) -> int:
        return self.num_env_groups * self.group_size

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()

    @property
    def trajectories_collected(self) -> int:
        with self._count_lock:
            return self._count

    def on_trajectory(self, traj: Trajectory) -> None:
        target_hit = False
        with self._count_lock:
            self._count += 1
            # redundant env rollout: stop at the target, abandon stragglers
            if self.target is not None and self._count >= self.target \
                    and not self._stop.is_set():
                self._stop.set()
                target_hit = True
        if target_hit:
            # wake every straggler NOW (outside _count_lock: aborting goes
            # through the rollout client's lock)
            for m in self.managers:
                m.cancel_inflight()

    def start(self) -> "EnvManagerPool":
        for m in self.managers:
            m.start()
        return self

    def stop(self, join: bool = True) -> None:
        # order matters: set the stop flag first, then sweep registered
        # handles — _await registers under its lock only after re-checking
        # the flag, so no turn can slip between flag and sweep.
        self._stop.set()
        for m in self.managers:
            m.cancel_inflight()
        if join:
            for m in self.managers:
                m.join(timeout=10)
