"""EnvManager: per-environment event loop for agentic rollouts (§4.2, §5.2).

Each EnvManager mediates between its BaseEnv and the shared LLMProxy:
reset -> (action <- LLM) -> step -> ... -> reward -> SampleBuffer.  Running
many EnvManagers concurrently against one proxy realizes *environment-level
asynchronous rollout*: while one trajectory waits on its environment, the
decode slots serve other trajectories.

``EnvManagerPool`` implements *redundant environment rollout*:
``num_env_groups x group_size`` managers run concurrently, the pool stops
at ``target_trajectories``, and stragglers/failed envs are abandoned —
fail-slow and fail-stop environments never gate the step.
"""
from __future__ import annotations

import threading
from typing import Callable, List, Optional

import numpy as np

from repro.core.llm_proxy import LLMProxy
from repro.core.sample_buffer import SampleBuffer
from repro.core.types import (GenerationResult, RolloutTask, Trajectory, Turn,
                              next_uid)
from repro.envs.base import BaseEnv


class EnvManager(threading.Thread):
    """One environment's rollout loop."""

    def __init__(self, env: BaseEnv, proxy: LLMProxy, pool: "EnvManagerPool",
                 *, env_id: int, group_id: int, max_steps: int,
                 max_new_tokens: int):
        super().__init__(name=f"env_manager_{env_id}", daemon=True)
        self.env = env
        self.proxy = proxy
        self.pool = pool
        self.env_id = env_id
        self.group_id = group_id
        self.max_steps = max_steps
        self.max_new_tokens = max_new_tokens
        self._result: Optional[GenerationResult] = None
        self._result_ready = threading.Event()

    # LLM call: submit to the shared proxy, park this manager (NOT the GPU —
    # other managers' requests keep the decode slots busy meanwhile).
    def _llm(self, obs_tokens: np.ndarray, version: int) -> Optional[GenerationResult]:
        self._result_ready.clear()
        task = RolloutTask(task_id=next_uid(), prompt_id=self.env_id,
                           replica_idx=0, prompt_tokens=obs_tokens,
                           max_new_tokens=self.max_new_tokens,
                           group_id=self.group_id)

        def cb(res: GenerationResult) -> None:
            self._result = res
            self._result_ready.set()

        self.proxy.generate(task, version, cb)
        while not self._result_ready.wait(timeout=0.1):
            if self.pool.stopped:
                self.proxy.abort(task.task_id)
                return None
        return self._result

    def run(self) -> None:
        while not self.pool.stopped:
            version = self.pool.buffer.begin_generation(timeout=0.1)
            if version is None:
                if self.pool.buffer.closed:
                    return
                continue
            traj = Trajectory(traj_id=next_uid(), env_id=self.env_id,
                              group_id=self.group_id, version_started=version)
            try:
                obs = self.env.reset()
            except Exception:
                traj.failed = True
                self.pool.buffer.reclaim(1)
                continue
            aborted = False
            for _ in range(self.max_steps):
                res = self._llm(np.asarray(obs, np.int32), version)
                if res is None or res.aborted:
                    aborted = True
                    break
                action = np.asarray(res.tokens, np.int32)
                try:
                    obs, reward, done, info = self.env.step(action)
                except Exception:
                    traj.failed = True
                    break
                traj.turns.append(Turn(observation_tokens=np.asarray(obs, np.int32),
                                       action_tokens=action,
                                       logprobs=np.asarray(res.logprobs, np.float32)))
                if done:
                    traj.done = True
                    traj.reward = float(reward)
                    break
            if aborted or traj.failed or not traj.done:
                self.pool.buffer.reclaim(1)
                continue
            sample = traj.to_sample()
            try:
                self.pool.buffer.put(sample)
            except Exception:
                self.pool.buffer.reclaim(1)
                continue
            self.pool.on_trajectory(traj)


class EnvManagerPool:
    def __init__(self, make_env: Callable[[int], BaseEnv], proxy: LLMProxy,
                 buffer: SampleBuffer, *, num_env_groups: int, group_size: int,
                 max_steps: int, max_new_tokens: int,
                 target_trajectories: Optional[int] = None):
        self.buffer = buffer
        self.proxy = proxy
        self.num_env_groups = num_env_groups
        self.group_size = group_size
        self.target = target_trajectories
        self._stop = threading.Event()
        self._count = 0
        self._count_lock = threading.Lock()
        self.managers: List[EnvManager] = []
        eid = 0
        for g in range(num_env_groups):
            for _ in range(group_size):
                env = make_env(eid)
                self.managers.append(EnvManager(
                    env, proxy, self, env_id=eid, group_id=g,
                    max_steps=max_steps, max_new_tokens=max_new_tokens))
                eid += 1

    @property
    def total_envs(self) -> int:
        return self.num_env_groups * self.group_size

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()

    @property
    def trajectories_collected(self) -> int:
        with self._count_lock:
            return self._count

    def on_trajectory(self, traj: Trajectory) -> None:
        with self._count_lock:
            self._count += 1
            # redundant env rollout: stop at the target, abandon stragglers
            if self.target is not None and self._count >= self.target:
                self._stop.set()

    def start(self) -> "EnvManagerPool":
        for m in self.managers:
            m.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        if join:
            for m in self.managers:
                m.join(timeout=10)
