"""Propositions 1 & 2 (§3.1): closed-form efficiency bounds.

All times are in abstract seconds; K counts generation *workers* (decode
slots), matching the paper's queue-scheduling model where a finished worker
immediately receives the next task.
"""
from __future__ import annotations

import dataclasses


# ---------------------------------------------------------------------------
# Proposition 1: generation time under queue scheduling
# ---------------------------------------------------------------------------

def prop1_completion_bound(q: int, k: int, mu_gen: float, l_gen: float) -> float:
    """T_completion <= Q/K * mu + L (eq. 4)."""
    return q / k * mu_gen + l_gen


def prop1_per_sample_bound(q: int, k: int, mu_gen: float, l_gen: float) -> float:
    """Average per-sample completion time bound (eq. 5)."""
    return mu_gen / k + l_gen / q


def prop1_sync_per_sample(n: int, k: int, mu_gen: float, l_gen: float) -> float:
    """Sync: Q = N (eq. 6)."""
    return prop1_per_sample_bound(n, k, mu_gen, l_gen)


def prop1_async_per_sample(n: int, k: int, mu_gen: float, l_gen: float,
                           alpha: float) -> float:
    """Async: Q = (alpha+1) N (eq. 7)."""
    return prop1_per_sample_bound(int((alpha + 1) * n), k, mu_gen, l_gen)


def prop1_max_speedup(mu_gen: float, l_gen: float) -> float:
    """K = N, alpha -> inf: (L + mu) / mu."""
    return (l_gen + mu_gen) / mu_gen


# ---------------------------------------------------------------------------
# Proposition 2: end-to-end with resource partitioning
# ---------------------------------------------------------------------------

def prop2_sync_bound(n: int, k: int, mu_gen: float, l_gen: float,
                     mu_train: float, e: float) -> float:
    """T_sync <= N/K (mu_gen + E mu_train) + L_gen (eq. 8)."""
    return n / k * (mu_gen + e * mu_train) + l_gen


def prop2_async_bound(n: int, k: int, mu_gen: float, l_gen: float,
                      mu_train: float, e: float, alpha: float,
                      beta: float) -> float:
    """T_async <= max(gen-side, train-side) (eq. 9)."""
    gen = n / ((1 - beta) * k) * mu_gen + l_gen / ((alpha + 1) * (1 - beta))
    train = e * n / (beta * k) * mu_train
    return max(gen, train)


def prop2_optimal_beta(n: int, k: int, mu_gen: float, l_gen: float,
                       mu_train: float, e: float, alpha: float) -> float:
    """beta* balancing both pipelines (eq. 10)."""
    num = e * n * mu_train
    den = n * mu_gen + k * l_gen / (alpha + 1) + e * n * mu_train
    return num / den


def prop2_async_bound_at_optimum(n: int, k: int, mu_gen: float, l_gen: float,
                                 mu_train: float, e: float, alpha: float) -> float:
    """T_async <= N/K (mu_gen + E mu_train) + L/(alpha+1) (eq. 11)."""
    return n / k * (mu_gen + e * mu_train) + l_gen / (alpha + 1)


def prop2_max_speedup(n: int, k: int, mu_gen: float, l_gen: float,
                      mu_train: float, e: float) -> float:
    """alpha -> inf: 1 + K L / (N (mu_gen + E mu_train))."""
    return 1.0 + k * l_gen / (n * (mu_gen + e * mu_train))


@dataclasses.dataclass(frozen=True)
class Workload:
    """Convenience bundle for the benchmarks."""
    n: int              # rollout batch size (samples per training step)
    k: int              # generation workers
    mu_gen: float
    l_gen: float
    mu_train: float
    e: float = 1.0      # sample reuse (ppo_epochs)

    def sync_bound(self) -> float:
        return prop2_sync_bound(self.n, self.k, self.mu_gen, self.l_gen,
                                self.mu_train, self.e)

    def async_bound(self, alpha: float, beta: float | None = None) -> float:
        if beta is None:
            beta = prop2_optimal_beta(self.n, self.k, self.mu_gen, self.l_gen,
                                      self.mu_train, self.e, alpha)
        return prop2_async_bound(self.n, self.k, self.mu_gen, self.l_gen,
                                 self.mu_train, self.e, alpha, beta)
