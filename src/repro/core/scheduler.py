"""Queue scheduling + prompt replication + dynamic filtering (§5.1).

Two entry points, both thin consumers of the handle-based RolloutClient
(`repro.core.rollout_client`) — abort→resume continuation, token stitching
and budget clamping live in the client layer, never here:

* ``collect_rollout`` — one synchronous rollout step under queue scheduling:
  stream group completions, reward immediately, filter, top up redundant
  prompts, cancel leftovers once the batch qualifies.  (Sync-ROLL mode.)
* ``RolloutProducer`` — the continuous producer thread for the asynchronous
  architecture: keeps the SampleBuffer saturated subject to the freshness
  capacity (1+alpha)B, assembling GRPO groups before publishing.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.analysis.sanitizer import new_condition
from repro.core.rollout_client import (GenerationHandle, GroupHandle,
                                       RolloutClient)
from repro.core.sample_buffer import SampleBuffer
from repro.core.types import (PRIORITY_NORMAL, GenerationResult, Rejected,
                              RolloutTask, Sample, next_uid)


def expand_tasks(prompt_id: int, prompt_tokens, group_size: int,
                 max_new_tokens: int, *, replicate: bool,
                 priority: int = PRIORITY_NORMAL,
                 deadline_ms: Optional[float] = None) -> List[RolloutTask]:
    """Prompt replication (`num_return_sequences_expand`): one prompt with G
    candidates becomes G independently schedulable tasks; without it the
    whole group is a single task (one submission decoding G sequences —
    realized by the client/proxy as a group expansion, COW-shared where the
    engine supports it)."""
    gid = next_uid()
    if replicate:
        return [RolloutTask(task_id=next_uid(), prompt_id=prompt_id,
                            replica_idx=i, prompt_tokens=prompt_tokens,
                            max_new_tokens=max_new_tokens, group_id=gid,
                            priority=priority, deadline_ms=deadline_ms)
                for i in range(group_size)]
    return [RolloutTask(task_id=next_uid(), prompt_id=prompt_id, replica_idx=0,
                        prompt_tokens=prompt_tokens,
                        max_new_tokens=max_new_tokens, group_id=gid,
                        meta={"num_return_sequences": group_size},
                        priority=priority, deadline_ms=deadline_ms)]


def _make_sample(result: GenerationResult) -> Sample:
    """A finished handle result (already stitched + clamped) as a Sample."""
    task = result.task
    meta = dict(task.meta)
    if result.legs:
        meta["legs"] = list(result.legs)   # per-leg (version, ntokens) tags
    if getattr(result, "timed_out", False):
        meta["timed_out"] = True           # partial sample: deadline/stall hit
    if isinstance(result, Rejected):
        meta["rejected"] = result.reason
    return Sample(
        sample_id=next_uid(), prompt_id=task.prompt_id,
        replica_idx=task.replica_idx,
        prompt_tokens=np.asarray(task.prompt_tokens, np.int32),
        response_tokens=np.asarray(result.tokens, np.int32),
        logprobs=np.asarray(result.logprobs, np.float32),
        version_started=result.version_started, group_id=task.group_id,
        meta=meta)


class _GroupCollector:
    """Assemble per-prompt groups, reward on completion, apply the filter.

    Consumers wait on the collector's condition — no polling."""

    def __init__(self, group_size: int, reward_fn: Callable,
                 filter_fn: Optional[Callable] = None):
        self.group_size = group_size
        self.reward_fn = reward_fn
        self.filter_fn = filter_fn
        self._cond = new_condition(name="_GroupCollector._cond")
        self._partial: Dict[int, List[Sample]] = \
            collections.defaultdict(list)  # guarded-by: _cond
        self.done_groups: "collections.deque[List[Sample]]" = \
            collections.deque()  # guarded-by: _cond
        self.filtered_groups = 0  # guarded-by: _cond

    def add(self, result: GenerationResult) -> None:
        """Handle done-callback: samples carry result.version_started."""
        if result.aborted:
            with self._cond:
                self._cond.notify_all()
            return
        sample = _make_sample(result)
        # reward immediately on completion (overlaps with ongoing generation)
        sample.reward = float(self.reward_fn(sample))
        sample.is_positive = sample.reward > 0
        with self._cond:
            group = self._partial[result.task.group_id]
            group.append(sample)
            if len(group) == self.group_size:
                del self._partial[result.task.group_id]
                if self.filter_fn is not None and not self.filter_fn(group):
                    self.filtered_groups += 1
                else:
                    self.done_groups.append(group)
            self._cond.notify_all()

    def wait(self, timeout: float) -> None:
        """Park until the next completion/filter event (or timeout)."""
        with self._cond:
            if self.done_groups or self.filtered_groups:
                return
            # concheck: disable=cond-wait-loop — single timed park by design:
            # the caller (collect_rollout) re-evaluates its own predicate
            # each iteration; a spurious wakeup just re-enters the loop.
            self._cond.wait(timeout)

    def take_filtered(self) -> int:
        with self._cond:
            n, self.filtered_groups = self.filtered_groups, 0
            return n

    def pop_groups(self, max_samples: int) -> List[Sample]:
        out: List[Sample] = []
        with self._cond:
            while self.done_groups and len(out) < max_samples:
                out.extend(self.done_groups.popleft())
        return out

    def has_ready(self) -> bool:
        with self._cond:
            return bool(self.done_groups)


def variance_filter(group: List[Sample]) -> bool:
    """Dynamic-filtering default: drop zero intra-group reward variance."""
    rewards = [s.reward for s in group]
    return float(np.var(rewards)) > 0.0


def collect_rollout(
    proxy,
    prompts: Iterator[tuple[int, np.ndarray]],
    *,
    num_groups: int,
    group_size: int,
    max_new_tokens: int,
    reward_fn: Callable[[Sample], float],
    replicate: bool = True,
    filter_fn: Optional[Callable] = None,
    max_additional_running_prompts: int = 0,
    version: int = 0,
    timeout: float = 300.0,
    group_submit: bool = True,
    priority: int = PRIORITY_NORMAL,
    deadline_ms: Optional[float] = None,
) -> List[Sample]:
    """One rollout step (queue scheduling): returns num_groups qualifying
    groups, flattened.  Extra in-flight generations are cancelled on return.

    ``proxy`` may be a raw ``LLMProxy`` (wrapped in a RolloutClient
    internally) or an existing ``RolloutClient``.  With ``group_submit``
    (default) the G replicated candidates of a prompt go down as ONE group
    submission (COW prefix sharing on engines that support it); with
    ``replicate=False`` the single group task is expanded by the client, so
    both configurations yield exactly G samples per prompt.

    A finite prompt stream may exhaust mid-step (e.g. during filtered-group
    top-up at the end of an epoch): the step then returns the qualifying
    groups it could assemble (possibly fewer than ``num_groups``) instead of
    raising or spinning until the timeout."""
    client = RolloutClient.ensure(proxy, version_fn=lambda: version)
    collector = _GroupCollector(group_size, reward_fn, filter_fn)
    handles: List[GenerationHandle] = []
    exhausted = False

    def submit_one_prompt() -> bool:
        nonlocal exhausted
        try:
            pid, toks = next(prompts)
        except StopIteration:
            # a bare StopIteration would escape the caller's generator frames
            # as RuntimeError (PEP 479) — degrade to "no more prompts".
            exhausted = True
            return False
        tasks = expand_tasks(pid, toks, group_size, max_new_tokens,
                             replicate=replicate, priority=priority,
                             deadline_ms=deadline_ms)
        if replicate and group_submit and len(tasks) > 1:
            new = client.submit_group(tasks, version=version).handles
        else:
            new = []
            for task in tasks:
                h = client.submit(task, version=version)
                new.extend(h.handles if isinstance(h, GroupHandle) else [h])
        for h in new:
            h.add_done_callback(collector.add)
        handles.extend(new)
        return True

    for _ in range(num_groups + max_additional_running_prompts):
        if not submit_one_prompt():
            break

    want = num_groups * group_size
    out: List[Sample] = []
    deadline = time.monotonic() + timeout
    try:
        while len(out) < want:
            out.extend(collector.pop_groups(want - len(out)))
            if len(out) >= want:
                break
            # top up for filtered-out groups so the step always completes
            for _ in range(collector.take_filtered()):
                if not submit_one_prompt():
                    break
            if exhausted and all(h.done() for h in handles) \
                    and not collector.has_ready():
                break      # nothing in flight, no prompts left: partial
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError("collect_rollout timed out")
            collector.wait(min(remaining, 1.0))
        out.extend(collector.pop_groups(want - len(out)))
    finally:
        # cancel whatever is still running — on the normal exit the step
        # has what it needs; on the timeout exit the leftovers must not
        # keep decoding (and rewarding into an abandoned collector) on a
        # shared proxy.
        for h in handles:
            if not h.done():
                h.abort()
    return out


class _GroupAssembler:
    """Prompt-aligned group assembly over a (pid, tokens) stream.

    Owns the two pieces of cross-group state the producer used to thread by
    hand: the *held prompt* (a pull that crossed a prompt boundary during
    partial-group assembly seeds the next group, keeping grouping aligned
    with the stream) and the *group uid* (consecutive pulls of one prompt
    share a fresh ``next_uid()`` until group_size is reached, so a
    capacity-pinch partial flush stays one logical group while a prompt
    repeated in a later epoch never collides with its earlier group)."""

    def __init__(self, prompts: Iterator[tuple], group_size: int):
        self.prompts = prompts
        self.group_size = group_size
        self.held: Optional[tuple] = None
        self._uid: Optional[int] = None
        self._pid: Optional[int] = None
        self._count = 0

    def pull(self, group_pid: Optional[int]) -> Tuple[str, Optional[int], Optional[np.ndarray]]:
        """Next prompt for a group anchored at ``group_pid``: ("ok", pid,
        toks), ("boundary", ...) when the stream crossed into the next
        prompt (held back to seed the next group), or ("exhausted", ...)."""
        if self.held is not None:
            pid, toks = self.held
            self.held = None
        else:
            try:
                pid, toks = next(self.prompts)
            except StopIteration:
                return "exhausted", None, None
        if group_pid is not None and pid != group_pid:
            self.held = (pid, toks)
            return "boundary", None, None
        return "ok", pid, toks

    def group_id(self, pid: int) -> int:
        if (self._uid is None or pid != self._pid
                or self._count >= self.group_size):
            self._uid = next_uid()
            self._pid = pid
            self._count = 0
        self._count += 1
        return self._uid


class RolloutProducer(threading.Thread):
    """Continuous RLVR producer for the async architecture — a thin consumer
    of RolloutClient handles.

    Each candidate generation claims a freshness slot from the buffer before
    starting (begin_generation), guaranteeing occupancy <= (1+alpha)B.
    Completed handles are rewarded and published sample-by-sample; an
    in-flight generation interrupted by a weight sync is transparently
    resumed BY THE CLIENT under the new version (the producer only ever
    sees final results)."""

    def __init__(self, proxy, buffer: SampleBuffer,
                 prompts: Iterator[tuple[int, np.ndarray]], *,
                 group_size: int, max_new_tokens: int,
                 reward_fn: Callable[[Sample], float],
                 replicate: bool = True, name: str = "rollout_producer",
                 priority: int = PRIORITY_NORMAL,
                 deadline_ms: Optional[float] = None):
        super().__init__(name=name, daemon=True)
        self.buffer = buffer
        self.group_size = group_size
        self.max_new_tokens = max_new_tokens
        self.reward_fn = reward_fn
        self.replicate = replicate
        self.priority = priority
        self.deadline_ms = deadline_ms
        # NB: not named _stop — threading.Thread owns that attribute,
        # and join() calls it as a method
        self._halt = threading.Event()
        self._owns_client = not isinstance(proxy, RolloutClient)
        self.client = RolloutClient.ensure(
            proxy, version_fn=lambda: self.buffer.version,
            resume_gate=lambda: not (self.buffer.closed
                                     or self._halt.is_set()))
        self.proxy = self.client.proxy
        self._groups = _GroupAssembler(prompts, group_size)

    def stop(self) -> None:
        self._halt.set()
        if self._owns_client:
            # a caller-provided (possibly shared) client is left open —
            # other consumers may still rely on its continuations.
            self.client.close()

    def _publish(self, result: GenerationResult) -> None:
        """Handle done-callback: reward + publish, or release the freshness
        slot of a cancelled/shutdown generation."""
        if result.aborted:
            self.buffer.reclaim(1)
            return
        sample = _make_sample(result)
        sample.reward = float(self.reward_fn(sample))
        sample.is_positive = sample.reward > 0
        try:
            self.buffer.put(sample)
        except Exception:
            self.buffer.reclaim(1)

    def _submit(self, tasks: List[RolloutTask], version: int) -> None:
        if not tasks:
            return
        if not self.replicate and len(tasks) > 1:
            # non-replicated group: ONE submission decoding k sequences
            # (client expands it; COW group sharing where supported)
            t0 = tasks[0]
            handle = self.client.submit(RolloutTask(
                task_id=t0.task_id, prompt_id=t0.prompt_id, replica_idx=0,
                prompt_tokens=t0.prompt_tokens,
                max_new_tokens=t0.max_new_tokens, group_id=t0.group_id,
                meta={"num_return_sequences": len(tasks)},
                priority=t0.priority, deadline_ms=t0.deadline_ms),
                version=version)
        elif len(tasks) > 1:
            handle = self.client.submit_group(tasks, version=version)
        else:
            handle = self.client.submit(tasks[0], version=version)
        handle.add_done_callback(self._publish)

    def _produce_group(self) -> bool:
        """Claim up to group_size freshness slots and submit them as ONE
        group (prompt_stream repeats each prompt group_size times, so
        consecutive pulls are replicas of the same prompt).  A capacity
        pinch flushes a partial group — COW sharing degrades for that group,
        correctness doesn't: assembly downstream keys on group_id.  Groups
        always cut at prompt boundaries (see _GroupAssembler).  Returns
        False to stop the producer."""
        tasks: List[RolloutTask] = []
        version = 0
        exhausted = False
        while len(tasks) < self.group_size:
            if self._halt.is_set() or self.buffer.closed:
                self.buffer.reclaim(len(tasks))
                return False
            v = self.buffer.begin_generation(timeout=0.1)
            if v is None:
                if tasks:
                    break  # freshness capacity pinch: flush a partial group
                continue
            status, pid, toks = self._groups.pull(
                tasks[0].prompt_id if tasks else None)
            if status != "ok":
                self.buffer.reclaim(1)
                exhausted = status == "exhausted"
                break
            version = max(version, v)
            tasks.append(RolloutTask(task_id=next_uid(), prompt_id=pid,
                                     replica_idx=len(tasks),
                                     prompt_tokens=toks,
                                     max_new_tokens=self.max_new_tokens,
                                     group_id=self._groups.group_id(pid),
                                     priority=self.priority,
                                     deadline_ms=self.deadline_ms))
        self._submit(tasks, version)
        return not exhausted

    def run(self) -> None:
        while not self._halt.is_set() and not self.buffer.closed:
            if not self._produce_group():
                return
