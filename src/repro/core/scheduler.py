"""Queue scheduling + prompt replication + dynamic filtering (§5.1).

Two entry points:

* ``collect_rollout`` — one synchronous rollout step under queue scheduling:
  stream group completions, reward immediately, filter, top up redundant
  prompts, ABORT leftovers once the batch qualifies.  (Sync-ROLL mode.)
* ``RolloutProducer`` — the continuous producer thread for the asynchronous
  architecture: keeps the SampleBuffer saturated subject to the freshness
  capacity (1+alpha)B, assembling GRPO groups before publishing.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

from repro.core.llm_proxy import LLMProxy
from repro.core.sample_buffer import SampleBuffer
from repro.core.types import GenerationResult, RolloutTask, Sample, next_uid


def expand_tasks(prompt_id: int, prompt_tokens, group_size: int,
                 max_new_tokens: int, *, replicate: bool) -> List[RolloutTask]:
    """Prompt replication (`num_return_sequences_expand`): one prompt with G
    candidates becomes G independently schedulable tasks; without it the
    whole group is a single task (one engine request decoding G sequences)."""
    gid = next_uid()
    if replicate:
        return [RolloutTask(task_id=next_uid(), prompt_id=prompt_id,
                            replica_idx=i, prompt_tokens=prompt_tokens,
                            max_new_tokens=max_new_tokens, group_id=gid)
                for i in range(group_size)]
    return [RolloutTask(task_id=next_uid(), prompt_id=prompt_id, replica_idx=0,
                        prompt_tokens=prompt_tokens,
                        max_new_tokens=max_new_tokens, group_id=gid,
                        meta={"num_return_sequences": group_size})]


class _GroupCollector:
    """Assemble per-prompt groups, reward on completion, apply the filter."""

    def __init__(self, group_size: int, reward_fn: Callable,
                 filter_fn: Optional[Callable] = None):
        self.group_size = group_size
        self.reward_fn = reward_fn
        self.filter_fn = filter_fn
        self._partial: Dict[int, List[Sample]] = collections.defaultdict(list)
        self.done_groups: "collections.deque[List[Sample]]" = collections.deque()
        self.filtered_groups = 0
        self.lock = threading.Lock()
        self.event = threading.Event()

    def add(self, result: GenerationResult, version: int) -> None:
        if result.aborted:
            return
        task = result.task
        sample = Sample(
            sample_id=next_uid(), prompt_id=task.prompt_id,
            replica_idx=task.replica_idx, prompt_tokens=task.prompt_tokens,
            response_tokens=np.asarray(result.tokens),
            logprobs=np.asarray(result.logprobs),
            version_started=result.version_started, group_id=task.group_id,
            meta=dict(task.meta),
        )
        # reward immediately on completion (overlaps with ongoing generation)
        sample.reward = float(self.reward_fn(sample))
        sample.is_positive = sample.reward > 0
        with self.lock:
            group = self._partial[task.group_id]
            group.append(sample)
            if len(group) == self.group_size:
                del self._partial[task.group_id]
                if self.filter_fn is not None and not self.filter_fn(group):
                    self.filtered_groups += 1
                else:
                    self.done_groups.append(group)
        self.event.set()


def variance_filter(group: List[Sample]) -> bool:
    """Dynamic-filtering default: drop zero intra-group reward variance."""
    rewards = [s.reward for s in group]
    return float(np.var(rewards)) > 0.0


def collect_rollout(
    proxy: LLMProxy,
    prompts: Iterator[tuple[int, np.ndarray]],
    *,
    num_groups: int,
    group_size: int,
    max_new_tokens: int,
    reward_fn: Callable[[Sample], float],
    replicate: bool = True,
    filter_fn: Optional[Callable] = None,
    max_additional_running_prompts: int = 0,
    version: int = 0,
    timeout: float = 300.0,
    group_submit: bool = True,
) -> List[Sample]:
    """One rollout step (queue scheduling): returns num_groups qualifying
    groups, flattened. Extra in-flight generations are ABORTed on return.

    With ``group_submit`` (default) the G replicated candidates of a prompt
    go to the proxy as ONE group submission: COW engines prefill the prompt
    once and fork G lanes sharing its KV pages; other engines degrade to G
    independent requests inside the proxy.

    A finite prompt stream may exhaust mid-step (e.g. during filtered-group
    top-up at the end of an epoch): the step then returns the qualifying
    groups it could assemble (possibly fewer than ``num_groups``) instead of
    raising or spinning until the timeout."""
    collector = _GroupCollector(group_size, reward_fn, filter_fn)
    submitted: List[int] = []
    finished_ids: set = set()
    ids_lock = threading.Lock()
    exhausted = False

    def submit_one_prompt() -> bool:
        nonlocal exhausted
        try:
            pid, toks = next(prompts)
        except StopIteration:
            # a bare StopIteration would escape the caller's generator frames
            # as RuntimeError (PEP 479) — degrade to "no more prompts".
            exhausted = True
            return False
        tasks = expand_tasks(pid, toks, group_size, max_new_tokens,
                             replicate=replicate)
        submitted.extend(t.task_id for t in tasks)

        def cb(r: GenerationResult) -> None:
            if not r.aborted:
                with ids_lock:
                    finished_ids.add(r.request_id)
            collector.add(r, version)

        if group_submit and replicate and len(tasks) > 1:
            proxy.generate_group(tasks, version, cb)
        else:
            for task in tasks:
                proxy.generate(task, version, cb)
        return True

    for _ in range(num_groups + max_additional_running_prompts):
        if not submit_one_prompt():
            break

    out: List[Sample] = []
    import time as _time
    deadline = _time.monotonic() + timeout
    while len(out) < num_groups * group_size:
        collector.event.wait(timeout=0.05)
        collector.event.clear()
        while collector.done_groups and len(out) < num_groups * group_size:
            out.extend(collector.done_groups.popleft())
        # top up for filtered-out groups so the step always completes
        with collector.lock:
            need_more = collector.filtered_groups
            collector.filtered_groups = 0
        for _ in range(need_more):
            if not submit_one_prompt():
                break
        if exhausted:
            with ids_lock:
                all_done = len(finished_ids) >= len(submitted)
            if all_done and not collector.done_groups:
                break          # nothing in flight, no prompts left: partial
        if _time.monotonic() > deadline:
            raise TimeoutError("collect_rollout timed out")
    while collector.done_groups and len(out) < num_groups * group_size:
        out.extend(collector.done_groups.popleft())
    # ABORT only what is still running — the step has what it needs
    with ids_lock:
        running = [tid for tid in submitted if tid not in finished_ids]
    for tid in running:
        proxy.abort(tid)
    return out


class RolloutProducer(threading.Thread):
    """Continuous RLVR producer for the async architecture.

    Each candidate generation claims a freshness slot from the buffer before
    starting (begin_generation), guaranteeing occupancy <= (1+alpha)B.
    Completed groups are rewarded and published sample-by-sample.
    """

    def __init__(self, proxy: LLMProxy, buffer: SampleBuffer,
                 prompts: Iterator[tuple[int, np.ndarray]], *,
                 group_size: int, max_new_tokens: int,
                 reward_fn: Callable[[Sample], float],
                 replicate: bool = True, name: str = "rollout_producer"):
        super().__init__(name=name, daemon=True)
        self.proxy = proxy
        self.buffer = buffer
        self.prompts = prompts
        self.group_size = group_size
        self.max_new_tokens = max_new_tokens
        self.reward_fn = reward_fn
        self.replicate = replicate
        self._stop = threading.Event()
        # prompt pulled past a group boundary during partial-group assembly;
        # it seeds the next group so grouping stays aligned with the stream.
        self._held_prompt: Optional[tuple] = None
        # current group uid: one fresh next_uid() per assembled group.  Using
        # the prompt id would collide a prompt repeated across epochs with
        # its earlier group in downstream assembly/GRPO grouping.
        self._group_uid: Optional[int] = None
        self._group_pid: Optional[int] = None
        self._group_count = 0

    def stop(self) -> None:
        self._stop.set()

    def _next_group_id(self, pid: int) -> int:
        """Group uid for the next pull of prompt ``pid``: consecutive pulls
        of the same prompt share one uid until group_size is reached (so a
        capacity-pinch partial flush stays one logical group), then a fresh
        uid starts — a prompt repeated in a later epoch never collides with
        its earlier group."""
        if (self._group_uid is None or pid != self._group_pid
                or self._group_count >= self.group_size):
            self._group_uid = next_uid()
            self._group_pid = pid
            self._group_count = 0
        self._group_count += 1
        return self._group_uid

    def _publish(self, task: RolloutTask, response: np.ndarray,
                 logprobs: np.ndarray, version_started: int) -> None:
        """Reward and publish a finished sample.  The response is clamped to
        the ORIGINAL generation budget — abort→resume legs must never let
        the concatenated response exceed it."""
        opl = task.meta.get("orig_prompt_len",
                            len(np.asarray(task.prompt_tokens)))
        budget = task.meta.get("orig_max_new_tokens", task.max_new_tokens)
        sample = Sample(
            sample_id=next_uid(), prompt_id=task.prompt_id,
            replica_idx=task.replica_idx,
            prompt_tokens=np.asarray(task.prompt_tokens, np.int32)[:opl],
            response_tokens=np.asarray(response, np.int32)[:budget],
            logprobs=np.asarray(logprobs, np.float32)[:budget],
            version_started=version_started, group_id=task.group_id)
        sample.reward = float(self.reward_fn(sample))
        sample.is_positive = sample.reward > 0
        self.buffer.put(sample)

    def _on_result(self, result: GenerationResult) -> None:
        task = result.task
        if result.aborted:
            if self.buffer.closed or self._stop.is_set():
                self.buffer.reclaim(1)
                if result.resumable:
                    # the engine parked this request's pages; nobody will
                    # resume it, so hand them back to the pool.
                    self.proxy.release_retained(result.request_id)
                return
            # ABORT -> resume: the partial response is NOT wasted.  Its
            # behaviour-policy logprobs are kept — exactly what IS-based
            # correctors need (new-policy logprobs are recomputed by the
            # trainer's forward where the correctors consume them, never
            # here) — and the sample is re-initiated at the current
            # version, keeping the already-claimed freshness slot.
            partial = np.asarray(result.tokens) if result.tokens is not None \
                else np.zeros((0,), np.int32)
            done = task.meta.get("resumed_tokens", np.zeros((0,), np.int32))
            lps = task.meta.get("resumed_logprobs", np.zeros((0,), np.float32))
            plp = np.asarray(result.logprobs) if result.logprobs is not None \
                else np.zeros((0,), np.float32)
            budget = task.meta.get("orig_max_new_tokens", task.max_new_tokens)
            all_tokens = np.concatenate([done, partial])
            all_lps = np.concatenate([lps, plp])
            remaining = budget - len(all_tokens)
            if remaining <= 0:
                # the budget is already spent: resuming would decode >= 1
                # extra token per resume cycle (budget overrun).  The sample
                # is complete — publish it and drop any retained pages.
                if result.resumable:
                    self.proxy.release_retained(result.request_id)
                self._publish(task, all_tokens, all_lps,
                              result.version_started)
                return
            carried_meta = {
                **{k: v for k, v in task.meta.items()
                   if not k.startswith("resumed_")},
                "orig_prompt_len": task.meta.get(
                    "orig_prompt_len", len(np.asarray(task.prompt_tokens))),
                "orig_max_new_tokens": budget,
                "resumed_tokens": all_tokens,
                "resumed_logprobs": all_lps,
            }
            if result.resumable:
                # Paged engine retained the prefix's KV pages: resume
                # re-attaches them — zero prefix recomputation.  The prompt
                # stays the ORIGINAL prompt; the decoded prefix lives in
                # the retained pages and in resumed_tokens meta.
                resumed = RolloutTask(
                    task_id=next_uid(), prompt_id=task.prompt_id,
                    replica_idx=task.replica_idx,
                    prompt_tokens=np.asarray(task.prompt_tokens, np.int32),
                    max_new_tokens=remaining,
                    group_id=task.group_id, meta=carried_meta)
                self.proxy.generate_resumed(resumed, self.buffer.version,
                                            self._on_result,
                                            resume_from=result.request_id)
                return
            # Slot engine fallback: the decoded prefix becomes part of the
            # prompt of a resumed task (KV recomputed at prefill).
            resumed = RolloutTask(
                task_id=next_uid(), prompt_id=task.prompt_id,
                replica_idx=task.replica_idx,
                prompt_tokens=np.concatenate(
                    [np.asarray(task.prompt_tokens, np.int32),
                     partial.astype(np.int32)]),
                max_new_tokens=remaining,
                group_id=task.group_id, meta=carried_meta)
            self.proxy.generate(resumed, self.buffer.version, self._on_result)
            return
        prefix_t = task.meta.get("resumed_tokens", np.zeros((0,), np.int32))
        prefix_l = task.meta.get("resumed_logprobs", np.zeros((0,), np.float32))
        self._publish(
            task,
            np.concatenate([prefix_t.astype(np.int32),
                            np.asarray(result.tokens, np.int32)]),
            np.concatenate([prefix_l.astype(np.float32),
                            np.asarray(result.logprobs, np.float32)]),
            result.version_started)

    def _produce_group(self) -> bool:
        """Claim up to group_size freshness slots and submit them as ONE
        group (prompt_stream repeats each prompt group_size times, so
        consecutive pulls are replicas of the same prompt).  A capacity
        pinch flushes a partial group — COW sharing degrades for that group,
        correctness doesn't: assembly downstream keys on group_id, not on
        submission batching.  Groups always cut at prompt boundaries: a pull
        that crosses into the next prompt is held back to seed the next
        group, so one partial flush never de-aligns the rest of the run.
        Returns False to stop the producer."""
        tasks: List[RolloutTask] = []
        version = 0
        exhausted = False
        while len(tasks) < self.group_size:
            if self._stop.is_set() or self.buffer.closed:
                self.buffer.reclaim(len(tasks))
                return False
            v = self.buffer.begin_generation(timeout=0.1)
            if v is None:
                if tasks:
                    break  # freshness capacity pinch: flush a partial group
                continue
            if self._held_prompt is not None:
                pid, toks = self._held_prompt
                self._held_prompt = None
            else:
                try:
                    pid, toks = next(self.prompts)
                except StopIteration:
                    self.buffer.reclaim(1)
                    exhausted = True
                    break
            if tasks and pid != tasks[0].prompt_id:
                # crossed a prompt boundary (a previous partial flush left
                # the stream mid-prompt): hold it for the next group.
                self._held_prompt = (pid, toks)
                self.buffer.reclaim(1)
                break
            version = max(version, v)
            tasks.append(RolloutTask(task_id=next_uid(), prompt_id=pid,
                                     replica_idx=len(tasks),
                                     prompt_tokens=toks,
                                     max_new_tokens=self.max_new_tokens,
                                     group_id=self._next_group_id(pid)))
        if len(tasks) > 1:
            self.proxy.generate_group(tasks, version, self._on_result)
        elif tasks:
            self.proxy.generate(tasks[0], version, self._on_result)
        return not exhausted

    def run(self) -> None:
        if self.replicate and self.group_size > 1:
            while not self._stop.is_set() and not self.buffer.closed:
                if not self._produce_group():
                    return
            return
        while not self._stop.is_set() and not self.buffer.closed:
            version = self.buffer.begin_generation(timeout=0.1)
            if version is None:
                continue
            try:
                pid, toks = next(self.prompts)
            except StopIteration:
                self.buffer.reclaim(1)
                return
            task = RolloutTask(task_id=next_uid(), prompt_id=pid,
                               replica_idx=0, prompt_tokens=toks,
                               max_new_tokens=self.max_new_tokens,
                               group_id=self._next_group_id(pid))
            self.proxy.generate(task, version, self._on_result)
