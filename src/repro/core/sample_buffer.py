"""SampleBuffer: the producer–consumer heart of rollout–train decoupling.

Implements the paper's §4.3 *asynchronous ratio* alpha as a per-sample
freshness constraint: a sample whose generation was initiated at policy
version ``v`` is admissible only while ``current_version - v <= alpha``.
Because generation initiation is gated on buffer occupancy
(``<= (1 + alpha) * batch_size`` unconsumed-or-in-flight samples), no sample
is ever wasted — the buffer never needs to drop a violating sample in steady
state; the ``reclaim`` hook exists for ABORTed partial generations, which
are recycled for recomputation rather than discarded.

alpha = 0 degenerates to fully synchronous training (the consumer blocks
until the freshest batch is complete and producers cannot run ahead).
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis.sanitizer import new_condition, new_lock
from repro.core.types import Sample


class StaleSampleError(RuntimeError):
    pass


class SampleBuffer:
    def __init__(self, batch_size: int, alpha: float = 0.0, *,
                 strict: bool = True):
        self.batch_size = batch_size
        self.alpha = alpha
        self.strict = strict
        self._lock = new_lock("SampleBuffer._lock")
        self._not_empty = new_condition(self._lock, name="SampleBuffer._not_empty")
        self._can_produce = new_condition(self._lock, name="SampleBuffer._can_produce")
        self._samples: List[Sample] = []  # guarded-by: _lock
        self._inflight = 0                # guarded-by: _lock
        self._initiated = 0               # guarded-by: _lock
        self._version = 0                 # guarded-by: _lock
        self._closed = False              # guarded-by: _lock
        self.total_produced = 0           # guarded-by: _lock
        self.total_consumed = 0           # guarded-by: _lock
        self.total_reclaimed = 0          # guarded-by: _lock
        self.total_evicted = 0            # guarded-by: _lock

    # ------------------------------------------------------------------ info
    @property
    def capacity(self) -> int:
        return int((1 + self.alpha) * self.batch_size)

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def occupancy(self) -> int:
        """Completed-unconsumed + in-flight samples (the (1+alpha)B bound)."""
        with self._lock:
            return len(self._samples) + self._inflight

    # ------------------------------------------------------------ producers
    def _admissible(self) -> bool:  # holds: _lock
        """Freshness gate.  With FIFO-by-initiation consumption, the i-th
        initiated sample (0-based) is consumed while the policy is at version
        floor(i / B); admitting it requires floor(i/B) - v_now <= alpha, i.e.
        initiated < (v_now + alpha + 1) * B.  This also implies occupancy
        <= (1 + alpha) * B (the paper's buffer bound) since consumption
        removes B per version advance."""
        return self._initiated < (self._version + self.alpha + 1) * self.batch_size

    def try_begin_generation(self) -> Optional[int]:
        """Claim a generation slot; returns the initiating policy version or
        None if the freshness capacity is exhausted."""
        with self._lock:
            if self._closed or not self._admissible():
                return None
            self._inflight += 1
            self._initiated += 1
            return self._version

    def begin_generation(self, timeout: Optional[float] = None) -> Optional[int]:
        """Blocking variant of try_begin_generation."""
        with self._can_produce:
            while not self._closed and not self._admissible():
                if not self._can_produce.wait(timeout=timeout):
                    return None
            if self._closed:
                return None
            self._inflight += 1
            self._initiated += 1
            return self._version

    def put(self, sample: Sample) -> None:
        with self._lock:
            if self.strict and self._version - sample.version_started > self.alpha:
                raise StaleSampleError(
                    f"sample initiated at v{sample.version_started} is older than "
                    f"alpha={self.alpha} behind v{self._version}")
            sample.version_finished = self._version
            self._samples.append(sample)
            self._inflight = max(0, self._inflight - 1)
            self.total_produced += 1
            self._not_empty.notify_all()

    def reclaim(self, n: int = 1) -> None:
        """Release in-flight slots for abandoned generations (failed envs,
        shutdown).  Returns both the slot and the consumption reservation."""
        with self._lock:
            self._inflight = max(0, self._inflight - n)
            self._initiated = max(0, self._initiated - n)
            self.total_reclaimed += n
            self._can_produce.notify_all()

    # ------------------------------------------------------------ consumers
    def get_batch(self, n: Optional[int] = None, *, block: bool = True,
                  timeout: Optional[float] = None) -> List[Sample]:
        """Blocking get of n samples (FIFO = oldest-first, preserving
        freshness headroom for the rest)."""
        n = n if n is not None else self.batch_size
        with self._not_empty:
            if block:
                ok = self._not_empty.wait_for(
                    lambda: len(self._samples) >= n or self._closed, timeout=timeout)
                if not ok:
                    raise TimeoutError(f"get_batch({n}) timed out")
            if len(self._samples) < n:
                raise RuntimeError("buffer closed with insufficient samples")
            # consume oldest-initiated first: completion order can invert under
            # long-tail generation, and freshness headroom must go to the
            # oldest samples or they would stale out while waiting.
            self._samples.sort(key=lambda s: s.version_started)
            batch, self._samples = self._samples[:n], self._samples[n:]
            self.total_consumed += len(batch)
            # capture the version INSIDE the critical section: a concurrent
            # advance_version between releasing the lock and the strict
            # re-check below must not fail a batch that was admissible at
            # the moment it was consumed.
            version_at_consume = self._version
            self._can_produce.notify_all()
        if self.strict:
            for s in batch:
                if version_at_consume - s.version_started > self.alpha:
                    raise StaleSampleError(
                        f"consumed sample from v{s.version_started} "
                        f"at v{version_at_consume}")
        return batch

    def advance_version(self) -> int:
        """Called by the AsyncController after each train step / model_update.

        Enforces the per-sample freshness invariant on COMPLETED samples:
        a long-tail sample can complete at gap alpha, miss its batch (because
        faster, newer samples filled it), and would violate after this
        advance.  In-flight stragglers are ABORTed by the controller; the
        completed ones are evicted here and their reservations recycled so a
        fresh generation starts immediately (tracked as total_evicted —
        empirically a small fraction, see EXPERIMENTS.md)."""
        with self._lock:
            self._version += 1
            keep, evicted = [], 0
            for s in self._samples:
                if self._version - s.version_started > self.alpha:
                    evicted += 1
                else:
                    keep.append(s)
            if evicted:
                self._samples = keep
                self._initiated = max(0, self._initiated - evicted)
                self.total_evicted += evicted
            self._can_produce.notify_all()
            return self._version

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._can_produce.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def max_staleness(self) -> int:
        with self._lock:
            if not self._samples:
                return 0
            return max(self._version - s.version_started for s in self._samples)
