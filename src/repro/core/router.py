"""ProxyRouter: queue scheduling across an elastic fleet of rollout replicas.

The paper's headline rollout mechanism is *queue scheduling*: instead of
statically partitioning a batch across inference workers (and waiting for
the slowest partition — the long-tail straggler problem), every prompt is
dispatched individually to the least-loaded worker the moment it is
submitted.  This module scales the single proxy/engine rollout path to N
replicas behind one object that speaks the exact ``LLMProxy`` protocol, so
``RolloutClient``, ``RolloutProducer``, ``EnvManagerPool`` and the
``AsyncController`` consume a fleet without changes:

* **Queue scheduling** — ``generate`` routes each request to the replica
  with the least outstanding decode work (``LLMProxy.load()``, in tokens),
  subject to static admission feedback (``can_accept``: a request that can
  never fit a replica's page pool is not queued there).
* **Co-location** — the G candidates of a GRPO group land on ONE replica
  (COW prefix sharing is per-replica), and every turn of an agentic
  ``Session`` follows its predecessors (the radix prefix cache holding the
  conversation history is per-replica too).  Placement pins are LRU-capped.
* **Cross-replica abort→resume migration** — ``prefer_resume`` tells the
  RolloutClient whether an aborted-with-retain request should re-attach in
  place (the cheap default) or migrate.  ``generate_migrated`` moves the
  parked KV pages themselves: the home replica exports them to a host-side
  record (``export_retained``), the target imports them and resumes with
  ZERO re-prefill (``generate_transferred``), and only when the transfer
  can't run (dead home, page pressure on the target, quant mismatch) does
  it degrade to the client-built concatenated re-prefill.  Migration
  triggers when the home replica is draining (``drain()``), overloaded
  past ``migrate_factor``/``migrate_margin``, or DEAD (its parked pages
  died with it — a crash is the one case that still re-prefills).
* **Cache-aware routing** (``cache_aware=True``) — a router-owned
  ``FleetRadixIndex`` mirrors every replica's radix prefix cache
  (maintained push-style from insert/evict/clear events), making placement
  two-tier: a request routes to the replica holding its longest cached
  prefix when that replica's load is within ``cache_affinity_slack``
  tokens of the fleet minimum, otherwise it routes least-loaded and the
  prefix pages are PULLED across (``export_prefix``/``import_prefix``)
  before admission.  ``fleet_audit`` cross-checks the index against every
  live replica's local tree.
* **Replica lifecycle & crash failover** — every replica carries a state
  (``healthy``/``draining``/``dead``/``retired``).  Death is detected by
  the ``healthy()`` heartbeat probe (``probe_health`` — poll it, or run
  ``start_health_monitor``) or by catching ``ReplicaDeadError`` at
  dispatch.  ``mark_dead`` then fails EVERY in-flight handle on the dead
  replica over through the client's existing abort→resume continuation: a
  synthesized non-resumable abort makes the client re-admit the request's
  concatenated prefix (original prompt + all completed legs) on a live
  replica — exactly-once handle resolution, leg/version tags preserved,
  no completed sample ever lost.  Only the dead replica's un-delivered
  current-leg decode progress is re-computed (``lost_tokens``).
* **Elasticity** — ``add_replica`` grows the fleet mid-run (warmed with
  the last-synced weights before taking traffic — the reverse of
  ``drain``); an ``AutoscalePolicy`` drives load-triggered scaling from
  the fleet's ``queue_depth``/``active_per_replica`` stats with
  hysteresis + cooldown, retiring drained replicas on scale-down.
* **Fleet-wide weight sync** — ``update_weights[_async]`` fan out to every
  live replica; the staged variant returns an aggregate event that is set
  once all LIVE replicas acknowledge — a replica dying mid-sync has its
  ack waived instead of deadlocking the trainer.
* **Aggregated observability** — ``cache_stats``/``load``/``queue_depth``
  sum across live replicas; ``replica_stats`` exposes the per-replica view
  (state, load, active/pending, staleness, cache hits); ``fleet_audit``
  asserts the rid→replica map is consistent (and empty at quiescence) and
  runs every live engine's ``audit_pages``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.analysis.sanitizer import new_condition, new_lock, new_rlock
from repro.core.faults import ReplicaDeadError
from repro.core.llm_proxy import LLMProxy
from repro.core.slo import SLOConfig, stamp_deadline
from repro.core.types import (PRIORITY_NORMAL, GenerationResult, Rejected,
                              RolloutTask, expand_replicas)

# Cross-class acquisition order the AST pass cannot see (concheck reads these
# declarations into its cycle check):
# lock-order: FleetSyncEvent._cond -> ProxyRouter._lock
#   (FleetSyncEvent.is_set consults router._down() under its condition; the
#   reverse never happens — the router notifies sync waiters OUTSIDE _lock)
# lock-order: ProxyRouter._lock -> LLMProxy._load_lock
#   (_place queries replica load()/can_accept() while holding the router lock)
# lock-order: ProxyRouter._lock -> FleetRadixIndex._lock
#   (_place queries best_prefix under the router lock; index listeners fire
#   from replica loop threads holding no other lock, and the index never
#   calls out while holding its own lock)

# group/session placement memory; old pins evict LRU (a group whose pin
# evicted mid-flight merely loses co-location for later members, never
# correctness — assembly keys on group_id, not placement).
_MAX_PINS = 8192


class MultiEvent:
    """Aggregate of the per-replica staged weight-sync events: ``wait``
    returns True once EVERY replica has acknowledged its swap."""

    def __init__(self, events: List[threading.Event]):
        self._events = list(events)

    def is_set(self) -> bool:
        return all(e.is_set() for e in self._events)

    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        for e in self._events:
            left = (None if deadline is None
                    else max(0.0, deadline - time.monotonic()))
            if not e.wait(left):
                return False
        return True


class FleetSyncEvent(MultiEvent):
    """Fleet-wide staged sync that tolerates replica death: set once every
    replica has acknowledged OR died — a crashed replica serves no traffic,
    so waiting for its ack would only deadlock the trainer.

    Push-based: each per-replica ``NotifyingEvent`` ack and every router
    death/retire event notifies this waiter's condition, so ``wait`` parks
    instead of polling.  For monitor-less fleets (nothing else would ever
    call ``mark_dead``) each wakeup also re-probes fleet health — on a
    bounded fallback cadence, not a busy spin."""

    # how long wait() parks between fallback health probes when no
    # notification arrives (monitor-less death detection latency bound)
    _PROBE_SLICE_S = 0.05

    def __init__(self, pairs: List[tuple], router: "ProxyRouter"):
        super().__init__([e for _, e in pairs])
        self._pairs = list(pairs)
        self._router = router
        self._cond = new_condition(name="FleetSyncEvent._cond")
        for _i, e in pairs:
            subscribe = getattr(e, "on_set", None)
            if subscribe is not None:    # raw Events (test doubles) fall
                subscribe(self._notify)  # back to the probe cadence
        router._watch_sync(self)

    def _notify(self) -> None:
        """Ack/death push — called from proxy-loop and router threads,
        never with ProxyRouter._lock held."""
        with self._cond:
            self._cond.notify_all()

    def _acked(self) -> bool:
        """All replicas acknowledged (no death waiver needed) — this
        waiter needs no further notifications."""
        return MultiEvent.is_set(self)

    def is_set(self) -> bool:
        down = self._router._down()
        return all(e.is_set() or i in down for i, e in self._pairs)

    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.is_set():
                return True
            now = time.monotonic()
            if deadline is not None and now >= deadline:
                return False
            # fallback probe OUTSIDE _cond: mark_dead notifies waiters
            self._router.probe_health()
            left = (self._PROBE_SLICE_S if deadline is None
                    else min(self._PROBE_SLICE_S, deadline - time.monotonic()))
            if left <= 0:
                continue
            with self._cond:
                if not self.is_set():
                    self._cond.wait(left)


@dataclasses.dataclass
class AutoscalePolicy:
    """Load-triggered elasticity knobs (hysteresis by consecutive-tick
    patience + post-action cooldown so load breathing doesn't flap).

    Scale up when fleet queue depth exceeds ``queue_high`` pending requests
    per live replica for ``up_patience`` consecutive ticks; scale down when
    slot utilization sits below ``active_low`` with an empty queue for
    ``down_patience`` ticks (the victim drains first, retiring only once
    idle — in-flight work is never killed by the autoscaler)."""
    min_replicas: int = 1
    max_replicas: int = 8
    queue_high: float = 4.0      # pending per live replica → scale up
    active_low: float = 0.25     # active/slot utilization → scale down
    up_patience: int = 2
    down_patience: int = 3
    cooldown: int = 2            # ticks after any action with no new action


class _IndexNode:
    """One page-granular node of the fleet index: which replicas cache the
    page whose content address is the path to this node."""
    __slots__ = ("children", "replicas")

    def __init__(self):
        self.children: Dict[tuple, "_IndexNode"] = {}
        self.replicas: set = set()


class _ReplicaCacheListener:
    """Adapter bound to one replica: forwards its ``RadixCache``
    insert/evict/clear events into the router's fleet index.  Fires on the
    replica's loop thread; the index does its own locking."""
    __slots__ = ("index", "idx")

    def __init__(self, index: "FleetRadixIndex", idx: int):
        self.index = index
        self.idx = idx

    def on_insert(self, path: tuple) -> None:
        self.index.on_insert(self.idx, path)

    def on_evict(self, path: tuple) -> None:
        self.index.on_evict(self.idx, path)

    def on_clear(self) -> None:
        self.index.on_clear(self.idx)


class FleetRadixIndex:
    """Router-owned map of token-content prefixes → the replicas caching
    them: the fleet-global view of every replica's local radix prefix
    cache, maintained push-style from insert/evict/clear events.

    Content-addressed exactly like ``RadixCache``: one node per full page,
    keyed by that page's token tuple, so ``best_prefix`` answers "who holds
    the longest cached prefix of this prompt" in one walk.  Placement uses
    it for the cache-affinity tier and for picking pull sources.  The index
    holds NO page references — it is purely a map, kept honest against the
    local trees by ``fleet_audit``.

    Every method takes only the index's own lock and never calls out under
    it; see the declared ``ProxyRouter._lock -> FleetRadixIndex._lock``
    edge for how it composes with placement."""

    def __init__(self):
        self._lock = new_lock("FleetRadixIndex._lock")
        self._root = _IndexNode()          # guarded-by: _lock
        # all replicas of a fleet share one page size; recorded at attach
        self.page_size: Optional[int] = None
        self.inserts = 0                   # guarded-by: _lock
        self.evictions = 0                 # guarded-by: _lock
        self.clears = 0                    # guarded-by: _lock

    # ------------------------------------------------------ event ingestion
    def on_insert(self, replica: int, path: tuple) -> None:
        with self._lock:
            node = self._root
            for key in path:
                child = node.children.get(key)
                if child is None:
                    child = _IndexNode()
                    node.children[key] = child
                node = child
            node.replicas.add(replica)
            self.inserts += 1

    def on_evict(self, replica: int, path: tuple) -> None:
        with self._lock:
            chain = [self._root]
            node = self._root
            for key in path:
                node = node.children.get(key)
                if node is None:
                    return
                chain.append(node)
            node.replicas.discard(replica)
            self.evictions += 1
            # prune replica-less childless tails: the index tracks the
            # union of live caches, not their history
            for i in range(len(chain) - 1, 0, -1):
                n = chain[i]
                if n.children or n.replicas:
                    break
                del chain[i - 1].children[path[i - 1]]

    def on_clear(self, replica: int) -> None:
        with self._lock:
            self._scrub(self._root, replica)
            self.clears += 1

    def drop_replica(self, replica: int) -> None:
        """Forget everything a dead/retired replica cached."""
        with self._lock:
            self._scrub(self._root, replica)

    def _scrub(self, node: _IndexNode, replica: int) -> None:
        # holds: _lock
        for key in list(node.children):
            child = node.children[key]
            child.replicas.discard(replica)
            self._scrub(child, replica)
            if not child.replicas and not child.children:
                del node.children[key]

    # -------------------------------------------------------------- queries
    def best_prefix(self, tokens) -> Dict[int, int]:
        """replica → cached prefix length in TOKENS (page-aligned) for this
        prompt.  Each replica reports the deepest node it holds along the
        walk; replicas caching nothing of the prompt are absent."""
        ps = self.page_size
        if ps is None:
            return {}
        out: Dict[int, int] = {}
        with self._lock:
            node = self._root
            for i in range(len(tokens) // ps):
                key = tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
                node = node.children.get(key)
                if node is None:
                    break
                for r in node.replicas:
                    out[r] = (i + 1) * ps
        return out

    def paths_for(self, replica: int) -> set:
        """Every content path the index attributes to ``replica`` — the
        ``fleet_audit`` cross-check against the replica's local tree."""
        out: set = set()
        with self._lock:
            stack: List[tuple] = [(self._root, ())]
            while stack:
                node, prefix = stack.pop()
                for key, child in node.children.items():
                    p = prefix + (key,)
                    if replica in child.replicas:
                        out.add(p)
                    stack.append((child, p))
        return out


@dataclasses.dataclass
class _Home:
    """Per-request routing record: where it lives, and everything needed
    to synthesize its failover abort if that replica dies."""
    idx: int
    callback: Callable[[GenerationResult], None]
    version: int
    retained: bool = False       # parked pages (abort-with-retain victim)


class ProxyRouter:
    """N proxy/engine replicas behind the single-proxy protocol.

    ``migrate_factor`` / ``migrate_margin_tokens`` bound when an
    aborted-with-retain request migrates instead of resuming in place: the
    home replica must carry more than ``factor * min_load + margin``
    outstanding tokens (or be draining/dead).  In-place resume re-attaches
    retained pages at zero prefill cost, so migration has to buy real
    rebalancing to be worth a concatenated re-prefill.

    ``replica_factory`` builds a fresh proxy for ``add_replica()`` /
    autoscale scale-up; ``autoscale`` arms the load-triggered policy
    (ticked by the health monitor, or manually via ``autoscale_tick``).
    """

    def __init__(self, proxies: List[LLMProxy], *,
                 migrate_factor: float = 2.0,
                 migrate_margin_tokens: int = 128,
                 replica_factory: Optional[Callable[[], LLMProxy]] = None,
                 autoscale: Optional[AutoscalePolicy] = None,
                 slo: Optional[SLOConfig] = None,
                 cache_aware: bool = False,
                 cache_affinity_slack: int = 256,
                 cache_pull: bool = True,
                 page_transfer: bool = True):
        assert proxies, "router needs at least one replica"
        self.proxies = list(proxies)
        self.migrate_factor = migrate_factor
        self.migrate_margin_tokens = migrate_margin_tokens
        self.replica_factory = replica_factory
        self.autoscale = autoscale
        # cache-aware routing: a fleet-global prefix index makes placement
        # two-tier (affinity within the slack band, else least-loaded with
        # an optional prefix pull); page_transfer moves retained pages on
        # migration instead of re-prefilling the concatenated prompt.
        self.cache_aware = cache_aware
        self.cache_affinity_slack = cache_affinity_slack
        self.cache_pull = cache_pull
        self.page_transfer = page_transfer
        self.fleet_index: Optional[FleetRadixIndex] = \
            FleetRadixIndex() if cache_aware else None
        # SLO front door: queue bounds are enforced HERE fleet-wide (the
        # replicas behind a router carry an admission-stripped copy — see
        # slo.without_admission); preemption/watchdog run on the replicas.
        self.slo = slo
        self._lock = new_rlock("ProxyRouter._lock")
        self._home: Dict[int, _Home] = {}      # guarded-by: _lock — request_id -> routing record
        # requests whose callback resolved BEFORE _register could record
        # them (submit→resolve race on the proxy loop thread): _register
        # must not re-insert a mapping nobody will ever remove.
        self._early_resolved: set = set()      # guarded-by: _lock
        # rids resolved by a synthesized failover abort: a late real
        # callback from the (not-quite-dead-yet) replica must be dropped,
        # not forwarded — the failover leg already owns the handle.
        self._failed_over: set = set()         # guarded-by: _lock
        # retained rids whose parked pages died with their replica: the
        # continuation must re-prefill elsewhere, never resume in place.
        self._lost_retained: set = set()       # guarded-by: _lock
        self._group_home: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()          # guarded-by: _lock
        self._session_home: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()          # guarded-by: _lock
        self._draining: set = set()            # guarded-by: _lock
        self._dead: set = set()                # guarded-by: _lock — crashed
        self._retired: set = set()             # guarded-by: _lock — scaled down cleanly
        self._scaledown_pending: set = set()   # guarded-by: _lock — draining toward retirement
        self._started = False                  # guarded-by: _lock
        self._last_weights = None              # guarded-by: _lock — warm-start for add_replica
        # in-flight FleetSyncEvents to poke (OUTSIDE _lock) on death/retire
        self._sync_waiters: List["FleetSyncEvent"] = []  # guarded-by: _lock
        self._monitor: Optional[threading.Thread] = None
        self._monitor_stop = threading.Event()
        # replica-stall detection: idx -> (steps_executed, wall time seen)
        self._progress: Dict[int, tuple] = {}  # guarded-by: _lock
        self._rejected = 0                     # guarded-by: _lock — front-door bounces
        # autoscale streaks are ticked by exactly one thread (the health
        # monitor, or manual autoscale_tick callers) — thread-owned, unlocked.
        self._up_streak = 0
        self._down_streak = 0
        self._cooldown = 0
        self.routed = 0                        # guarded-by: _lock
        self.migrations = 0                    # guarded-by: _lock
        self.failovers = 0                     # guarded-by: _lock — handles failed over off dead replicas
        self.lost_tokens = 0                   # guarded-by: _lock — decode progress lost to crashes
        self.replicas_failed = 0               # guarded-by: _lock
        self.replicas_added = 0                # guarded-by: _lock
        self.scale_ups = 0                     # guarded-by: _lock
        self.scale_downs = 0                   # guarded-by: _lock
        self.cache_routed = 0                  # guarded-by: _lock — affinity-tier placements
        self.cache_pulls = 0                   # guarded-by: _lock — prefix pulls initiated
        self.pages_transferred = 0             # guarded-by: _lock — cross-replica pages moved
        self.transfer_bytes = 0                # guarded-by: _lock
        if self.fleet_index is not None:
            for i, p in enumerate(self.proxies):
                self._attach_index(i, p)

    def _attach_index(self, idx: int, proxy) -> None:
        """Subscribe the fleet index to a replica's radix-cache events —
        and seed it with anything already cached (warm ``add_replica``)."""
        if self.fleet_index is None:
            return
        cache = getattr(getattr(proxy, "engine", None), "prefix_cache", None)
        if cache is None or not hasattr(cache, "paths"):
            return
        self.fleet_index.page_size = cache.page_size
        cache.listener = _ReplicaCacheListener(self.fleet_index, idx)
        for path in cache.paths():
            self.fleet_index.on_insert(idx, path)

    # ---------------------------------------------------------- lifecycle
    def _down(self) -> set:
        with self._lock:
            return self._dead | self._retired

    def _watch_sync(self, ev: "FleetSyncEvent") -> None:
        """Track an in-flight fleet sync so death/retire events can wake
        its waiters push-style.  Fully-acked syncs are pruned here (an
        abandoned, never-fully-acked sync lingers until the next sync —
        bounded by sync cadence, not by fleet lifetime)."""
        with self._lock:
            self._sync_waiters = [w for w in self._sync_waiters
                                  if not w._acked()]
            self._sync_waiters.append(ev)

    def _notify_sync_waiters(self) -> None:
        """Wake every in-flight fleet sync.  MUST be called outside
        ``_lock``: FleetSyncEvent re-checks ``is_set()`` (→ ``_down()``)
        under its own condition, so notifying under the router lock would
        invert the declared FleetSyncEvent._cond -> ProxyRouter._lock
        order."""
        with self._lock:
            waiters = list(self._sync_waiters)
        for w in waiters:
            w._notify()

    def replica_state(self, idx: int) -> str:
        with self._lock:
            if idx in self._dead:
                return "dead"
            if idx in self._retired:
                return "retired"
            if idx in self._draining:
                return "draining"
            return "healthy"

    @property
    def replicas_alive(self) -> int:
        with self._lock:
            return len(self.proxies) - len(self._dead) - len(self._retired)

    def _live(self) -> List[int]:
        """Replicas that can still execute work (healthy or draining)."""
        down = self._down()
        return [i for i in range(len(self.proxies)) if i not in down]

    def probe_health(self) -> List[int]:
        """Heartbeat sweep: ask every live replica ``healthy()``; mark the
        ones that fail (or raise) dead and fail their work over.  Returns
        the newly dead indices."""
        newly: List[int] = []
        for i in self._live():
            p = self.proxies[i]
            probe = getattr(p, "healthy", None)
            try:
                ok = probe() if probe is not None else True
            except Exception:
                ok = False
            if not ok:
                self.mark_dead(i)
                newly.append(i)
        if self.slo is not None and self.slo.replica_stall_s:
            newly.extend(self._probe_stalls())
        return newly

    def _probe_stalls(self) -> List[int]:
        """Hang detection: a replica that still answers ``healthy()`` but
        whose ``steps_executed`` counter has not moved for
        ``slo.replica_stall_s`` WALL-CLOCK seconds while it holds active
        work is wedged (hung engine loop, stuck collective) — declare it
        dead and fail its handles over like a crash.  Idle replicas are
        exempt: no active work, nothing to step."""
        grace = self.slo.replica_stall_s
        now = time.monotonic()
        newly: List[int] = []
        for i in self._live():
            p = self.proxies[i]
            try:
                active = p.num_active
                steps = p.steps_executed
            except Exception:
                continue        # liveness probe above owns hard failures
            with self._lock:
                if active <= 0:
                    self._progress.pop(i, None)
                    continue
                prev = self._progress.get(i)
                if prev is None or prev[0] != steps:
                    self._progress[i] = (steps, now)
                    continue
                stalled = now - prev[1] >= grace
                if stalled:
                    self._progress.pop(i, None)
            if stalled:         # mark_dead fires callbacks: outside _lock
                self.mark_dead(i)
                newly.append(i)
        return newly

    def mark_dead(self, idx: int) -> None:
        """Crash handling — the paper's queue-scheduling gains assume the
        dispatcher always has healthy workers; this is what keeps that true.

        Every in-flight request homed on the dead replica fails over: its
        consumer callback receives a synthesized non-resumable abort, which
        the RolloutClient continuation answers by re-admitting the
        concatenated prefix (original prompt + completed legs) on a live
        replica — exactly-once resolution, nothing completed is lost.
        Retained (parked-pages) victims are remembered in
        ``_lost_retained`` so their continuation migrates instead of
        resuming into pages that no longer exist."""
        with self._lock:
            if idx in self._dead or idx in self._retired:
                return
            self._dead.add(idx)
            self._draining.discard(idx)
            self._scaledown_pending.discard(idx)
            self.replicas_failed += 1
            if self.fleet_index is not None:
                self.fleet_index.drop_replica(idx)
            fail: List[tuple] = []
            for rid, rec in list(self._home.items()):
                if rec.idx != idx:
                    continue
                del self._home[rid]
                self._failed_over.add(rid)
                if rec.retained:
                    self._lost_retained.add(rid)
                else:
                    fail.append((rid, rec))
        # decode progress that died with the replica (sim-measurable hook)
        counts: Dict[int, int] = {}
        dc = getattr(self.proxies[idx], "decoded_counts", None)
        if dc is not None:
            try:
                counts = dc()
            except Exception:
                counts = {}
        with self._lock:
            self.failovers += len(fail)
            for rid, _rec in fail:
                self.lost_tokens += int(counts.get(rid, 0))
        for rid, rec in fail:   # consumer callbacks run OUTSIDE _lock
            rec.callback(GenerationResult(
                request_id=rid, task=None, tokens=None, logprobs=None,
                version_started=rec.version, aborted=True, partial=True,
                resumable=False))
        # a dead replica's pending ack is waived: wake in-flight syncs
        self._notify_sync_waiters()

    def add_replica(self, proxy: Optional[LLMProxy] = None, *,
                    warm: bool = True) -> int:
        """Grow the fleet mid-run (the reverse of ``drain``): append a
        replica, warm it with the last-synced weights BEFORE it takes
        traffic (a cold replica would serve the initial policy), and start
        its loop if the fleet is running.  Returns the new index."""
        if proxy is None:
            if self.replica_factory is None:
                raise RuntimeError("add_replica() needs a proxy or a "
                                   "replica_factory")
            proxy = self.replica_factory()
        with self._lock:
            weights = self._last_weights
        if warm and weights is not None:
            # pre-start staging applies inline; a started proxy stages the
            # swap and we wait for the ack so no request sees cold weights.
            proxy.update_weights_async(weights).wait(timeout=30)
        with self._lock:
            idx = len(self.proxies)
            self.proxies.append(proxy)
            self.replicas_added += 1
            started = self._started
        self._attach_index(idx, proxy)
        if started:
            proxy.start()
        return idx

    def _retire(self, idx: int) -> None:
        """Finish a scale-down: the drained replica stops and leaves the
        placement set for good (distinct from ``dead`` — not a failure)."""
        with self._lock:
            if idx in self._retired or idx in self._dead:
                return
            self._retired.add(idx)
            self._draining.discard(idx)
            self._scaledown_pending.discard(idx)
            self.scale_downs += 1
            if self.fleet_index is not None:
                self.fleet_index.drop_replica(idx)
        self.proxies[idx].stop()
        self._notify_sync_waiters()     # retired == down for sync waivers

    # --------------------------------------------------------- autoscaling
    def autoscale_tick(self) -> Optional[str]:
        """One observation of the load-triggered policy: retire drained
        scale-down victims, then scale up/down when the patience streaks
        cross their thresholds (no action during cooldown).  Returns
        "up" | "down" | None for observability."""
        pol = self.autoscale
        if pol is None:
            return None
        with self._lock:
            pending_retire = list(self._scaledown_pending)
            draining = set(self._draining)
        for i in pending_retire:
            p = self.proxies[i]
            if p.num_active == 0 and p.num_pending == 0 and p.load() == 0:
                self._retire(i)
        live = self._live()
        n = len(live)
        queue = sum(self.proxies[i].num_pending for i in live)
        active = sum(self.proxies[i].num_active for i in live)
        capacity = sum(self.proxies[i].num_active
                       + self.proxies[i].engine.num_free_slots for i in live)
        util = active / capacity if capacity else 0.0
        self._up_streak = (self._up_streak + 1
                           if n and queue > pol.queue_high * n else 0)
        self._down_streak = (self._down_streak + 1
                             if queue == 0 and util < pol.active_low else 0)
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        placeable = [i for i in live if i not in draining]
        if (self._up_streak >= pol.up_patience and n < pol.max_replicas
                and self.replica_factory is not None):
            self.add_replica()
            with self._lock:
                self.scale_ups += 1
            self._up_streak = 0
            self._cooldown = pol.cooldown
            return "up"
        if (self._down_streak >= pol.down_patience
                and len(placeable) > pol.min_replicas):
            # drain the least-loaded placeable replica; it retires on a
            # later tick once its in-flight work finishes.
            victim = min(placeable, key=lambda i: (self.proxies[i].load(), -i))
            with self._lock:
                self._draining.add(victim)
                self._scaledown_pending.add(victim)
            self._down_streak = 0
            self._cooldown = pol.cooldown
            return "down"
        return None

    def start_health_monitor(self, interval: float = 0.02) -> None:
        """Background heartbeat: probe fleet health (and tick the
        autoscaler) every ``interval`` seconds until ``stop()``."""
        if self._monitor is not None:
            return
        self._monitor_stop.clear()      # restart after a previous stop()

        def loop():
            while not self._monitor_stop.wait(interval):
                self.probe_health()
                self.autoscale_tick()
        self._monitor = threading.Thread(target=loop, name="fleet_health",
                                         daemon=True)
        self._monitor.start()

    # ---------------------------------------------------------- placement
    def _alive(self) -> List[int]:
        with self._lock:                # RLock: reentrant from _place
            down = self._dead | self._retired
            idxs = [i for i in range(len(self.proxies))
                    if i not in down and i not in self._draining]
            if idxs:
                return idxs
            # every live replica draining: they can still run work
            idxs = [i for i in range(len(self.proxies)) if i not in down]
        if not idxs:
            raise RuntimeError("no live replicas in the fleet")
        return idxs

    @staticmethod
    def _pin(pins: "collections.OrderedDict", key, idx: int) -> None:
        pins[key] = idx
        pins.move_to_end(key)
        while len(pins) > _MAX_PINS:
            pins.popitem(last=False)

    def _place(self, task: RolloutTask, *,
               exclude: Optional[int] = None) -> int:
        return self._place_with_pull(task, exclude=exclude)[0]

    def _place_with_pull(self, task: RolloutTask, *,
                         exclude: Optional[int] = None) -> tuple:
        """Pick the replica for a new submission: sessions stay where
        their radix-cached history lives, GRPO groups stay co-located,
        everything else goes least-outstanding-tokens.  A pin is honored
        only while the pinned replica can still EVER take the request —
        a session whose conversation outgrew its home's capacity (or whose
        home died) re-places (and re-pins) instead of queueing there.

        With ``cache_aware``, unpinned placement is two-tier: the replica
        holding the request's longest indexed prefix wins while its load
        is within ``cache_affinity_slack`` tokens of the fleet minimum;
        otherwise least-loaded wins and the second element of the returned
        ``(idx, pull_src)`` names a replica whose cached prefix should be
        pulled to ``idx`` before admission (None = no pull)."""
        plen = len(task.prompt_tokens)
        with self._lock:
            down = self._dead | self._retired
            sid = task.meta.get("session_id")
            if sid is not None:
                idx = self._session_home.get(sid)
                if idx is not None and idx not in self._draining \
                        and idx not in down and idx != exclude \
                        and self.proxies[idx].can_accept(
                            plen, task.max_new_tokens):
                    self.routed += 1
                    return idx, None
            gid = task.group_id
            if gid is not None and gid >= 0:
                idx = self._group_home.get(gid)
                if idx is not None and idx not in self._draining \
                        and idx not in down and idx != exclude \
                        and self.proxies[idx].can_accept(
                            plen, task.max_new_tokens):
                    self.routed += 1
                    return idx, None
            cands = [i for i in self._alive()
                     if self.proxies[i].can_accept(plen,
                                                   task.max_new_tokens)]
            if exclude is not None and len(cands) > 1:
                cands = [i for i in cands if i != exclude]
            if not cands:
                raise ValueError(
                    f"no replica can accept prompt_len={plen} "
                    f"max_new_tokens={task.max_new_tokens} (fleet of "
                    f"{len(self.proxies)}; shard capacity too small?)")
            pull_src: Optional[int] = None
            prefix: Dict[int, int] = {}
            if self.fleet_index is not None and plen > 1:
                # admission matches at most plen-1 tokens (the final token
                # always prefills for first logits) — query the same span
                prefix = self.fleet_index.best_prefix(
                    task.prompt_tokens[:plen - 1])
            if prefix:
                min_load = min(self.proxies[i].load() for i in cands)
                band = min_load + self.cache_affinity_slack
                affine = [i for i in cands if prefix.get(i, 0) > 0
                          and self.proxies[i].load() <= band]
                if affine:
                    # longest cached prefix wins inside the slack band
                    idx = max(affine, key=lambda i: (
                        prefix[i], -self.proxies[i].load(), -i))
                    self.cache_routed += 1
                else:
                    idx = min(cands, key=lambda i: (self.proxies[i].load(), i))
                    if self.cache_pull:
                        have = prefix.get(idx, 0)
                        srcs = [(n, -i) for i, n in prefix.items()
                                if i != idx and i not in down and n > have]
                        if srcs:
                            pull_src = -max(srcs)[1]
                            self.cache_pulls += 1
            else:
                idx = min(cands, key=lambda i: (self.proxies[i].load(), i))
            if sid is not None:
                self._pin(self._session_home, sid, idx)
            if gid is not None and gid >= 0:
                self._pin(self._group_home, gid, idx)
            self.routed += 1
            return idx, pull_src

    def _register(self, idx: int, rids, callback: Callable,
                  version: int) -> None:
        stranded: List[tuple] = []
        with self._lock:
            down = self._dead | self._retired
            for rid in (rids if isinstance(rids, list) else [rids]):
                if rid in self._early_resolved:
                    self._early_resolved.discard(rid)   # already resolved
                elif rid in self._home:
                    self._home[rid].idx = idx   # retained re-insert won race
                else:
                    rec = _Home(idx, callback, version)
                    if idx in down:
                        # the replica died between the dispatch liveness
                        # check and this registration: mark_dead already
                        # swept the map, so nobody else will fail this rid
                        # over — do it here or the handle hangs forever.
                        self._failed_over.add(rid)
                        stranded.append((rid, rec))
                    else:
                        self._home[rid] = rec
        if stranded:
            with self._lock:
                self.failovers += len(stranded)
        for rid, rec in stranded:   # callbacks OUTSIDE _lock
            rec.callback(GenerationResult(
                request_id=rid, task=None, tokens=None, logprobs=None,
                version_started=rec.version, aborted=True, partial=True,
                resumable=False))

    def _tracked(self, idx: int, callback: Callable,
                 version: int = 0) -> Callable:
        """Wrap the consumer callback so the rid→replica map follows each
        request's life: dropped on resolution, kept while retained pages
        park on the replica (resume/release must find them).  A request
        resolving before ``_register`` runs (the proxy loop won the race)
        is remembered so registration doesn't leave a stale entry; a
        result arriving AFTER the rid was failed over is dropped — the
        synthesized failover abort already owns the handle."""
        def cb(res: GenerationResult) -> None:
            with self._lock:
                if res.request_id in self._failed_over:
                    self._failed_over.discard(res.request_id)
                    return
                if res.aborted and res.resumable:
                    rec = self._home.get(res.request_id)
                    if rec is not None:
                        rec.retained = True
                    else:
                        self._home[res.request_id] = _Home(
                            idx, callback, res.version_started, retained=True)
                elif self._home.pop(res.request_id, None) is None:
                    self._early_resolved.add(res.request_id)
            callback(res)
        return cb

    # --------------------------------------------------- admission control
    def _admit_or_reject(self, task: RolloutTask, n: int, version: int,
                         callback: Callable) -> Optional[List[int]]:
        """Fleet front door.  Stamps the absolute deadline, then either
        admits (returns None) or resolves the submission immediately with a
        typed ``Rejected`` (returns the rejected ids, callbacks already
        fired) — expired deadline, per-class bound, or total bound with
        nothing lower-priority left to shed.  Queue depths are lock-free
        snapshots, so bounds are approximate under concurrent submitters:
        a few requests over, never silent unbounded queueing."""
        slo = self.slo
        if slo is None:
            return None
        now = slo.clock()
        deadline_at = stamp_deadline(task, now)
        priority = getattr(task, "priority", PRIORITY_NORMAL)
        reason = None
        if slo.shed_expired and deadline_at is not None and now >= deadline_at:
            reason = "expired"
        if reason is None and slo.queue_limit_per_class is not None:
            depth = self.queue_depth_by_class.get(priority, 0)
            if depth + n > slo.queue_limit_per_class:
                reason = "queue_full"
        if reason is None and slo.queue_limit_total is not None:
            if self.num_pending + n > slo.queue_limit_total:
                if not self._shed_below(priority, n):
                    reason = "queue_full"
        if reason is None:
            return None
        with self._lock:
            self._rejected += n
        rejected_ids: List[int] = []
        for t in (expand_replicas(task, n) if n > 1 else [task]):
            rejected_ids.append(t.task_id)
            callback(Rejected(request_id=t.task_id, task=t, tokens=None,
                              logprobs=None, version_started=version,
                              aborted=True, partial=True, reason=reason))
        return rejected_ids

    def _shed_below(self, priority: int, n: int) -> bool:
        """Make room at the total bound: shed up to ``n`` queued requests
        of strictly lower priority, deepest-queued replicas first.  Returns
        True if any shed was issued (the arrival is then admitted — the
        shed lands asynchronously on the replica loop)."""
        shed = 0
        order = sorted(self._live(),
                       key=lambda i: -self.proxies[i].num_pending)
        for i in order:
            by_class = getattr(self.proxies[i], "pending_by_priority", None)
            if by_class is None or not hasattr(self.proxies[i], "shed_lowest"):
                continue
            lower = sum(c for p, c in by_class.items() if p < priority)
            while lower > 0 and shed < n:
                self.proxies[i].shed_lowest(priority)
                lower -= 1
                shed += 1
            if shed >= n:
                break
        return shed > 0

    # ------------------------------------------------------ proxy protocol
    def generate(self, task: RolloutTask, version: int,
                 callback: Callable[[GenerationResult], None],
                 stream_cb: Optional[Callable] = None):
        n = int(task.meta.get("num_return_sequences", 1))
        rejected_ids = self._admit_or_reject(task, n, version, callback)
        if rejected_ids is not None:
            return rejected_ids if n > 1 else rejected_ids[0]
        kw = {"stream_cb": stream_cb} if stream_cb is not None else {}
        while True:
            idx, pull_src = self._place_with_pull(task)
            if pull_src is not None:
                self._execute_pull(pull_src, idx, task.prompt_tokens)
            try:
                rids = self.proxies[idx].generate(
                    task, version, self._tracked(idx, callback, version),
                    **kw)
            except ReplicaDeadError:
                self.mark_dead(idx)     # stale probe: detected at dispatch
                continue
            self._register(idx, rids, callback, version)
            return rids

    def _execute_pull(self, src: int, dst: int, tokens) -> None:
        """Pull ``src``'s cached prefix pages for ``tokens`` into ``dst``'s
        radix cache ahead of the request's admission there.  Best-effort on
        both sides: the source exports whatever it still caches and the
        target skips the import under page pressure or across a weight
        epoch — and with threaded loops a pull landing mid-prefill is still
        adopted at the next page boundary (the engine's cached-prefix
        extension probe).  Runs OUTSIDE the router lock; ``deliver`` fires
        on the source's loop thread."""
        export = getattr(self.proxies[src], "export_prefix", None)
        imp = getattr(self.proxies[dst], "import_prefix", None)
        if export is None or imp is None:
            return

        def deliver(record: Optional[dict]) -> None:
            if record is None:
                return
            try:
                imp(record)
            except ReplicaDeadError:
                return
            t = record["transfer"]
            with self._lock:
                self.pages_transferred += t.num_pages
                self.transfer_bytes += t.nbytes

        try:
            export(tokens, deliver)
        except ReplicaDeadError:
            self.mark_dead(src)

    def generate_group(self, tasks: List[RolloutTask], version: int,
                       callback: Callable[[GenerationResult], None]) -> List[int]:
        assert tasks, "empty group"
        if self.slo is not None:
            slo, now = self.slo, self.slo.clock()
            for t in tasks:
                stamp_deadline(t, now)
            t0 = tasks[0]
            priority = getattr(t0, "priority", PRIORITY_NORMAL)
            reason = None
            deadline_at = t0.meta.get("deadline_at")
            if slo.shed_expired and deadline_at is not None \
                    and now >= deadline_at:
                reason = "expired"
            if reason is None and slo.queue_limit_per_class is not None \
                    and self.queue_depth_by_class.get(priority, 0) \
                    + len(tasks) > slo.queue_limit_per_class:
                reason = "queue_full"
            if reason is None and slo.queue_limit_total is not None \
                    and self.num_pending + len(tasks) > slo.queue_limit_total \
                    and not self._shed_below(priority, len(tasks)):
                reason = "queue_full"
            if reason is not None:
                with self._lock:
                    self._rejected += len(tasks)
                for t in tasks:
                    callback(Rejected(
                        request_id=t.task_id, task=t, tokens=None,
                        logprobs=None, version_started=version,
                        aborted=True, partial=True, reason=reason))
                return [t.task_id for t in tasks]
        while True:
            idx = self._place(tasks[0])
            try:
                rids = self.proxies[idx].generate_group(
                    tasks, version, self._tracked(idx, callback, version))
            except ReplicaDeadError:
                self.mark_dead(idx)
                continue
            self._register(idx, rids, callback, version)
            return rids

    def generate_resumed(self, task: RolloutTask, version: int,
                         callback: Callable[[GenerationResult], None],
                         resume_from: int,
                         stream_cb: Optional[Callable] = None) -> int:
        """Resume ALWAYS lands on the replica holding the retained pages —
        they cannot re-attach anywhere else, so an unknown ``resume_from``
        is a caller bug and fails loudly (routed blind, the request would
        pend forever on a replica whose ``can_resume`` never passes).
        (Migration goes through ``generate_migrated`` instead.)  A home
        replica found dead here raises ``ReplicaDeadError`` — the client
        falls back to the concatenated re-prefill path."""
        with self._lock:
            rec = self._home.get(resume_from)
        if rec is None:
            raise ValueError(f"resume_from={resume_from} has no retained "
                             "pages on any replica known to this router")
        idx = rec.idx
        kw = {"stream_cb": stream_cb} if stream_cb is not None else {}
        try:
            rid = self.proxies[idx].generate_resumed(
                task, version, self._tracked(idx, callback, version),
                resume_from=resume_from, **kw)
        except ReplicaDeadError:
            self.mark_dead(idx)
            raise
        with self._lock:
            self._home.pop(resume_from, None)
        self._register(idx, rid, callback, version)
        return rid

    # ------------------------------------------------- resume migration
    def prefer_resume(self, resume_from: int, remaining: int) -> bool:
        """Continuation-placement feedback for the RolloutClient: True →
        resume in place (retained pages re-attach, zero re-prefill);
        False → the home replica is draining, dead, or overloaded enough
        that a concatenated re-prefill on another replica wins."""
        with self._lock:
            if resume_from in self._lost_retained:
                return False            # pages died with the replica
            rec = self._home.get(resume_from)
            if rec is None or len(self.proxies) == 1:
                return True
            idx = rec.idx
            if idx in self._draining or idx in self._dead \
                    or idx in self._retired:
                return False
            others = [i for i in self._alive() if i != idx]
        if not others:
            return True
        home_load = self.proxies[idx].load()
        low = min(self.proxies[i].load() for i in others)
        return home_load <= self.migrate_factor * low + self.migrate_margin_tokens

    def generate_migrated(self, task: RolloutTask, version: int,
                          callback: Callable[[GenerationResult], None],
                          release_from: int,
                          stream_cb: Optional[Callable] = None) -> int:
        """Cross-replica abort→resume migration, zero-re-prefill where
        possible.  The home replica's parked pages are exported to a
        host-side record, the target imports them and resumes the request
        in place — no token of the decoded prefix is recomputed.  When the
        transfer can't run (home dead/lost, loop-thread ownership, or the
        target rejects the import under page pressure / quant mismatch)
        the flow degrades to the previous behavior: route the client-built
        concatenated re-prefill (``task`` carries it in full) and let the
        target's radix cache make any previously seen prefix incremental.
        A migrated session re-pins to the target so its later turns find
        the freshly cached context.

        Placement is confirmed BEFORE the parked pages are released: when
        no replica can take the (grown) concatenated prompt this raises
        with the pages still retained, and the RolloutClient falls back to
        resuming in place.  The export is a host-side COPY, so releasing
        home's pages right after placement is safe regardless of when the
        target processes the import.  Pages that died with a crashed
        replica (``_lost_retained``) have nothing left to export or
        release."""
        with self._lock:
            rec = self._home.get(release_from)
            home = rec.idx if rec is not None else None
            lost_now = release_from in self._lost_retained
        record = None
        if (self.page_transfer and home is not None and not lost_now
                and home not in self._down()):
            export = getattr(self.proxies[home], "export_retained", None)
            if export is not None:
                try:
                    record = export(release_from)
                except ReplicaDeadError:
                    self.mark_dead(home)
                    record = None
        idx = self._place(task, exclude=home)     # may raise: nothing freed
        with self._lock:
            self._home.pop(release_from, None)
            lost = release_from in self._lost_retained
            self._lost_retained.discard(release_from)
        if home is not None and not lost and home not in self._down():
            try:
                self.proxies[home].release_retained(release_from)
            except ReplicaDeadError:
                self.mark_dead(home)
        with self._lock:
            sid = task.meta.get("session_id")
            if sid is not None:
                self._pin(self._session_home, sid, idx)
            gid = task.group_id
            if gid is not None and gid >= 0:
                self._pin(self._group_home, gid, idx)
            self.migrations += 1
        kw = {"stream_cb": stream_cb} if stream_cb is not None else {}
        while True:
            try:
                transferred = getattr(self.proxies[idx],
                                      "generate_transferred", None)
                if record is not None and transferred is not None:
                    rid = transferred(
                        task, version, self._tracked(idx, callback, version),
                        record=record, resume_from=release_from, **kw)
                    t = record["transfer"]
                    with self._lock:
                        self.pages_transferred += t.num_pages
                        self.transfer_bytes += t.nbytes
                else:
                    rid = self.proxies[idx].generate(
                        task, version, self._tracked(idx, callback, version),
                        **kw)
            except ReplicaDeadError:
                self.mark_dead(idx)
                idx = self._place(task, exclude=home)
                continue
            self._register(idx, rid, callback, version)
            return rid

    # ------------------------------------------------------------- control
    def abort(self, request_id: int, retain: bool = False) -> None:
        with self._lock:
            rec = self._home.get(request_id)
        if rec is not None:
            if rec.idx in self._down():
                return                  # already failed over / pages gone
            try:
                self.proxies[rec.idx].abort(request_id, retain=retain)
            except ReplicaDeadError:
                self.mark_dead(rec.idx)
            return
        for i in self._live():   # unknown rid: broadcast (no-op on misses)
            try:
                self.proxies[i].abort(request_id, retain=retain)
            except ReplicaDeadError:
                self.mark_dead(i)

    def abort_stale(self, min_version: int, retain: bool = False) -> None:
        for i in self._live():
            try:
                self.proxies[i].abort_stale(min_version, retain=retain)
            except ReplicaDeadError:
                self.mark_dead(i)

    def release_retained(self, request_id: int) -> None:
        with self._lock:
            rec = self._home.pop(request_id, None)
            self._lost_retained.discard(request_id)
        if rec is not None and rec.idx in self._down():
            return                      # pages died with the replica
        targets = [rec.idx] if rec is not None else self._live()
        for i in targets:
            try:
                self.proxies[i].release_retained(request_id)
            except ReplicaDeadError:
                self.mark_dead(i)

    def suspend(self) -> None:
        for i in self._live():
            self.proxies[i].suspend()

    def resume(self) -> None:
        for i in self._live():
            self.proxies[i].resume()

    def update_weights(self, params) -> None:
        with self._lock:
            self._last_weights = params
        for i in self._live():
            try:
                self.proxies[i].update_weights(params)
            except ReplicaDeadError:
                self.mark_dead(i)

    def update_weights_async(self, params) -> MultiEvent:
        """Stage the swap on EVERY live replica; the aggregate event is set
        once all of them acknowledge or die (fleet-wide overlapped sync
        that a mid-sync crash cannot deadlock)."""
        with self._lock:
            self._last_weights = params
        pairs = []
        for i in self._live():
            try:
                pairs.append((i, self.proxies[i].update_weights_async(params)))
            except ReplicaDeadError:
                self.mark_dead(i)
        return FleetSyncEvent(pairs, self)

    def drain(self, idx: int) -> None:
        """Mark a replica as draining: no new placements land on it and
        its retained abort victims migrate instead of resuming in place.
        In-flight requests run to completion."""
        with self._lock:
            self._draining.add(idx)

    def undrain(self, idx: int) -> None:
        with self._lock:
            self._draining.discard(idx)
            self._scaledown_pending.discard(idx)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ProxyRouter":
        with self._lock:
            self._started = True
        for i in self._live():
            try:
                self.proxies[i].start()
            except ReplicaDeadError:
                self.mark_dead(i)   # died before launch: fail its work over
        return self

    def stop(self) -> None:
        self._monitor_stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=10)
            self._monitor = None
        for p in self.proxies:
            p.stop()                    # dead/retired stops are no-ops
        with self._lock:
            self._started = False

    # ----------------------------------------------------------- auditing
    def fleet_audit(self, *, require_empty: bool = True) -> None:
        """``audit_pages``-style fleet invariant check (call at
        quiescence).  Asserts the rid→replica map holds no entry for a
        dead/retired replica and none the owning proxy doesn't know
        (active, pending, or retained) — the map must not leak entries for
        requests that already finished (e.g. via group-follower
        promotion).  With ``require_empty`` (default) the map must be
        EMPTY — nothing in flight, nothing parked; every live engine's
        ``audit_pages`` runs too."""
        with self._lock:
            entries = {rid: rec.idx for rid, rec in self._home.items()}
            down = self._dead | self._retired
            lost = set(self._lost_retained)
        assert not lost, f"lost-retained rids never reclaimed: {lost}"
        for rid, idx in entries.items():
            assert idx not in down, \
                f"rid {rid} still homed on down replica {idx}"
            owns = getattr(self.proxies[idx], "owns_request", None)
            assert owns is None or owns(rid), \
                f"rid {rid} leaked: replica {idx} does not know it"
        if require_empty:
            assert not entries, f"rid→replica map not empty: {entries}"
        for i in self._live():
            audit = getattr(self.proxies[i].engine, "audit_pages", None)
            if audit is not None:
                audit()
        # fleet index ↔ local radix trees: the index must attribute to each
        # live replica EXACTLY the content paths its local cache holds — no
        # stale entries surviving evictions or weight-sync flushes, nothing
        # cached that placement can't see.
        if self.fleet_index is not None:
            for i in self._live():
                cache = getattr(self.proxies[i].engine, "prefix_cache", None)
                if cache is None or not hasattr(cache, "paths"):
                    continue
                local = set(cache.paths())
                indexed = self.fleet_index.paths_for(i)
                assert local == indexed, (
                    f"fleet index out of sync for replica {i}: "
                    f"missing={local - indexed} stale={indexed - local}")

    # -------------------------------------------------------------- metrics
    def load(self) -> int:
        return sum(self.proxies[i].load() for i in self._live())

    @property
    def num_replicas(self) -> int:
        return len(self.proxies)

    @property
    def num_active(self) -> int:
        return sum(self.proxies[i].num_active for i in self._live())

    @property
    def num_pending(self) -> int:
        return sum(self.proxies[i].num_pending for i in self._live())

    @property
    def queue_depth(self) -> int:
        """Fleet-wide submitted-but-unadmitted requests (live replicas)."""
        return self.num_pending

    @property
    def queue_depth_by_class(self) -> Dict[int, int]:
        """Fleet-wide queued request count per priority class."""
        depth: Dict[int, int] = {}
        for i in self._live():
            by_class = getattr(self.proxies[i], "pending_by_priority", None)
            if by_class is None:
                continue
            for priority, count in by_class.items():
                depth[priority] = depth.get(priority, 0) + count
        return depth

    @property
    def deadline_misses(self) -> int:
        """Expired rejections + enforced deadline timeouts, fleet-wide
        (counters survive replica death — sums run over ALL replicas)."""
        return sum(int(getattr(p, "deadline_misses", 0)) for p in self.proxies)

    @property
    def preemptions(self) -> int:
        return sum(int(getattr(p, "preemptions", 0)) for p in self.proxies)

    @property
    def long_tail_defers(self) -> int:
        return sum(int(getattr(p, "long_tail_defers", 0)) for p in self.proxies)

    @property
    def stall_aborts(self) -> int:
        return sum(int(getattr(p, "stall_aborts", 0)) for p in self.proxies)

    @property
    def rejected(self) -> int:
        """Typed Rejected resolutions: front-door bounces + replica-level
        sheds/expiries."""
        with self._lock:
            front_door = self._rejected
        return front_door + sum(int(getattr(p, "rejected", 0))
                                for p in self.proxies)

    @property
    def active_per_replica(self) -> List[int]:
        return [self.proxies[i].num_active for i in self._live()]

    @property
    def steps_executed(self) -> int:
        return sum(p.steps_executed for p in self.proxies)

    @property
    def requests_completed(self) -> int:
        return sum(p.requests_completed for p in self.proxies)

    @property
    def requests_aborted(self) -> int:
        return sum(p.requests_aborted for p in self.proxies)

    @property
    def suspend_count(self) -> int:
        return sum(p.suspend_count for p in self.proxies)

    @property
    def staged_weight_updates(self) -> int:
        return sum(p.staged_weight_updates for p in self.proxies)

    @property
    def oldest_active_version(self) -> Optional[int]:
        versions = [v for v in (self.proxies[i].oldest_active_version
                                for i in self._live())
                    if v is not None]
        return min(versions) if versions else None

    @property
    def cache_hit_tokens(self) -> int:
        return sum(p.cache_hit_tokens for p in self.proxies)

    @property
    def cache_stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for p in self.proxies:
            for k, v in p.cache_stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def replica_stats(self) -> List[Dict]:
        """Per-replica state/load/occupancy/staleness/cache view."""
        return [{
            "name": p.name,
            "state": self.replica_state(i),
            "load_tokens": p.load(),
            "active": p.num_active,
            "pending": p.num_pending,
            "completed": p.requests_completed,
            "aborted": p.requests_aborted,
            "oldest_active_version": p.oldest_active_version,
            "cache_hit_tokens": p.cache_hit_tokens,
            "pages_transferred": int(getattr(p, "pages_transferred", 0)),
            "transfer_bytes": int(getattr(p, "transfer_bytes", 0)),
            "draining": self.replica_state(i) == "draining",
        } for i, p in enumerate(self.proxies)]
