"""ProxyRouter: queue scheduling across a fleet of rollout replicas (§4.3).

The paper's headline rollout mechanism is *queue scheduling*: instead of
statically partitioning a batch across inference workers (and waiting for
the slowest partition — the long-tail straggler problem), every prompt is
dispatched individually to the least-loaded worker the moment it is
submitted.  This module scales the single proxy/engine rollout path to N
replicas behind one object that speaks the exact ``LLMProxy`` protocol, so
``RolloutClient``, ``RolloutProducer``, ``EnvManagerPool`` and the
``AsyncController`` consume a fleet without changes:

* **Queue scheduling** — ``generate`` routes each request to the replica
  with the least outstanding decode work (``LLMProxy.load()``, in tokens),
  subject to static admission feedback (``can_accept``: a request that can
  never fit a replica's page pool is not queued there).
* **Co-location** — the G candidates of a GRPO group land on ONE replica
  (COW prefix sharing is per-replica), and every turn of an agentic
  ``Session`` follows its predecessors (the radix prefix cache holding the
  conversation history is per-replica too).  Placement pins are LRU-capped.
* **Cross-replica abort→resume migration** — retained KV pages cannot move
  between replicas.  ``prefer_resume`` tells the RolloutClient whether an
  aborted-with-retain request should re-attach in place (the cheap default)
  or migrate; ``generate_migrated`` frees the parked pages on the home
  replica and routes the client-built concatenated re-prefill to a
  less-loaded one.  Migration triggers when the home replica is draining
  (``drain()``) or overloaded past ``migrate_factor``/``migrate_margin``.
* **Fleet-wide weight sync** — ``update_weights[_async]`` fan out to every
  replica; the staged variant returns an aggregate event that is set once
  ALL replicas acknowledge, so the controller advances the policy version
  exactly when the whole fleet holds the new weights.
* **Aggregated observability** — ``cache_stats``/``load``/``queue_depth``
  sum across replicas; ``replica_stats`` exposes the per-replica view
  (load, active/pending, staleness, cache hits, draining).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.core.llm_proxy import LLMProxy
from repro.core.types import GenerationResult, RolloutTask

# group/session placement memory; old pins evict LRU (a group whose pin
# evicted mid-flight merely loses co-location for later members, never
# correctness — assembly keys on group_id, not placement).
_MAX_PINS = 8192


class MultiEvent:
    """Aggregate of the per-replica staged weight-sync events: ``wait``
    returns True once EVERY replica has acknowledged its swap."""

    def __init__(self, events: List[threading.Event]):
        self._events = list(events)

    def is_set(self) -> bool:
        return all(e.is_set() for e in self._events)

    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        for e in self._events:
            left = (None if deadline is None
                    else max(0.0, deadline - time.monotonic()))
            if not e.wait(left):
                return False
        return True


class ProxyRouter:
    """N proxy/engine replicas behind the single-proxy protocol.

    ``migrate_factor`` / ``migrate_margin_tokens`` bound when an
    aborted-with-retain request migrates instead of resuming in place: the
    home replica must carry more than ``factor * min_load + margin``
    outstanding tokens (or be draining).  In-place resume re-attaches
    retained pages at zero prefill cost, so migration has to buy real
    rebalancing to be worth a concatenated re-prefill.
    """

    def __init__(self, proxies: List[LLMProxy], *,
                 migrate_factor: float = 2.0,
                 migrate_margin_tokens: int = 128):
        assert proxies, "router needs at least one replica"
        self.proxies = list(proxies)
        self.migrate_factor = migrate_factor
        self.migrate_margin_tokens = migrate_margin_tokens
        self._lock = threading.RLock()
        self._home: Dict[int, int] = {}        # request_id -> replica idx
        # requests whose callback resolved BEFORE _register could record
        # them (submit→resolve race on the proxy loop thread): _register
        # must not re-insert a mapping nobody will ever remove.
        self._early_resolved: set = set()
        self._group_home: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()
        self._session_home: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()
        self._draining: set = set()
        self.routed = 0
        self.migrations = 0

    # ---------------------------------------------------------- placement
    def _alive(self) -> List[int]:
        idxs = [i for i in range(len(self.proxies)) if i not in self._draining]
        return idxs or list(range(len(self.proxies)))

    @staticmethod
    def _pin(pins: "collections.OrderedDict", key, idx: int) -> None:
        pins[key] = idx
        pins.move_to_end(key)
        while len(pins) > _MAX_PINS:
            pins.popitem(last=False)

    def _place(self, task: RolloutTask, *,
               exclude: Optional[int] = None) -> int:
        """Pick the replica for a new submission: sessions stay where
        their radix-cached history lives, GRPO groups stay co-located,
        everything else goes least-outstanding-tokens.  A pin is honored
        only while the pinned replica can still EVER take the request —
        a session whose conversation outgrew its home's capacity re-places
        (and re-pins) instead of queueing there forever."""
        plen = len(task.prompt_tokens)
        with self._lock:
            sid = task.meta.get("session_id")
            if sid is not None:
                idx = self._session_home.get(sid)
                if idx is not None and idx not in self._draining \
                        and idx != exclude \
                        and self.proxies[idx].can_accept(
                            plen, task.max_new_tokens):
                    self.routed += 1
                    return idx
            gid = task.group_id
            if gid is not None and gid >= 0:
                idx = self._group_home.get(gid)
                if idx is not None and idx not in self._draining \
                        and idx != exclude \
                        and self.proxies[idx].can_accept(
                            plen, task.max_new_tokens):
                    self.routed += 1
                    return idx
            cands = [i for i in self._alive()
                     if self.proxies[i].can_accept(plen,
                                                   task.max_new_tokens)]
            if exclude is not None and len(cands) > 1:
                cands = [i for i in cands if i != exclude]
            if not cands:
                raise ValueError(
                    f"no replica can accept prompt_len={plen} "
                    f"max_new_tokens={task.max_new_tokens} (fleet of "
                    f"{len(self.proxies)}; shard capacity too small?)")
            idx = min(cands, key=lambda i: (self.proxies[i].load(), i))
            if sid is not None:
                self._pin(self._session_home, sid, idx)
            if gid is not None and gid >= 0:
                self._pin(self._group_home, gid, idx)
            self.routed += 1
            return idx

    def _register(self, idx: int, rids) -> None:
        with self._lock:
            for rid in (rids if isinstance(rids, list) else [rids]):
                if rid in self._early_resolved:
                    self._early_resolved.discard(rid)   # already resolved
                else:
                    self._home[rid] = idx

    def _tracked(self, idx: int, callback: Callable) -> Callable:
        """Wrap the consumer callback so the rid→replica map follows each
        request's life: dropped on resolution, kept while retained pages
        park on the replica (resume/release must find them).  A request
        resolving before ``_register`` runs (the proxy loop won the race)
        is remembered so registration doesn't leave a stale entry."""
        def cb(res: GenerationResult) -> None:
            with self._lock:
                if res.aborted and res.resumable:
                    self._home[res.request_id] = idx
                elif self._home.pop(res.request_id, None) is None:
                    self._early_resolved.add(res.request_id)
            callback(res)
        return cb

    # ------------------------------------------------------ proxy protocol
    def generate(self, task: RolloutTask, version: int,
                 callback: Callable[[GenerationResult], None],
                 stream_cb: Optional[Callable] = None):
        idx = self._place(task)
        kw = {"stream_cb": stream_cb} if stream_cb is not None else {}
        rids = self.proxies[idx].generate(task, version,
                                          self._tracked(idx, callback), **kw)
        self._register(idx, rids)
        return rids

    def generate_group(self, tasks: List[RolloutTask], version: int,
                       callback: Callable[[GenerationResult], None]) -> List[int]:
        assert tasks, "empty group"
        idx = self._place(tasks[0])
        rids = self.proxies[idx].generate_group(tasks, version,
                                                self._tracked(idx, callback))
        self._register(idx, rids)
        return rids

    def generate_resumed(self, task: RolloutTask, version: int,
                         callback: Callable[[GenerationResult], None],
                         resume_from: int,
                         stream_cb: Optional[Callable] = None) -> int:
        """Resume ALWAYS lands on the replica holding the retained pages —
        they cannot re-attach anywhere else, so an unknown ``resume_from``
        is a caller bug and fails loudly (routed blind, the request would
        pend forever on a replica whose ``can_resume`` never passes).
        (Migration goes through ``generate_migrated`` instead.)"""
        with self._lock:
            idx = self._home.get(resume_from)
        if idx is None:
            raise ValueError(f"resume_from={resume_from} has no retained "
                             "pages on any replica known to this router")
        kw = {"stream_cb": stream_cb} if stream_cb is not None else {}
        rid = self.proxies[idx].generate_resumed(
            task, version, self._tracked(idx, callback),
            resume_from=resume_from, **kw)
        with self._lock:
            self._home.pop(resume_from, None)
        self._register(idx, rid)
        return rid

    # ------------------------------------------------- resume migration
    def prefer_resume(self, resume_from: int, remaining: int) -> bool:
        """Continuation-placement feedback for the RolloutClient: True →
        resume in place (retained pages re-attach, zero re-prefill);
        False → the home replica is draining or overloaded enough that a
        concatenated re-prefill on another replica wins."""
        with self._lock:
            idx = self._home.get(resume_from)
            if idx is None or len(self.proxies) == 1:
                return True
            if idx in self._draining:
                return False
            others = [i for i in self._alive() if i != idx]
        if not others:
            return True
        home_load = self.proxies[idx].load()
        low = min(self.proxies[i].load() for i in others)
        return home_load <= self.migrate_factor * low + self.migrate_margin_tokens

    def generate_migrated(self, task: RolloutTask, version: int,
                          callback: Callable[[GenerationResult], None],
                          release_from: int,
                          stream_cb: Optional[Callable] = None) -> int:
        """Cross-replica abort→resume migration.  Retained KV pages cannot
        move between replicas: free them on the home replica and route the
        client-built concatenated re-prefill (original prompt + decoded
        prefix) to a less-loaded one.  The target's radix cache makes any
        prefix it has seen before incremental.  A migrated session re-pins
        to the target so its later turns find the freshly cached context.

        Placement is confirmed BEFORE the parked pages are released: when
        no replica can take the (grown) concatenated prompt this raises
        with the pages still retained, and the RolloutClient falls back to
        resuming in place."""
        with self._lock:
            home = self._home.get(release_from)
        idx = self._place(task, exclude=home)     # may raise: nothing freed
        with self._lock:
            self._home.pop(release_from, None)
        if home is not None:
            self.proxies[home].release_retained(release_from)
        with self._lock:
            sid = task.meta.get("session_id")
            if sid is not None:
                self._pin(self._session_home, sid, idx)
            gid = task.group_id
            if gid is not None and gid >= 0:
                self._pin(self._group_home, gid, idx)
            self.migrations += 1
        kw = {"stream_cb": stream_cb} if stream_cb is not None else {}
        rid = self.proxies[idx].generate(task, version,
                                         self._tracked(idx, callback), **kw)
        self._register(idx, rid)
        return rid

    # ------------------------------------------------------------- control
    def abort(self, request_id: int, retain: bool = False) -> None:
        with self._lock:
            idx = self._home.get(request_id)
        if idx is not None:
            self.proxies[idx].abort(request_id, retain=retain)
            return
        for p in self.proxies:     # unknown rid: broadcast (no-op on misses)
            p.abort(request_id, retain=retain)

    def abort_stale(self, min_version: int, retain: bool = False) -> None:
        for p in self.proxies:
            p.abort_stale(min_version, retain=retain)

    def release_retained(self, request_id: int) -> None:
        with self._lock:
            idx = self._home.pop(request_id, None)
        for p in (self.proxies if idx is None else [self.proxies[idx]]):
            p.release_retained(request_id)

    def suspend(self) -> None:
        for p in self.proxies:
            p.suspend()

    def resume(self) -> None:
        for p in self.proxies:
            p.resume()

    def update_weights(self, params) -> None:
        for p in self.proxies:
            p.update_weights(params)

    def update_weights_async(self, params) -> MultiEvent:
        """Stage the swap on EVERY replica; the aggregate event is set
        once all of them acknowledge (fleet-wide overlapped sync)."""
        return MultiEvent([p.update_weights_async(params)
                           for p in self.proxies])

    def drain(self, idx: int) -> None:
        """Mark a replica as draining: no new placements land on it and
        its retained abort victims migrate instead of resuming in place.
        In-flight requests run to completion."""
        with self._lock:
            self._draining.add(idx)

    def undrain(self, idx: int) -> None:
        with self._lock:
            self._draining.discard(idx)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ProxyRouter":
        for p in self.proxies:
            p.start()
        return self

    def stop(self) -> None:
        for p in self.proxies:
            p.stop()

    # -------------------------------------------------------------- metrics
    def load(self) -> int:
        return sum(p.load() for p in self.proxies)

    @property
    def num_replicas(self) -> int:
        return len(self.proxies)

    @property
    def num_active(self) -> int:
        return sum(p.num_active for p in self.proxies)

    @property
    def num_pending(self) -> int:
        return sum(p.num_pending for p in self.proxies)

    @property
    def queue_depth(self) -> int:
        """Fleet-wide submitted-but-unadmitted requests."""
        return self.num_pending

    @property
    def steps_executed(self) -> int:
        return sum(p.steps_executed for p in self.proxies)

    @property
    def requests_completed(self) -> int:
        return sum(p.requests_completed for p in self.proxies)

    @property
    def requests_aborted(self) -> int:
        return sum(p.requests_aborted for p in self.proxies)

    @property
    def suspend_count(self) -> int:
        return sum(p.suspend_count for p in self.proxies)

    @property
    def staged_weight_updates(self) -> int:
        return sum(p.staged_weight_updates for p in self.proxies)

    @property
    def oldest_active_version(self) -> Optional[int]:
        versions = [v for v in (p.oldest_active_version for p in self.proxies)
                    if v is not None]
        return min(versions) if versions else None

    @property
    def cache_hit_tokens(self) -> int:
        return sum(p.cache_hit_tokens for p in self.proxies)

    @property
    def cache_stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for p in self.proxies:
            for k, v in p.cache_stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def replica_stats(self) -> List[Dict]:
        """Per-replica load/occupancy/staleness/cache view."""
        with self._lock:
            draining = set(self._draining)
        return [{
            "name": p.name,
            "load_tokens": p.load(),
            "active": p.num_active,
            "pending": p.num_pending,
            "completed": p.requests_completed,
            "aborted": p.requests_aborted,
            "oldest_active_version": p.oldest_active_version,
            "cache_hit_tokens": p.cache_hit_tokens,
            "draining": i in draining,
        } for i, p in enumerate(self.proxies)]
