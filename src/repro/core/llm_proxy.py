"""LLMProxy: command-driven event loop orchestrating an inference engine.

Mirrors the paper's §4.2 LLMProxy exactly:

* **Step-wise inference** — each loop iteration advances the engine by a
  single decode step over the whole active batch (continuous batching).
* **Post-processing** — completed requests immediately trigger the
  registered callback with the result.
* **Process commands** — ADD enqueues new requests; ABORT interrupts
  running requests and returns partials for reclamation into the
  SampleBuffer (recompute/resume under a newer policy version).

The proxy owns the engine thread-exclusively: all cross-thread interaction
goes through the command queue.  ``suspend``/``resume``/``update_weights``
implement the AsyncController's 3-phase weight synchronization.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Protocol

from repro.core.types import GenerationRequest, GenerationResult, RolloutTask


class InferenceEngine(Protocol):
    """Slot-based continuous-batching engine (see rollout/engine.py)."""

    @property
    def num_free_slots(self) -> int: ...

    def add_request(self, request_id: int, prompt_tokens, max_new_tokens: int) -> None: ...

    def abort(self, request_id: int) -> GenerationResult | Any: ...

    def step(self) -> List[Any]:
        """One decode step; returns finished (request_id, tokens, logprobs)."""
        ...

    def update_weights(self, params) -> None: ...


class LLMProxy:
    def __init__(self, engine: InferenceEngine, *, name: str = "llm_proxy"):
        self.engine = engine
        self.name = name
        self._commands: "queue.Queue[tuple]" = queue.Queue()
        self._pending: collections.deque[GenerationRequest] = collections.deque()
        self._active: Dict[int, GenerationRequest] = {}
        self._suspended = threading.Event()
        self._resumed = threading.Event()
        self._resumed.set()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._idle_sleep = 0.0005
        self.steps_executed = 0
        self.requests_completed = 0
        self.requests_aborted = 0

    # ------------------------------------------------------------- commands
    def generate(self, task: RolloutTask, version: int,
                 callback: Callable[[GenerationResult], None]) -> int:
        req = GenerationRequest(request_id=task.task_id, task=task,
                                version_started=version, callback=callback)
        self._commands.put(("ADD", req))
        return req.request_id

    def abort(self, request_id: int) -> None:
        self._commands.put(("ABORT", request_id))

    def abort_stale(self, min_version: int) -> None:
        """ABORT every in-flight request initiated before min_version."""
        self._commands.put(("ABORT_STALE", min_version))

    def suspend(self) -> None:
        """Pause the loop after the current engine step (weight-sync phase 1)."""
        self._resumed.clear()
        self._suspended.wait()

    def update_weights(self, params) -> None:
        """Weight-sync phase 2 (call between suspend and resume)."""
        assert self._suspended.is_set(), "update_weights requires suspend()"
        self.engine.update_weights(params)

    def resume(self) -> None:
        """Weight-sync phase 3."""
        self._suspended.clear()
        self._resumed.set()

    def stop(self) -> None:
        self._stop.set()
        self._resumed.set()
        if self._thread is not None:
            self._thread.join(timeout=30)

    # ------------------------------------------------------------ the loop
    def start(self) -> "LLMProxy":
        self._thread = threading.Thread(target=self.run_loop, name=self.name,
                                        daemon=True)
        self._thread.start()
        return self

    def run_loop(self) -> None:
        while not self._stop.is_set():
            if not self._resumed.is_set():
                # suspend handshake: acknowledge, park until resume()
                self._suspended.set()
                self._resumed.wait()
                self._suspended.clear()
            if self._stop.is_set():
                break
            self._process_commands()
            self._admit_pending()
            if not self._active:
                time.sleep(self._idle_sleep)
                continue
            finished = self.engine.step()
            self.steps_executed += 1
            for rid, tokens, logprobs in finished:
                req = self._active.pop(rid, None)
                if req is None:
                    continue
                self.requests_completed += 1
                req.callback(GenerationResult(
                    request_id=rid, task=req.task, tokens=tokens,
                    logprobs=logprobs, version_started=req.version_started))

    def _process_commands(self) -> None:
        while True:
            try:
                op, arg = self._commands.get_nowait()
            except queue.Empty:
                return
            if op == "ADD":
                self._pending.append(arg)
            elif op == "ABORT":
                self._do_abort(arg)
            elif op == "ABORT_STALE":
                stale = [rid for rid, r in self._active.items()
                         if r.version_started < arg]
                for rid in stale:
                    self._do_abort(rid)
                # pending (not yet started) requests simply re-tag: they will
                # start under the current weights.
                for r in self._pending:
                    r.version_started = max(r.version_started, arg)

    def _do_abort(self, request_id: int) -> None:
        req = self._active.pop(request_id, None)
        if req is not None:
            partial = self.engine.abort(request_id)
            self.requests_aborted += 1
            req.callback(GenerationResult(
                request_id=request_id, task=req.task,
                tokens=getattr(partial, "tokens", None),
                logprobs=getattr(partial, "logprobs", None),
                version_started=req.version_started,
                aborted=True, partial=True))
        else:
            # not yet admitted: drop from pending
            self._pending = collections.deque(
                r for r in self._pending if r.request_id != request_id)

    def _admit_pending(self) -> None:
        while self._pending and self.engine.num_free_slots > 0:
            req = self._pending.popleft()
            self.engine.add_request(req.request_id, req.task.prompt_tokens,
                                    req.task.max_new_tokens)
            self._active[req.request_id] = req

    # ------------------------------------------------------------- metrics
    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def num_pending(self) -> int:
        return len(self._pending)
