"""LLMProxy: command-driven event loop orchestrating an inference engine.

Mirrors the paper's §4.2 LLMProxy exactly:

* **Step-wise inference** — each loop iteration advances the engine by a
  single decode step over the whole active batch (continuous batching).
* **Post-processing** — completed requests immediately trigger the
  registered callback with the result.
* **Process commands** — ADD enqueues new requests; ABORT interrupts
  running requests and returns partials for reclamation into the
  SampleBuffer (recompute/resume under a newer policy version).

The proxy owns the engine thread-exclusively: all cross-thread interaction
goes through the command queue.  ``suspend``/``resume``/``update_weights``
implement the AsyncController's 3-phase weight synchronization.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Protocol

from repro.analysis.sanitizer import new_lock
from repro.core.slo import SLOConfig, stamp_deadline
from repro.core.types import (PRIORITY_NORMAL, GenerationRequest,
                              GenerationResult, NotifyingEvent, Rejected,
                              RolloutTask, expand_replicas)


class InferenceEngine(Protocol):
    """Continuous-batching engine (slot-based: rollout/engine.py; paged-KV
    with chunked prefill + COW prefix sharing: rollout/paged_engine.py).

    Optional capabilities, feature-detected by the proxy via getattr:

    * ``supports_retain`` (bool) — ``abort(rid, retain=True)`` parks the
      request's KV pages; ``resume_request(old_rid, new_rid, max_new)``
      re-attaches them (no prefix re-prefill); ``release_retained(rid)``
      frees parked pages; ``can_resume(rid, max_new)`` gates admission.
    * ``can_admit(prompt_len, max_new)`` — admission gate beyond free
      slots (e.g. page-pool headroom in the paged engine).
    * ``supports_group`` (bool) — ``submit_group([rids], prompt, max_new)``
      admits the G candidates of one prompt as a unit, prefilling the
      prompt ONCE and forking G decode lanes whose block tables alias the
      shared prefix pages (copy-on-write); ``can_admit_group(plen, G,
      max_new)`` gates it.  Engines without it get the group expanded into
      G independent requests by the proxy.
    """

    @property
    def num_free_slots(self) -> int: ...

    def add_request(self, request_id: int, prompt_tokens, max_new_tokens: int) -> None: ...

    def abort(self, request_id: int) -> GenerationResult | Any: ...

    def step(self) -> List[Any]:
        """One decode step; returns finished (request_id, tokens, logprobs)."""
        ...

    def update_weights(self, params) -> None: ...


@dataclasses.dataclass
class _PendingGroup:
    """G candidates of one prompt awaiting an all-or-nothing group admit."""
    requests: List[GenerationRequest]


class LLMProxy:
    def __init__(self, engine: InferenceEngine, *, name: str = "llm_proxy",
                 slo: Optional[SLOConfig] = None):
        self.engine = engine
        self.name = name
        self._slo = slo
        self._commands: "queue.Queue[tuple]" = queue.Queue()
        # entries: GenerationRequest | _PendingGroup
        self._pending: collections.deque = collections.deque()
        self._active: Dict[int, GenerationRequest] = {}
        self._suspended = threading.Event()
        self._resumed = threading.Event()
        self._resumed.set()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._idle_sleep = 0.0005
        self._num_streaming = 0          # active requests with a stream_cb
        # cheap load metric for fleet routers: outstanding decode work in
        # tokens (unprefilled prompt + unspent budget), updated at SUBMIT
        # time on the caller thread so a router sees its own placements
        # immediately (the command queue only drains on the loop thread).
        self._load_lock = new_lock("LLMProxy._load_lock")
        self._load_by_rid: Dict[int, int] = {}  # guarded-by: _load_lock
        self._outstanding_tokens = 0            # guarded-by: _load_lock
        self.steps_executed = 0
        self.requests_completed = 0
        self.requests_aborted = 0
        self.suspend_count = 0
        self.staged_weight_updates = 0   # non-blocking (overlapped) swaps
        # --- SLO counters (monotonic; aggregated fleet-wide by the router) ---
        self.deadline_misses = 0         # expired rejections + enforced timeouts
        self.preemptions = 0             # active work aborted-with-retain for priority
        self.long_tail_defers = 0        # detected long-tails parked to unblock others
        self.stall_aborts = 0            # no-decode-progress force-resolutions
        self.rejected = 0                # requests resolved with a typed Rejected

    # ------------------------------------------------------------- load
    def _load_add(self, request_id: int, tokens: int) -> None:
        with self._load_lock:
            self._load_by_rid[request_id] = tokens
            self._outstanding_tokens += tokens

    def _load_drop(self, request_id: int) -> None:
        with self._load_lock:
            self._outstanding_tokens -= self._load_by_rid.pop(request_id, 0)

    def _load_add_group(self, reqs: List[GenerationRequest]) -> None:
        """COW sharing prefills the prompt once: charge it to the leader
        only, so fleet load stays comparable across engine types."""
        for i, r in enumerate(reqs):
            self._load_add(r.request_id, r.task.max_new_tokens
                           + (len(r.task.prompt_tokens) if i == 0 else 0))

    def load(self) -> int:
        """Outstanding decode work admitted to this proxy, in tokens
        (prompt prefill + generation budget of every pending/active
        request).  Routers dispatch each request to the least-loaded
        replica (queue scheduling)."""
        with self._load_lock:
            return self._outstanding_tokens

    def can_accept(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Static admission feedback for routers: whether this replica
        could EVER take one request of this shape (sequence / page-pool
        capacity), independent of current load.  A request failing this
        must be routed elsewhere — queued here it would block the pending
        queue forever.  Group size doesn't enter: a group that fits only
        as singles is expanded by the admission path."""
        eng = self.engine
        max_total = getattr(eng, "max_total_len", None)
        if max_total is not None and prompt_len + max_new_tokens > max_total:
            return False
        fits = getattr(eng, "group_fits_pool", None)
        if fits is not None and not fits(prompt_len, 1, max_new_tokens):
            return False
        return True

    def owns_request(self, request_id: int) -> bool:
        """Whether this replica currently knows the request — active,
        queued pending, or parked as retained pages.  Fleet audits use
        this to prove the router's rid→replica map never leaks entries
        for requests that already finished.  Commands still in the
        submit queue are not visible: call at quiescence."""
        if request_id in self._active:
            return True
        while True:     # lock-free snapshot, same idiom as num_pending
            try:
                pending = [r.request_id for e in tuple(self._pending)
                           for r in self._entry_requests(e)]
                break
            except RuntimeError:
                continue
        if request_id in pending:
            return True
        return request_id in getattr(self.engine, "retained", {})

    # ------------------------------------------------------------- commands
    def generate(self, task: RolloutTask, version: int,
                 callback: Callable[[GenerationResult], None],
                 stream_cb: Optional[Callable] = None):
        """Submit one task.  A task carrying ``meta["num_return_sequences"]
        = G > 1`` (the non-replicated group encoding) is expanded into G
        candidate requests sharing its group id — engines decode one
        sequence per request, so the proxy realizes the group as a group
        submission (COW sharing where supported); the callback then fires
        once per candidate.  Returns the request id (list of ids when
        expanded)."""
        n = int(task.meta.get("num_return_sequences", 1))
        if n > 1:
            if stream_cb is not None:
                # one stream_cb cannot disambiguate G interleaved candidate
                # streams — submit the replicas individually to stream them.
                raise ValueError("stream_cb is unsupported for "
                                 "num_return_sequences-expanded tasks")
            tasks = expand_replicas(task, n)
            if not self._admit_submission(tasks, version, callback):
                return [t.task_id for t in tasks]
            reqs = [GenerationRequest(request_id=t.task_id, task=t,
                                      version_started=version,
                                      callback=callback)
                    for t in tasks]
            self._load_add_group(reqs)
            self._commands.put(("ADD_GROUP", _PendingGroup(reqs)))
            return [r.request_id for r in reqs]
        if not self._admit_submission([task], version, callback):
            return task.task_id
        req = GenerationRequest(request_id=task.task_id, task=task,
                                version_started=version, callback=callback,
                                stream_cb=stream_cb)
        self._load_add(req.request_id,
                       len(task.prompt_tokens) + task.max_new_tokens)
        self._commands.put(("ADD", req))
        return req.request_id

    def generate_group(self, tasks: List[RolloutTask], version: int,
                       callback: Callable[[GenerationResult], None]) -> List[int]:
        """Submit the G candidates of ONE prompt as a single group.

        Engines with COW prefix sharing (``supports_group``) prefill the
        prompt once and fork G decode lanes sharing its KV pages; other
        engines transparently get G independent requests.  All tasks must
        carry the same prompt and budget (they are replicas)."""
        assert tasks, "empty group"
        t0 = tasks[0]
        assert all(t.max_new_tokens == t0.max_new_tokens
                   and len(t.prompt_tokens) == len(t0.prompt_tokens)
                   for t in tasks), "group tasks must be replicas"
        if not self._admit_submission(tasks, version, callback):
            return [t.task_id for t in tasks]
        reqs = [GenerationRequest(request_id=t.task_id, task=t,
                                  version_started=version, callback=callback)
                for t in tasks]
        self._load_add_group(reqs)
        self._commands.put(("ADD_GROUP", _PendingGroup(reqs)))
        return [r.request_id for r in reqs]

    def generate_resumed(self, task: RolloutTask, version: int,
                         callback: Callable[[GenerationResult], None],
                         resume_from: int,
                         stream_cb: Optional[Callable] = None) -> int:
        """Re-initiate an ABORTed-with-retain request: the engine re-attaches
        the retained KV pages instead of prefilling the prompt."""
        # no queue-bound admission: a continuation holds pages the fleet
        # wants back — rejecting it would leak them.  The watchdog still
        # sheds it from pending if its (inherited) deadline expires.
        if self._slo is not None:
            stamp_deadline(task, self._slo.clock())
        req = GenerationRequest(request_id=task.task_id, task=task,
                                version_started=version, callback=callback,
                                resume_from=resume_from, stream_cb=stream_cb)
        # no prefill work: the retained pages re-attach
        self._load_add(req.request_id, task.max_new_tokens)
        self._commands.put(("ADD", req))
        return req.request_id

    # ------------------------------------------- cross-replica page transfer
    def export_retained(self, request_id: int) -> Optional[dict]:
        """Host-side snapshot of a retained request's KV pages (for a
        router-directed migration to another replica).  The engine is only
        safe to touch from its own loop thread, so this degrades to None —
        and the caller to the concat re-prefill path — when invoked from
        anywhere else while the loop is running.  In practice migration runs
        either on this proxy's loop thread (the abort callback chain) or on
        the single driver thread of a lockstep fleet, so the fast path is
        the common case."""
        t = self._thread
        if (t is not None and t.is_alive()
                and threading.current_thread() is not t):
            return None
        export = getattr(self.engine, "export_retained", None)
        return None if export is None else export(request_id)

    def generate_transferred(self, task: RolloutTask, version: int,
                             callback: Callable[[GenerationResult], None],
                             record: dict, resume_from: int,
                             stream_cb: Optional[Callable] = None) -> int:
        """Submit a migrated continuation together with its exported KV
        record as ONE command: the loop imports the pages and queues the
        request as a resume — or, if the import is rejected at processing
        time (pool pressure, quant mismatch), degrades it in place to a
        plain re-prefill of ``task`` (which carries the full concatenated
        prompt).  Either way the request is admitted exactly once and can
        never hang on pages that failed to land."""
        if self._slo is not None:
            stamp_deadline(task, self._slo.clock())
        req = GenerationRequest(request_id=task.task_id, task=task,
                                version_started=version, callback=callback,
                                resume_from=resume_from, stream_cb=stream_cb)
        # charged as a resume (no prefill); _do_transfer adds the prompt
        # back if the import fails and the request degrades to re-prefill.
        self._load_add(req.request_id, task.max_new_tokens)
        if self._thread is None or not self._thread.is_alive():
            self._do_transfer(req, record)
        else:
            self._commands.put(("TRANSFER", (req, record)))
        return req.request_id

    def _do_transfer(self, req: GenerationRequest, record: dict) -> None:
        imp = getattr(self.engine, "import_retained", None)
        if imp is None or not imp(req.resume_from, record):
            # degrade: the task already carries the concatenated prompt —
            # admit it as a plain re-prefill and re-charge the prompt work.
            req.resume_from = None
            with self._load_lock:
                extra = len(req.task.prompt_tokens)
                self._load_by_rid[req.request_id] = \
                    self._load_by_rid.get(req.request_id, 0) + extra
                self._outstanding_tokens += extra
        self._enqueue_pending(req)

    def export_prefix(self, tokens, deliver: Callable[[Optional[dict]],
                                                      None]) -> None:
        """Snapshot this replica's cached prefix pages for ``tokens`` and
        hand the record to ``deliver`` (which typically forwards it to
        another proxy's ``import_prefix``).  Runs on the loop thread; fires
        inline when the loop isn't started (lockstep fleets)."""
        if self._thread is None or not self._thread.is_alive():
            self._do_export_prefix(tokens, deliver)
        else:
            self._commands.put(("EXPORT_PREFIX", (tokens, deliver)))

    def _do_export_prefix(self, tokens, deliver) -> None:
        export = getattr(self.engine, "export_prefix", None)
        deliver(None if export is None else export(tokens))

    def import_prefix(self, record: dict) -> None:
        """Admit a pulled prefix record into this replica's radix cache
        (best-effort: the engine skips it under page pressure or across a
        weight-epoch boundary)."""
        if self._thread is None or not self._thread.is_alive():
            imp = getattr(self.engine, "import_prefix", None)
            if imp is not None:
                imp(record)
        else:
            self._commands.put(("IMPORT_PREFIX", record))

    @property
    def pages_transferred(self) -> int:
        eng = self.engine
        return int(getattr(eng, "pages_transferred_in", 0)
                   + getattr(eng, "pages_transferred_out", 0))

    @property
    def transfer_bytes(self) -> int:
        eng = self.engine
        return int(getattr(eng, "transfer_bytes_in", 0)
                   + getattr(eng, "transfer_bytes_out", 0))

    def abort(self, request_id: int, retain: bool = False) -> None:
        self._commands.put(("ABORT", (request_id, retain)))

    def abort_stale(self, min_version: int, retain: bool = False) -> None:
        """ABORT every in-flight request initiated before min_version.

        ``retain=True`` (engines with ``supports_retain``) parks each
        victim's KV pages so the subsequent resume skips the prefix."""
        self._commands.put(("ABORT_STALE", (min_version, retain)))

    def release_retained(self, request_id: int) -> None:
        """Free the KV pages of a retained request that won't be resumed."""
        self._commands.put(("RELEASE", request_id))

    def shed_lowest(self, below_priority: int) -> None:
        """Evict the newest queued request of the lowest priority class
        strictly below ``below_priority`` (its callback fires with
        ``Rejected(reason="shed")``).  Routers use this to make room at the
        fleet-wide total bound for higher-priority arrivals."""
        self._commands.put(("SHED", below_priority))

    # ----------------------------------------------------- admission control
    def _admit_submission(self, tasks: List[RolloutTask], version: int,
                          callback: Callable) -> bool:
        """Admission control at the submit boundary (caller thread).  Stamps
        absolute deadlines, then rejects the submission outright — callback
        fired immediately with a typed ``Rejected`` — if its deadline is
        already past or the pending queue bounds leave no room.  Queue depth
        is read as a snapshot, so bounds are approximate under concurrent
        submitters (a few over, never silent unbounded growth)."""
        slo = self._slo
        if slo is None:
            return True
        now = slo.clock()
        for t in tasks:
            stamp_deadline(t, now)
        t0 = tasks[0]
        priority = getattr(t0, "priority", PRIORITY_NORMAL)
        reason = None
        deadline_at = t0.meta.get("deadline_at")
        if slo.shed_expired and deadline_at is not None and now >= deadline_at:
            reason = "expired"
        if reason is None and slo.queue_limit_per_class is not None:
            depth = self.pending_by_priority.get(priority, 0)
            if depth + len(tasks) > slo.queue_limit_per_class:
                reason = "queue_full"
        if reason is None and slo.queue_limit_total is not None:
            if self.num_pending + len(tasks) > slo.queue_limit_total:
                lower = self.pending_by_priority
                if any(c > 0 for p, c in lower.items() if p < priority):
                    # outranked work is queued: shed it (async command)
                    # instead of bouncing the higher-priority arrival.
                    for _ in range(len(tasks)):
                        self.shed_lowest(priority)
                else:
                    reason = "queue_full"
        if reason is None:
            return True
        for t in tasks:
            self.rejected += 1
            if reason == "expired":
                self.deadline_misses += 1
            callback(Rejected(request_id=t.task_id, task=t, tokens=None,
                              logprobs=None, version_started=version,
                              aborted=True, partial=True, reason=reason))
        return False

    def suspend(self) -> None:
        """Pause the loop after the current engine step (weight-sync phase 1)."""
        self.suspend_count += 1
        self._resumed.clear()
        self._suspended.wait()

    def update_weights(self, params) -> None:
        """Blocking weight-sync phase 2 (call between suspend and resume)."""
        assert self._suspended.is_set(), "update_weights requires suspend()"
        self.engine.update_weights(params)

    def update_weights_async(self, params) -> NotifyingEvent:
        """NON-BLOCKING weight sync: stage a parameter swap that the proxy
        loop applies between engine steps — rollout keeps advancing; there
        is no suspend barrier.  Returns an event set once the engine holds
        the new weights (a ``NotifyingEvent``: composite fleet waiters
        subscribe instead of polling).  (Do not mix with a concurrent
        ``suspend()``: a parked loop processes no commands.)"""
        done = NotifyingEvent()
        if self._thread is None or not self._thread.is_alive():
            # loop not running (tests, pre-start staging): apply inline
            self.engine.update_weights(params)
            self.staged_weight_updates += 1
            done.set()
            return done
        self._commands.put(("UPDATE", (params, done)))
        return done

    def resume(self) -> None:
        """Weight-sync phase 3."""
        self._suspended.clear()
        self._resumed.set()

    def healthy(self) -> bool:
        """Heartbeat/health-probe hook for fleet routers: True while the
        proxy can still make progress (loop thread alive, or not started —
        lockstep drivers step un-started proxies by hand)."""
        if self._stop.is_set():
            return False
        t = self._thread
        return t is None or t.is_alive()

    def stop(self) -> None:
        self._stop.set()
        self._resumed.set()
        if self._thread is not None:
            self._thread.join(timeout=30)

    # ------------------------------------------------------------ the loop
    def start(self) -> "LLMProxy":
        self._thread = threading.Thread(target=self.run_loop, name=self.name,
                                        daemon=True)
        self._thread.start()
        return self

    def run_loop(self) -> None:
        while not self._stop.is_set():
            if not self._resumed.is_set():
                # suspend handshake: acknowledge, park until resume()
                self._suspended.set()
                self._resumed.wait()
                self._suspended.clear()
            if self._stop.is_set():
                break
            if not self.step_once():
                time.sleep(self._idle_sleep)

    def step_once(self) -> bool:
        """One proxy iteration: drain commands, admit, and — if anything is
        active — run one engine step and dispatch completions.  ``run_loop``
        is exactly this under the suspend handshake; calling it directly
        (proxy thread NOT started) drives the proxy deterministically, which
        is what lockstep fleet benchmarks and parity tests need.  Returns
        True iff an engine step ran."""
        self._process_commands()
        if self._slo is not None:
            self._watchdog_tick()
            self._maybe_preempt()
        self._admit_pending()
        if not self._active:
            return False
        finished = self.engine.step()
        self.steps_executed += 1
        for rid, tokens, logprobs in finished:
            req = self._active.pop(rid, None)
            if req is None:
                continue
            if req.stream_cb is not None:
                self._num_streaming -= 1
                # flush the final decode step's tokens — the request is
                # no longer active, so _publish_streams won't see it.
                if len(tokens) > req.streamed:
                    req.stream_cb(list(tokens[req.streamed:]))
                    req.streamed = len(tokens)
            self.requests_completed += 1
            self._load_drop(rid)
            req.callback(GenerationResult(
                request_id=rid, task=req.task, tokens=tokens,
                logprobs=logprobs, version_started=req.version_started))
        if self._num_streaming > 0:
            self._publish_streams()
        return True

    def _publish_streams(self) -> None:
        """Push NEWLY decoded tokens (a delta per call) of stream-subscribed
        active requests — engines expose ``peek_tokens(rid, start)``;
        without it, subscribers only see per-leg chunks from the client
        layer.  The per-request cursor keeps this O(new tokens), not
        O(decoded), per step."""
        peek = getattr(self.engine, "peek_tokens", None)
        if peek is None:
            return
        for rid, req in list(self._active.items()):
            if req.stream_cb is None:
                continue
            delta = peek(rid, req.streamed)
            if delta:
                req.streamed += len(delta)
                req.stream_cb(delta)

    def _process_commands(self) -> None:
        while True:
            try:
                op, arg = self._commands.get_nowait()
            except queue.Empty:
                return
            if op == "ADD":
                self._enqueue_pending(arg)
            elif op == "ADD_GROUP":
                self._enqueue_pending(arg)
            elif op == "SHED":
                self._do_shed(arg)
            elif op == "ABORT":
                rid, retain = arg
                self._do_abort(rid, retain)
            elif op == "ABORT_STALE":
                min_version, retain = arg
                stale = [rid for rid, r in self._active.items()
                         if r.version_started < min_version]
                for rid in stale:
                    self._do_abort(rid, retain)
                # pending (not yet started) requests simply re-tag: they will
                # start under the current weights.
                for entry in self._pending:
                    for r in self._entry_requests(entry):
                        r.version_started = max(r.version_started, min_version)
            elif op == "RELEASE":
                release = getattr(self.engine, "release_retained", None)
                if release is not None:
                    release(arg)
            elif op == "TRANSFER":
                req, record = arg
                self._do_transfer(req, record)
            elif op == "EXPORT_PREFIX":
                tokens, deliver = arg
                self._do_export_prefix(tokens, deliver)
            elif op == "IMPORT_PREFIX":
                imp = getattr(self.engine, "import_prefix", None)
                if imp is not None:
                    imp(arg)
            elif op == "UPDATE":
                params, done = arg
                self.engine.update_weights(params)
                self.staged_weight_updates += 1
                done.set()

    def _do_abort(self, request_id: int, retain: bool = False) -> None:
        req = self._active.pop(request_id, None)
        if req is not None:
            if req.stream_cb is not None:
                self._num_streaming -= 1
            retain = retain and getattr(self.engine, "supports_retain", False)
            if retain:
                partial = self.engine.abort(request_id, retain=True)
            else:
                partial = self.engine.abort(request_id)
            self.requests_aborted += 1
            self._load_drop(request_id)
            req.callback(GenerationResult(
                request_id=request_id, task=req.task,
                tokens=getattr(partial, "tokens", None),
                logprobs=getattr(partial, "logprobs", None),
                version_started=req.version_started,
                aborted=True, partial=True,
                resumable=getattr(partial, "resumable", False)))
        else:
            # not yet admitted: drop from pending — free the retained pages
            # of a dropped resume request (nobody else will) and still fire
            # the callback with an empty aborted result so handle-layer
            # consumers always resolve.
            release = getattr(self.engine, "release_retained", None)
            for r in self._take_pending(request_id):
                if r.resume_from is not None and release is not None:
                    release(r.resume_from)
                self.requests_aborted += 1
                self._load_drop(r.request_id)
                r.callback(GenerationResult(
                    request_id=r.request_id, task=r.task, tokens=None,
                    logprobs=None, version_started=r.version_started,
                    aborted=True, partial=True))

    def _take_pending(self, request_id: int) -> List[GenerationRequest]:
        """Remove (and return) the pending request with this id, unwrapping
        it from a pending group if needed (the group's other members stay
        queued)."""
        taken: List[GenerationRequest] = []
        kept: collections.deque = collections.deque()
        for entry in self._pending:
            if isinstance(entry, _PendingGroup):
                hit = [r for r in entry.requests if r.request_id == request_id]
                entry.requests = [r for r in entry.requests
                                  if r.request_id != request_id]
                taken.extend(hit)
                if entry.requests:
                    kept.append(entry)
            elif entry.request_id == request_id:
                taken.append(entry)
            else:
                kept.append(entry)
        self._pending = kept
        return taken

    @staticmethod
    def _entry_requests(entry) -> List[GenerationRequest]:
        return entry.requests if isinstance(entry, _PendingGroup) else [entry]

    # --------------------------------------------------- SLO: priority queue
    @classmethod
    def _entry_priority(cls, entry) -> int:
        reqs = cls._entry_requests(entry)
        if not reqs:
            return PRIORITY_NORMAL
        return max(getattr(r.task, "priority", PRIORITY_NORMAL) for r in reqs)

    def _enqueue_pending(self, entry) -> None:
        """Insert by priority class, FIFO within a class: an entry lands
        after every queued entry of >= priority.  With uniform priorities
        (the default) this degenerates to a plain append, so non-SLO
        behavior is unchanged byte-for-byte."""
        priority = self._entry_priority(entry)
        if not self._pending or self._entry_priority(self._pending[-1]) >= priority:
            self._pending.append(entry)
            return
        items = list(self._pending)
        idx = next(i for i, e in enumerate(items)
                   if self._entry_priority(e) < priority)
        items.insert(idx, entry)
        self._pending = collections.deque(items)

    def _do_shed(self, below_priority: int) -> None:
        """Evict the newest pending entry of the lowest class < below."""
        cands = [(self._entry_priority(e), i)
                 for i, e in enumerate(self._pending)
                 if self._entry_priority(e) < below_priority]
        if not cands:
            return
        lowest = min(p for p, _ in cands)
        idx = max(i for p, i in cands if p == lowest)
        items = list(self._pending)
        entry = items.pop(idx)
        self._pending = collections.deque(items)
        for r in self._entry_requests(entry):
            self._reject_queued(r, "shed")

    def _reject_queued(self, req: GenerationRequest, reason: str) -> None:
        """Resolve an already-queued request with a typed Rejected (shed or
        expired-in-queue).  Retained pages of a rejected continuation are
        freed — its partial tokens are final."""
        release = getattr(self.engine, "release_retained", None)
        if req.resume_from is not None and release is not None:
            release(req.resume_from)
        self._load_drop(req.request_id)
        self.rejected += 1
        if reason == "expired":
            self.deadline_misses += 1
        req.callback(Rejected(request_id=req.request_id, task=req.task,
                              tokens=None, logprobs=None,
                              version_started=req.version_started,
                              aborted=True, partial=True, reason=reason))

    # ------------------------------------------------------- SLO: preemption
    def _decoded(self, request_id: int) -> int:
        """Tokens decoded so far in the CURRENT leg of an active request."""
        num_decoded = getattr(self.engine, "num_decoded", None)
        if num_decoded is not None:
            return int(num_decoded(request_id))
        peek = getattr(self.engine, "peek_tokens", None)
        if peek is not None:
            return len(peek(request_id, 0))
        return 0

    def _maybe_preempt(self) -> None:
        """If the head of the queue outranks active work and no slot is
        free, abort-with-retain the lowest-priority active request(s): the
        victim's pages park in the engine, its continuation re-queues at
        its own priority, and the high-priority head admits immediately.
        Zero re-prefill on resume — preemption is the abort/resume
        machinery pointed at priority inversion instead of staleness."""
        slo = self._slo
        if (slo is None or not slo.preempt or not self._pending
                or not getattr(self.engine, "supports_retain", False)):
            return
        entry = self._pending[0]
        reqs = self._entry_requests(entry)
        if not reqs:
            return
        head_priority = self._entry_priority(entry)
        need = len(reqs) - self.engine.num_free_slots
        if need <= 0:
            return
        # Preemption frees SLOTS, not pages: victims keep their retained
        # pages until resumed.  Only preempt when the page pool can cover
        # the head anyway (checked for one candidate — a group head that
        # still doesn't fit simply stays queued, no harm done).
        t0 = reqs[0].task
        cover = getattr(self.engine, "can_cover_pages", None)
        if cover is not None and not cover(len(t0.prompt_tokens),
                                           t0.max_new_tokens):
            return
        victims = sorted(
            ((rid, r) for rid, r in self._active.items()
             if getattr(r.task, "priority", PRIORITY_NORMAL) < head_priority),
            key=lambda kv: (getattr(kv[1].task, "priority", PRIORITY_NORMAL),
                            -(kv[1].task.max_new_tokens - self._decoded(kv[0]))))
        for rid, _ in victims[:need]:
            self.preemptions += 1
            self._do_abort(rid, retain=True)

    # --------------------------------------------------------- SLO: watchdog
    def _watchdog_tick(self) -> None:
        """Once per step: shed expired queued work, force-resolve active
        work past deadline or stalled, and defer detected long-tails."""
        slo = self._slo
        now = slo.clock()
        if slo.shed_expired and self._pending:
            expired = [r.request_id
                       for e in self._pending for r in self._entry_requests(e)
                       if r.task.meta.get("deadline_at") is not None
                       and now >= r.task.meta["deadline_at"]]
            for rid in expired:
                for r in self._take_pending(rid):
                    self._reject_queued(r, "expired")
        if not self._active:
            return
        if slo.enforce_deadlines:
            for rid, req in list(self._active.items()):
                deadline_at = req.task.meta.get("deadline_at")
                if deadline_at is not None and now >= deadline_at:
                    self._do_timeout(rid, stall=False)
        if slo.stall_timeout_s is None and slo.defer_after_tokens is None:
            return
        for rid, req in list(self._active.items()):
            if rid not in self._active:
                continue
            decoded = self._decoded(rid)
            # != not >: a resumed leg's count restarts below the old one.
            progressed = decoded != req.decoded_seen
            if progressed:
                req.decoded_seen = decoded
                req.last_progress = now
            if (slo.stall_timeout_s is not None and not progressed
                    and now - req.last_progress >= slo.stall_timeout_s):
                self._do_timeout(rid, stall=True)
                continue
            if (slo.defer_after_tokens is not None
                    and self._pending
                    and self.engine.num_free_slots <= 0
                    and not req.task.meta.get("slo_deferred")
                    and decoded >= slo.defer_after_tokens
                    and req.task.max_new_tokens - decoded >= slo.defer_min_remaining
                    and getattr(req.task, "priority", PRIORITY_NORMAL)
                    <= self._entry_priority(self._pending[0])
                    and getattr(self.engine, "supports_retain", False)):
                # Likely long-tail: park it (pages retained, resume later at
                # zero re-prefill) so queued peers aren't stuck behind it.
                # Tag the lineage so a rollout is deferred at most once.
                req.task.meta["slo_deferred"] = True
                self.long_tail_defers += 1
                self._do_abort(rid, retain=True)

    def _do_timeout(self, request_id: int, *, stall: bool) -> None:
        """Exactly-once forced resolution of an active request: pop it,
        release its pages (plain abort — nothing to resume), and fire the
        callback with the partial tokens and ``timed_out=True``.  The
        client layer sees timed_out and resolves WITHOUT a continuation."""
        req = self._active.pop(request_id, None)
        if req is None:
            return
        if req.stream_cb is not None:
            self._num_streaming -= 1
        partial = self.engine.abort(request_id)
        self.requests_aborted += 1
        if stall:
            self.stall_aborts += 1
        else:
            self.deadline_misses += 1
        self._load_drop(request_id)
        req.callback(GenerationResult(
            request_id=request_id, task=req.task,
            tokens=getattr(partial, "tokens", None),
            logprobs=getattr(partial, "logprobs", None),
            version_started=req.version_started,
            aborted=True, partial=True, resumable=False, timed_out=True))

    def _try_admit(self, req: GenerationRequest) -> bool:
        """Admit one request if the engine can take it right now."""
        if req.resume_from is not None:
            can_resume = getattr(self.engine, "can_resume", None)
            if can_resume is not None and not can_resume(
                    req.resume_from, req.task.max_new_tokens):
                return False
            self.engine.resume_request(req.resume_from, req.request_id,
                                       req.task.max_new_tokens)
            return True
        can_admit = getattr(self.engine, "can_admit", None)
        if can_admit is not None and not can_admit(
                len(req.task.prompt_tokens), req.task.max_new_tokens):
            return False
        self.engine.add_request(req.request_id, req.task.prompt_tokens,
                                req.task.max_new_tokens)
        return True

    def _try_admit_group(self, grp: _PendingGroup):
        """All-or-nothing group admission.  Returns True (admitted), False
        (blocked — not enough slots/pages right now) or "expand" (the engine
        cannot take this group as a unit; split into singles)."""
        reqs = grp.requests
        if len(reqs) == 1:
            return True if self._try_admit(reqs[0]) else False
        eng = self.engine
        t = reqs[0].task
        if (not getattr(eng, "supports_group", False)
                or len(reqs) > getattr(eng, "num_slots", len(reqs))):
            return "expand"
        fits = getattr(eng, "group_fits_pool", None)
        if fits is not None and not fits(len(t.prompt_tokens), len(reqs),
                                         t.max_new_tokens):
            # the group can NEVER be admitted as a unit (pool too small):
            # expand instead of blocking the queue head forever.
            return "expand"
        if eng.num_free_slots < len(reqs):
            # All-or-nothing admission convoys here while the previous
            # group's lanes drain at different speeds.  Deliberate: letting
            # singles backfill would admit the next group's candidates
            # WITHOUT sharing, silently reverting the COW win.  Size
            # num_slots >= 2*G (the default settings do) so two groups
            # interleave and cover each other's drain.
            return False
        can = getattr(eng, "can_admit_group", None)
        if can is not None and not can(len(t.prompt_tokens), len(reqs),
                                       t.max_new_tokens):
            return False
        eng.submit_group([r.request_id for r in reqs], t.prompt_tokens,
                         t.max_new_tokens)
        return True

    def _activate(self, req: GenerationRequest) -> None:
        self._active[req.request_id] = req
        # record the engine's numeric config on the task at admission time:
        # samples produced from this request carry the quantization mode
        # their tokens were actually generated under, so buffer consumers /
        # StepStats can report mixed-precision batches after a mid-run
        # set_quant_mode change (stamped per leg — the LAST engine to
        # touch a resumed request wins, which is the engine that decoded
        # its reported tokens).
        task = req.task
        if task is not None and isinstance(getattr(task, "meta", None), dict):
            task.meta["quant_mode"] = self.quant_mode
            kv = getattr(self.engine, "kv_quant", "off")
            if kv != "off":
                task.meta["kv_quant"] = kv
        if self._slo is not None:
            req.last_progress = self._slo.clock()
        if req.stream_cb is not None:
            self._num_streaming += 1

    def _admit_pending(self) -> None:
        while self._pending and self.engine.num_free_slots > 0:
            entry = self._pending[0]
            if isinstance(entry, _PendingGroup):
                verdict = self._try_admit_group(entry)
                if verdict == "expand":
                    # engine can't take the group as a unit: requeue the
                    # members as ordinary head-of-queue requests.
                    self._pending.popleft()
                    self._pending.extendleft(reversed(entry.requests))
                    continue
                if verdict:
                    self._pending.popleft()
                    for r in entry.requests:
                        self._activate(r)
                    continue
            elif self._try_admit(entry):
                self._pending.popleft()
                self._activate(entry)
                continue
            # Head is blocked (e.g. page-starved).  Resume requests further
            # back MUST be allowed to bypass it: they re-attach pages that
            # are already allocated and are often the only way pages ever
            # free up again — strict FIFO here would deadlock the pool.
            admitted_any = False
            for e in list(self._pending):
                if self.engine.num_free_slots <= 0:
                    break
                if (isinstance(e, GenerationRequest) and e.resume_from is not None
                        and self._try_admit(e)):
                    self._pending.remove(e)
                    self._activate(e)
                    admitted_any = True
            if not admitted_any:
                break

    # ------------------------------------------------------------- metrics
    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def num_pending(self) -> int:
        # metrics readers run off-thread while the loop mutates _pending;
        # retry the lock-free snapshot instead of serializing the hot path
        # (mutation windows are a few appends/pops — retries are rare).
        while True:
            try:
                return sum(len(self._entry_requests(e))
                           for e in tuple(self._pending))
            except RuntimeError:
                continue

    @property
    def pending_by_priority(self) -> Dict[int, int]:
        """Queued request count per priority class (lock-free snapshot,
        same idiom as num_pending)."""
        while True:
            try:
                depth: Dict[int, int] = {}
                for e in tuple(self._pending):
                    for r in self._entry_requests(e):
                        priority = getattr(r.task, "priority", PRIORITY_NORMAL)
                        depth[priority] = depth.get(priority, 0) + 1
                return depth
            except RuntimeError:
                continue

    @property
    def oldest_active_version(self) -> Optional[int]:
        """Policy version of the stalest in-flight request (None when
        idle) — per-replica staleness for fleet dashboards."""
        while True:
            try:
                versions = [r.version_started
                            for r in list(self._active.values())]
                break
            except RuntimeError:     # loop thread resized _active mid-copy
                continue
        return min(versions) if versions else None

    @property
    def quant_mode(self) -> str:
        """The engine's weight-quantization mode ("off" when unsupported)."""
        return getattr(self.engine, "quant_mode", "off")

    @property
    def cache_hit_tokens(self) -> int:
        """Prefill tokens the engine skipped via automatic prefix caching."""
        return getattr(self.engine, "cache_hit_tokens", 0)

    @property
    def cache_stats(self) -> Dict[str, int]:
        """Prefix-cache hit/miss counters (zeros on engines without one)."""
        eng = self.engine
        lookups = getattr(eng, "cache_lookups", 0)
        hits = getattr(eng, "cache_hits", 0)
        return {
            "lookups": lookups,
            "hits": hits,
            "misses": lookups - hits,
            "extension_hits": getattr(eng, "cache_ext_hits", 0),
            "hit_tokens": getattr(eng, "cache_hit_tokens", 0),
            "evicted_pages": getattr(eng, "cache_evicted_pages", 0),
            "pages_held": getattr(eng, "cache_pages_held", 0),
        }
