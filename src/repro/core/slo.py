"""SLO layer configuration: admission control, preemption, and the watchdog.

One ``SLOConfig`` is shared (by value) across the serving stack:

* **Admission control** (router front door, or a standalone proxy):
  ``queue_limit_per_class`` / ``queue_limit_total`` bound the pending
  queues; work that cannot be queued is resolved immediately with a typed
  :class:`~repro.core.types.Rejected` result instead of silently waiting.
  When the total bound is hit by a request that outranks queued work, the
  lowest-priority queued request is shed (``reason="shed"``) to make room.
* **Preemption** (proxy event loop): when the head of the pending queue
  outranks an active request and no slot is free, the lowest-priority
  active request is aborted WITH its KV pages retained, freeing a slot for
  the high-priority arrival; the victim's continuation re-queues at its own
  priority and later resumes at zero re-prefill cost.
* **Watchdog** (proxy event loop, once per ``step_once``):
  - pending work past its deadline is shed (``Rejected("expired")``),
  - active work past its deadline is force-resolved exactly once with
    ``timed_out=True`` (partial tokens, pages released),
  - active work whose decode made no progress for ``stall_timeout_s`` is
    treated the same (hung engine / stuck tool call),
  - active work that decoded ``defer_after_tokens`` with substantial budget
    left while others queue is deferred (abort-with-retain, re-queued) so
    detected long-tails never monopolize slots — RollPacker-style tail
    taming on top of the abort/resume machinery.

``clock`` is injectable so deterministic drivers (lockstep benchmarks,
tests) can express deadlines in rounds instead of wall-clock seconds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class SLOConfig:
    # --- admission control (None = unbounded) ---
    queue_limit_per_class: Optional[int] = None
    queue_limit_total: Optional[int] = None
    # --- scheduling ---
    preempt: bool = True             # high-priority arrivals evict low-priority decodes
    # --- watchdog ---
    enforce_deadlines: bool = True   # force-resolve active work past deadline_at
    shed_expired: bool = True        # drop queued work past deadline_at
    stall_timeout_s: Optional[float] = None   # no-decode-progress timeout (None = off)
    defer_after_tokens: Optional[int] = None  # long-tail defer threshold (None = off)
    defer_min_remaining: int = 4     # only defer if at least this much budget is left
    # --- router-level hang detection (real threads only) ---
    # A live replica with active work whose steps_executed counter has not
    # moved for this many WALL-CLOCK seconds is declared dead and failed
    # over (covers hung engine loops that still answer healthy()).  Must
    # exceed any legitimate pause (e.g. a blocking weight-sync suspend).
    replica_stall_s: Optional[float] = None
    # Time source for deadline / stall accounting (monotonic seconds).
    clock: Callable[[], float] = time.monotonic


def stamp_deadline(task, now: float) -> Optional[float]:
    """Return the task's absolute deadline, stamping it into
    ``meta["deadline_at"]`` on first sight.  Continuation legs copy meta, so
    the deadline is fixed at FIRST submission and survives abort->resume."""
    existing = task.meta.get("deadline_at")
    if existing is not None:
        return existing
    if getattr(task, "deadline_ms", None) is None:
        return None
    deadline_at = now + task.deadline_ms / 1000.0
    task.meta["deadline_at"] = deadline_at
    return deadline_at


def without_admission(slo: Optional[SLOConfig]) -> Optional[SLOConfig]:
    """Copy with queue bounds removed.  Behind a router the bounds are
    enforced fleet-wide at the front door; per-replica bounds would
    double-count and reject work the router already admitted."""
    if slo is None:
        return None
    return dataclasses.replace(
        slo, queue_limit_per_class=None, queue_limit_total=None)
