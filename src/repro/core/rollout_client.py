"""RolloutClient: the handle-based rollout programming surface (§4.2).

The raw ``LLMProxy`` speaks a callback protocol: ``generate(task, version,
cb)`` fires ``cb`` once per completion *or abort*, and every consumer used to
re-implement the abort→resume continuation by hand (token stitching, budget
clamping, ``resumed_tokens`` meta threading).  This module moves all of that
into one client layer so schedulers, env managers and user code consume
plain handles:

* ``submit(task) -> GenerationHandle`` — an awaitable result.
  ``handle.result(timeout)`` blocks for the final sample;
  ``handle.abort(retain=)`` cancels (``retain=False``) or interrupts with
  transparent re-admission (``retain=True``); ``handle.stream()`` iterates
  incremental token chunks.
* ``submit_group(tasks) -> GroupHandle`` — the G candidates of one GRPO
  prompt as a unit (COW prefix sharing on engines that support it).
* ``session(...) -> Session`` — first-class multi-turn agentic interaction:
  the session owns the conversation context (``turn``/``full`` modes), turns
  ride the radix prefix cache as incremental prefill, and every turn is
  version-tagged.

**Proxy-owned continuation.**  A request aborted under a newer policy
version (``LLMProxy.abort_stale``, or ``handle.abort(retain=True)``) is
transparently re-admitted by the client: paged engines re-attach the
retained KV pages (zero prefix re-prefill), slot engines re-prefill the
concatenated prefix.  Behind a ``ProxyRouter`` fleet, a retained request
whose home replica is draining or overloaded migrates to another replica
instead — the router TRANSFERS the parked pages to the target, which
resumes at zero re-prefill too (only when the transfer can't run does the
concatenated prefix re-prefill there).
The handle resolves EXACTLY once, with the
budget-clamped, logprob-stitched final result; ``result.legs`` tags each
leg with the policy version it was decoded under (what IS-based off-policy
correctors need).  Behaviour-policy logprobs of every leg are kept;
new-policy logprobs are recomputed by the trainer's forward pass, never
here.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.analysis.sanitizer import new_rlock
from repro.core.types import (GenerationResult, Rejected, RolloutTask,
                              expand_replicas, next_uid)

# The continuation path re-admits work on the proxy/router while holding the
# client lock (declared for concheck's cross-class cycle check):
# lock-order: RolloutClient._lock -> ProxyRouter._lock
# lock-order: RolloutClient._lock -> LLMProxy._load_lock

_SENTINEL = object()


def _np_tokens(x) -> np.ndarray:
    return (np.asarray(x, np.int32).ravel() if x is not None
            else np.zeros((0,), np.int32))


def _np_logprobs(x) -> np.ndarray:
    return (np.asarray(x, np.float32).ravel() if x is not None
            else np.zeros((0,), np.float32))


class GenerationHandle:
    """One submitted generation: resolves exactly once with the final,
    budget-clamped, logprob-stitched result — however many abort→resume
    legs it took to produce it."""

    def __init__(self, client: "RolloutClient", task: RolloutTask,
                 version: int, *, stream: bool = False):
        self._client = client
        self.task = task                     # the ORIGINAL task (leg 0)
        self.budget = int(task.max_new_tokens)
        self.orig_prompt = _np_tokens(task.prompt_tokens)
        self._tokens: List[np.ndarray] = []  # guarded-by: _client._lock — stitched per-leg chunks
        self._logprobs: List[np.ndarray] = []    # guarded-by: _client._lock
        self.legs: List[tuple] = []          # guarded-by: _client._lock — (version, tokens_in_leg)
        self._cur_rid = task.task_id         # guarded-by: _client._lock
        self._cur_version = version          # guarded-by: _client._lock
        self._streaming = stream
        self._emitted = 0                    # guarded-by: _client._lock — tokens pushed to stream queues
        self._done_len = 0                   # guarded-by: _client._lock — tokens across completed legs
        self._leg_tokens: List[np.ndarray] = []  # guarded-by: _client._lock — current leg's stream deltas
        self._leg_len = 0                    # guarded-by: _client._lock
        self._queues: List["queue.Queue"] = []   # guarded-by: _client._lock
        self._callbacks: List[Callable[[GenerationResult], None]] = []  # guarded-by: _client._lock
        self._cancelled = False              # guarded-by: _client._lock
        self._result: Optional[GenerationResult] = None  # guarded-by: _client._lock
        self._event = threading.Event()

    # ------------------------------------------------------------- waiting
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> GenerationResult:
        """Block for the final result (raises TimeoutError on timeout)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"generation {self.task.task_id} not done "
                               f"within {timeout}s")
        # the resolving thread writes _result strictly before _event.set():
        # Event.wait() returning True happens-after that write, so this
        # lock-free read observes the final value.
        # concheck: disable=guarded-by
        return self._result

    def add_done_callback(self, fn: Callable[[GenerationResult], None]) -> None:
        """Run ``fn(final_result)`` on resolution (immediately if already
        resolved).  Callbacks run on the proxy thread — keep them quick."""
        with self._client._lock:
            if self._result is None:
                self._callbacks.append(fn)
                return
            res = self._result
        fn(res)

    # ------------------------------------------------------------ aborting
    def abort(self, retain: bool = False) -> None:
        """``retain=False``: cancel — the handle resolves with the partial,
        aborted result and any retained pages are freed.  ``retain=True``:
        interrupt now, transparently re-admit (the continuation keeps the
        decoded prefix; on paged engines the KV pages are re-attached).

        Cancellation is best-effort and asynchronous: the cancel flag and
        the current leg's request id are taken under the client lock (so a
        concurrent continuation either sees the flag and stops, or has
        already swapped in the new id, which is then the one aborted), but
        a request that COMPLETES before the abort command lands resolves
        normally — the finished sample is not discarded."""
        with self._client._lock:
            if self._result is not None:
                return
            if not retain:
                self._cancelled = True
            rid = self._cur_rid
        self._client.proxy.abort(rid, retain=retain)

    # ----------------------------------------------------------- streaming
    def stream(self):
        """Iterator of incremental np.int32 token chunks, ending when the
        handle resolves.  Live per-step chunks require the handle to have
        been submitted with ``stream=True`` (and an engine that supports
        ``peek_tokens``); otherwise chunks arrive per completed leg."""
        q: "queue.Queue" = queue.Queue()
        with self._client._lock:
            if self._result is None:
                # catch up on everything decoded so far (one-time concat),
                # then live deltas keep the cursor in sync.
                parts = [*self._tokens, *self._leg_tokens]
                total = (np.concatenate(parts)[:self.budget] if parts
                         else np.zeros((0,), np.int32))
                if len(total) > self._emitted:
                    q.put(total[self._emitted:])
                    self._emitted = len(total)
                self._queues.append(q)
                q_live = None
            else:
                total = self._stitched_tokens()[:self.budget]
                q_live = total[self._emitted:]
                self._emitted = len(total)

        def gen():
            if q_live is not None:
                if len(q_live):
                    yield q_live
                return
            while True:
                chunk = q.get()
                if chunk is _SENTINEL:
                    return
                yield chunk
        return gen()

    # ------------------------------------------------- client-side internals
    # All _-methods below run under the client lock, on the proxy thread.
    def _stitched_tokens(self) -> np.ndarray:  # holds: _client._lock
        return (np.concatenate(self._tokens) if self._tokens
                else np.zeros((0,), np.int32))

    def _stitched_logprobs(self) -> np.ndarray:  # holds: _client._lock
        return (np.concatenate(self._logprobs) if self._logprobs
                else np.zeros((0,), np.float32))

    def _append_leg(self, tokens, logprobs, version: int) -> None:  # holds: _client._lock
        t = _np_tokens(tokens)
        self._tokens.append(t)
        self._logprobs.append(_np_logprobs(logprobs))
        self.legs.append((version, len(t)))
        self._done_len += len(t)
        self._leg_tokens = []
        self._leg_len = 0

    def _push_stream(self) -> List[tuple]:  # holds: _client._lock
        """Emit everything stitched beyond what streams have seen.  Returns
        deferred (queue, chunk) pairs — the caller delivers them OUTSIDE the
        client lock."""
        total = self._stitched_tokens()[:self.budget]
        # the cursor only advances when subscribers exist: a post-hoc
        # ``stream()`` on an unconsumed handle yields everything.
        if len(total) <= self._emitted or not self._queues:
            return []
        chunk = total[self._emitted:]
        self._emitted = len(total)
        return [(q, chunk) for q in self._queues]

    def _on_leg_tokens(self, delta) -> None:
        """Proxy-loop stream hook: the current leg's NEWLY decoded tokens
        (a delta — the proxy keeps the per-leg cursor), so a streaming
        request costs O(1) amortized per token, not O(decoded)."""
        delta = _np_tokens(delta)
        out: List[tuple] = []
        with self._client._lock:
            if self._result is not None or len(delta) == 0:
                return
            start_abs = self._done_len + self._leg_len
            self._leg_tokens.append(delta)
            self._leg_len += len(delta)
            if self._queues:
                lo = max(self._emitted - start_abs, 0)
                hi = min(self.budget - start_abs, len(delta))
                if hi > lo:
                    chunk = delta[lo:hi]
                    self._emitted = start_abs + hi
                    out = [(q, chunk) for q in self._queues]
        for q, c in out:
            q.put(c)

    def _resolve(self, *, aborted: bool, resumable: bool = False,  # holds: _client._lock
                 timed_out: bool = False,
                 rejected_reason: Optional[str] = None) -> None:
        """Build the final stitched result.  Caller holds the client lock;
        the returned closure (callbacks + stream flush) is run by the client
        after releasing it."""
        tokens = self._stitched_tokens()[:self.budget]
        logprobs = self._stitched_logprobs()[:self.budget]
        version = self.legs[-1][0] if self.legs else self._cur_version
        # published leg counts are clamped like tokens/logprobs, so they
        # exactly segment those arrays (per-leg IS-corrector slicing);
        # self.legs keeps the raw counts for budget accounting.
        legs, acc = [], 0
        for v, n in self.legs:
            take = max(0, min(n, len(tokens) - acc))
            legs.append((v, take))
            acc += take
        kwargs = dict(
            request_id=self.task.task_id, task=self.task, tokens=tokens,
            logprobs=logprobs, version_started=version, aborted=aborted,
            partial=aborted, resumable=resumable, legs=legs,
            timed_out=timed_out)
        if rejected_reason is not None:
            self._result = Rejected(reason=rejected_reason, **kwargs)
        else:
            self._result = GenerationResult(**kwargs)


class GroupHandle:
    """The G candidate handles of one prompt, submitted as a unit."""

    def __init__(self, handles: List[GenerationHandle]):
        self.handles = handles

    def done(self) -> bool:
        return all(h.done() for h in self.handles)

    def wait(self, timeout: Optional[float] = None) -> bool:
        import time as _t
        deadline = None if timeout is None else _t.monotonic() + timeout
        for h in self.handles:
            left = None if deadline is None else max(0.0, deadline - _t.monotonic())
            if not h.wait(left):
                return False
        return True

    def results(self, timeout: Optional[float] = None) -> List[GenerationResult]:
        if not self.wait(timeout):
            raise TimeoutError(f"group of {len(self.handles)} not done "
                               f"within {timeout}s")
        return [h.result(0) for h in self.handles]

    def abort(self, retain: bool = False) -> None:
        for h in self.handles:
            h.abort(retain=retain)

    def add_done_callback(self, fn) -> None:
        for h in self.handles:
            h.add_done_callback(fn)


class Session:
    """First-class multi-turn agentic interaction over a RolloutClient.

    The session owns the conversation context:

    * ``context_mode="turn"`` — each turn's prompt is the bare observation
      (for envs whose observation already encodes full state).
    * ``context_mode="full"`` — each turn resubmits the growing
      conversation (obs₀ a₀ obs₁ ... obsₜ); on an engine with automatic
      prefix caching this is *incremental prefill per turn* (the shared
      history aliases cached pages, only the new suffix is computed).
      ``max_context_tokens`` caps the prompt by dropping oldest turns.

    Each turn is version-tagged (``turn_versions``; multi-leg turns carry
    their full ``legs``), and an in-flight turn interrupted by a weight
    sync transparently resumes under the new version — the caller only
    ever sees the finished turn.
    """

    def __init__(self, client: "RolloutClient", *, session_id: int,
                 max_new_tokens: int, context_mode: str = "turn",
                 max_context_tokens: Optional[int] = None, group_id: int = -1,
                 priority: Optional[int] = None,
                 deadline_ms: Optional[float] = None):
        if context_mode not in ("turn", "full"):
            raise ValueError(f"context_mode must be turn|full, got {context_mode!r}")
        if context_mode == "full" and max_context_tokens is None:
            # an uncapped growing conversation would eventually overrun the
            # engine's sequence budget and assert inside the proxy thread.
            raise ValueError("context_mode='full' requires max_context_tokens")
        self.client = client
        self.session_id = session_id
        self.group_id = group_id
        self.max_new_tokens = max_new_tokens
        self.context_mode = context_mode
        self.max_context_tokens = max_context_tokens
        self.context: List[np.ndarray] = []   # alternating obs/action turns
        self.turn_versions: List[int] = []
        self.num_turns = 0
        self.priority = priority
        # per-TURN latency budget: each turn() stamps a fresh deadline
        # (an env step in between resets the clock, unlike a continuation).
        self.deadline_ms = deadline_ms

    def _build_prompt(self, obs: np.ndarray) -> np.ndarray:
        if self.context_mode != "full":
            return obs
        parts = list(self.context) + [obs]
        if self.max_context_tokens is not None:
            total = sum(len(p) for p in parts)
            while len(parts) > 1 and total > self.max_context_tokens:
                total -= len(parts.pop(0))   # drop oldest turns first
            if total > self.max_context_tokens:
                parts = [parts[0][-self.max_context_tokens:]]
        return np.concatenate(parts) if len(parts) > 1 else parts[0]

    def turn(self, obs_tokens,
             max_new_tokens: Optional[int] = None) -> GenerationHandle:
        """Submit one conversation turn; returns its handle.  On resolution
        the session appends (observation, action) to its context and
        records the turn's version tag — callers just ``.result()``."""
        obs = _np_tokens(obs_tokens)
        slo_kw = {}
        if self.priority is not None:
            slo_kw["priority"] = self.priority
        if self.deadline_ms is not None:
            slo_kw["deadline_ms"] = self.deadline_ms
        task = RolloutTask(
            task_id=next_uid(), prompt_id=self.session_id, replica_idx=0,
            prompt_tokens=self._build_prompt(obs),
            max_new_tokens=max_new_tokens or self.max_new_tokens,
            group_id=self.group_id,
            meta={"session_id": self.session_id, "turn": self.num_turns},
            **slo_kw)
        self.num_turns += 1
        handle = self.client.submit(task)

        def record(res: GenerationResult) -> None:
            if res.aborted:
                return
            self.context.append(obs)
            self.context.append(_np_tokens(res.tokens))
            self.turn_versions.append(res.version_started)

        handle.add_done_callback(record)
        return handle

    def reset(self) -> None:
        self.context = []
        self.turn_versions = []
        self.num_turns = 0


class RolloutClient:
    """Handle-issuing layer over an ``LLMProxy``.

    * ``version_fn`` — policy version used to tag new submissions and
      resume legs (pipelines pass the SampleBuffer's version).
    * ``resume_gate`` — continuation predicate: when it returns False an
      aborted request resolves instead of re-admitting (pipelines gate on
      buffer-closed / producer-stopped).
    """

    def __init__(self, proxy, *, version_fn: Optional[Callable[[], int]] = None,
                 resume_gate: Optional[Callable[[], bool]] = None):
        self.proxy = proxy
        self._version_fn = version_fn or (lambda: 0)
        self._resume_gate = resume_gate or (lambda: True)
        self._lock = new_rlock("RolloutClient._lock")
        self._inflight: Dict[int, GenerationHandle] = {}  # guarded-by: _lock
        self._closed = False             # guarded-by: _lock
        self.resumes = 0                 # guarded-by: _lock — retained-page re-attach legs
        self.reprefills = 0              # guarded-by: _lock — slot-engine concatenated-prefix legs
        self.migrations = 0              # guarded-by: _lock — cross-replica re-admission legs

    @classmethod
    def ensure(cls, proxy_or_client, **kwargs) -> "RolloutClient":
        """The proxy-or-client coercion every consumer needs: pass an
        existing RolloutClient through UNTOUCHED (the kwargs apply only
        when wrapping a raw LLMProxy — a pre-built client keeps its own
        version_fn / resume_gate, which is the point of passing one)."""
        if isinstance(proxy_or_client, cls):
            return proxy_or_client
        return cls(proxy_or_client, **kwargs)

    # ------------------------------------------------------------- submit
    def submit(self, task: RolloutTask, *, version: Optional[int] = None,
               stream: bool = False):
        """Submit one task; returns its ``GenerationHandle``.

        A task carrying ``meta["num_return_sequences"] = G > 1`` (the
        non-replicated group encoding from ``expand_tasks``) is expanded
        into G candidate handles and returns a ``GroupHandle`` — engines
        decode one sequence per request, so the group is realized as a COW
        group submission (or G singles on engines without group support).
        """
        n = int(task.meta.get("num_return_sequences", 1))
        if n > 1:
            if stream:
                raise ValueError("stream is unsupported for "
                                 "num_return_sequences-expanded tasks — "
                                 "submit the replicas individually")
            return self.submit_group(expand_replicas(task, n),
                                     version=version)
        v = self._version_fn() if version is None else version
        h = GenerationHandle(self, task, v, stream=stream)
        with self._lock:
            self._inflight[task.task_id] = h
        self.proxy.generate(task, v, self._dispatch,
                            **({"stream_cb": h._on_leg_tokens} if stream else {}))
        return h

    def submit_group(self, tasks: List[RolloutTask], *,
                     version: Optional[int] = None) -> GroupHandle:
        """Submit the G candidates of ONE prompt as a unit (COW prefix
        sharing where the engine supports it)."""
        assert tasks, "empty group"
        v = self._version_fn() if version is None else version
        handles = [GenerationHandle(self, t, v) for t in tasks]
        with self._lock:
            for t, h in zip(tasks, handles, strict=True):
                self._inflight[t.task_id] = h
        if len(tasks) > 1:
            self.proxy.generate_group(tasks, v, self._dispatch)
        else:
            self.proxy.generate(tasks[0], v, self._dispatch)
        return GroupHandle(handles)

    def session(self, *, session_id: Optional[int] = None,
                max_new_tokens: int, context_mode: str = "turn",
                max_context_tokens: Optional[int] = None,
                group_id: int = -1, priority: Optional[int] = None,
                deadline_ms: Optional[float] = None) -> Session:
        return Session(self, session_id=next_uid() if session_id is None
                       else session_id, max_new_tokens=max_new_tokens,
                       context_mode=context_mode,
                       max_context_tokens=max_context_tokens,
                       group_id=group_id, priority=priority,
                       deadline_ms=deadline_ms)

    def close(self) -> None:
        """Stop issuing continuations: subsequent aborts resolve their
        handles instead of re-admitting."""
        with self._lock:
            self._closed = True

    @property
    def num_inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    # ------------------------------------------------------- continuation
    def _dispatch(self, res: GenerationResult) -> None:
        """THE proxy callback: routes every leg's completion or abort to
        its handle and owns the abort→resume continuation."""
        deliver: List[tuple] = []
        fns: List = []
        final: Optional[GenerationResult] = None
        with self._lock:
            h = self._inflight.pop(res.request_id, None)
            if h is None:
                return
            if not res.aborted:
                h._append_leg(res.tokens, res.logprobs, res.version_started)
                h._resolve(aborted=False)
            else:
                h._append_leg(res.tokens, res.logprobs, res.version_started)
                decoded = sum(n for _, n in h.legs)
                remaining = h.budget - decoded
                # SLO terminal verdicts never continue: a timed-out request
                # had its pages released (partial tokens are final), and a
                # rejected one was refused admission — re-submitting it
                # would defeat the load shed.
                timed_out = bool(getattr(res, "timed_out", False))
                rejected_reason = res.reason if isinstance(res, Rejected) \
                    else None
                terminal = timed_out or rejected_reason is not None
                resume = (not terminal and not h._cancelled
                          and not self._closed and self._resume_gate())
                if resume and remaining > 0:
                    self._continue(h, res, remaining)
                    deliver = h._push_stream()
                    final = None
                else:
                    if res.resumable:
                        # parked pages nobody will re-attach
                        self.proxy.release_retained(res.request_id)
                    # budget spent => the sample is COMPLETE, not aborted:
                    # resuming would decode >= 1 extra token per cycle.
                    budget_done = (remaining <= 0 and not h._cancelled
                                   and not terminal)
                    h._resolve(aborted=not budget_done, timed_out=timed_out,
                               rejected_reason=rejected_reason)
            if h._result is not None:
                final = h._result
                deliver = h._push_stream()
                deliver += [(q, _SENTINEL) for q in h._queues]
                fns, h._callbacks = h._callbacks, []
        for q, chunk in deliver:
            q.put(chunk)
        if final is not None:
            # done callbacks run BEFORE the event trips so result() waiters
            # observe their effects (e.g. Session context updates); the
            # event is set even if a callback raises.
            try:
                for fn in fns:
                    fn(final)
            finally:
                h._event.set()

    def _continue(self, h: GenerationHandle, res: GenerationResult,  # holds: _lock
                  remaining: int) -> None:
        """Re-admit an interrupted request (caller holds the lock).  Paged
        engines re-attach the retained pages (zero prefix re-prefill);
        others re-prefill the concatenated prefix.  Behind a fleet router,
        a resumable request whose home replica is draining or overloaded
        (``prefer_resume`` → False) MIGRATES instead: the router transfers
        the parked pages to the target replica, which resumes at zero
        re-prefill.  The concatenated task built here is the transfer's
        fallback — when the pages can't move (crashed home, page pressure
        on the target) the target re-prefills it, incremental wherever its
        radix cache has seen the prefix."""
        new_rid = next_uid()
        version = self._version_fn()
        h._cur_rid = new_rid
        h._cur_version = version
        t = h.task
        # lineage tags the watchdog stamped on the CURRENT leg's task (the
        # long-tail defer marker) must survive into the next leg, whose
        # meta is copied from the leg-0 task.
        if res.task is not None and res.task.meta.get("slo_deferred") \
                and not t.meta.get("slo_deferred"):
            t.meta["slo_deferred"] = True
        stream = {"stream_cb": h._on_leg_tokens} if h._streaming else {}
        if res.resumable:
            prefer = getattr(self.proxy, "prefer_resume", None)
            if prefer is not None and not prefer(res.request_id, remaining):
                concat = RolloutTask(
                    task_id=new_rid, prompt_id=t.prompt_id,
                    replica_idx=t.replica_idx,
                    prompt_tokens=np.concatenate([h.orig_prompt,
                                                  h._stitched_tokens()]),
                    max_new_tokens=remaining, group_id=t.group_id,
                    meta=dict(t.meta), priority=t.priority,
                    deadline_ms=t.deadline_ms)
                self._inflight[new_rid] = h
                try:
                    self.proxy.generate_migrated(
                        concat, version, self._dispatch,
                        release_from=res.request_id, **stream)
                    self.migrations += 1
                    return
                except Exception:
                    # no replica can take the grown concatenated prompt;
                    # the pages are still parked (the router releases only
                    # after placing) — resume in place instead.
                    self._inflight.pop(new_rid, None)
            resumed = RolloutTask(
                task_id=new_rid, prompt_id=t.prompt_id,
                replica_idx=t.replica_idx, prompt_tokens=h.orig_prompt,
                max_new_tokens=remaining, group_id=t.group_id,
                meta=dict(t.meta), priority=t.priority,
                deadline_ms=t.deadline_ms)
            self._inflight[new_rid] = h
            try:
                self.proxy.generate_resumed(resumed, version, self._dispatch,
                                            resume_from=res.request_id,
                                            **stream)
                self.resumes += 1
                return
            except Exception:
                # the replica holding the retained pages died between the
                # abort and this resume (router raises: nothing left to
                # re-attach) — fall through to re-prefilling the
                # concatenated prefix on a live replica.
                self._inflight.pop(new_rid, None)
        self.reprefills += 1
        resumed = RolloutTask(
            task_id=new_rid, prompt_id=t.prompt_id, replica_idx=t.replica_idx,
            prompt_tokens=np.concatenate([h.orig_prompt,
                                          h._stitched_tokens()]),
            max_new_tokens=remaining, group_id=t.group_id, meta=dict(t.meta),
            priority=t.priority, deadline_ms=t.deadline_ms)
        self._inflight[new_rid] = h
        self.proxy.generate(resumed, version, self._dispatch, **stream)
