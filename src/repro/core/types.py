"""Shared dataclasses for the ROLL Flash pipeline."""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Callable, List, Optional

import numpy as np

_uid = itertools.count()


class NotifyingEvent(threading.Event):
    """A ``threading.Event`` that invokes subscriber callbacks on ``set()``.

    Lets composite waiters (e.g. the router's fleet-wide ``FleetSyncEvent``)
    park on their own condition and be woken push-style the moment any
    constituent event fires, instead of polling ``is_set()``.

    Callbacks run on the *setting* thread, outside any subscriber lock the
    callee wants to take — keep them tiny (a ``notify_all``).  A callback
    registered after ``set()`` fires immediately on the registering thread.
    Duplicate ``set()`` calls fire callbacks once."""

    def __init__(self) -> None:
        super().__init__()
        self._cbs_lock = threading.Lock()
        self._cbs: List[Callable[[], None]] = []  # guarded-by: _cbs_lock
        self._fired = False                       # guarded-by: _cbs_lock

    def on_set(self, cb: Callable[[], None]) -> None:
        with self._cbs_lock:
            if not self._fired:
                self._cbs.append(cb)
                return
        cb()

    def set(self) -> None:  # noqa: A003 - matching threading.Event API
        super().set()
        with self._cbs_lock:
            if self._fired:
                return
            self._fired = True
            cbs, self._cbs = self._cbs, []
        for cb in cbs:
            cb()

# Priority classes for SLO-aware scheduling.  Higher value = more important.
# Any int works as a priority; these three are the conventional tenant tiers.
PRIORITY_LOW = 0
PRIORITY_NORMAL = 1
PRIORITY_HIGH = 2


def next_uid() -> int:
    return next(_uid)


@dataclasses.dataclass
class RolloutTask:
    """One schedulable unit of generation (after prompt replication, one
    task == one candidate response; without it, one task == a whole group)."""
    task_id: int
    prompt_id: int
    replica_idx: int                 # which of the G candidates
    prompt_tokens: Any               # np.ndarray int32
    max_new_tokens: int
    group_id: int = -1
    meta: dict = dataclasses.field(default_factory=dict)
    # --- SLO fields (see core/slo.py) ---
    # Scheduling class: higher wins the queue and may preempt lower classes.
    priority: int = PRIORITY_NORMAL
    # Latency budget relative to FIRST submission.  The proxy/router stamp
    # the absolute deadline into meta["deadline_at"] once, so abort->resume
    # continuation legs (which copy meta) inherit the original deadline.
    deadline_ms: Optional[float] = None


def expand_replicas(task: "RolloutTask", n: int) -> "List[RolloutTask]":
    """Expand a non-replicated group task (meta ``num_return_sequences=G``)
    into G schedulable candidates sharing its group id.  Used by both the
    LLMProxy (raw callers) and the RolloutClient (handle callers) — engines
    decode one sequence per request, so the group is realized as a group
    submission."""
    meta = {k: v for k, v in task.meta.items() if k != "num_return_sequences"}
    return [RolloutTask(task_id=task.task_id if i == 0 else next_uid(),
                        prompt_id=task.prompt_id, replica_idx=i,
                        prompt_tokens=task.prompt_tokens,
                        max_new_tokens=task.max_new_tokens,
                        group_id=task.group_id, meta=dict(meta),
                        priority=task.priority, deadline_ms=task.deadline_ms)
            for i in range(n)]


@dataclasses.dataclass
class Sample:
    """A finished (prompt, response) pair flowing through the SampleBuffer."""
    sample_id: int
    prompt_id: int
    replica_idx: int
    prompt_tokens: Any               # np.ndarray int32 (P,)
    response_tokens: Any             # np.ndarray int32 (R,)
    logprobs: Any                    # np.ndarray f32 (R,) behaviour-policy logprobs
    reward: Optional[float] = None
    version_started: int = 0         # policy version that *initiated* generation
    version_finished: int = 0
    group_id: int = -1
    is_positive: bool = False
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def response_len(self) -> int:
        return int(np.asarray(self.response_tokens).shape[0])


@dataclasses.dataclass
class Turn:
    observation_tokens: Any
    action_tokens: Any
    logprobs: Any
    env_latency: float = 0.0


@dataclasses.dataclass
class Trajectory:
    """Agentic rollout: multi-turn env interaction."""
    traj_id: int
    env_id: int
    group_id: int
    turns: List[Turn] = dataclasses.field(default_factory=list)
    reward: Optional[float] = None
    version_started: int = 0
    version_finished: int = 0
    done: bool = False
    failed: bool = False

    def to_sample(self) -> Sample:
        prompt = np.concatenate([np.asarray(t.observation_tokens) for t in self.turns]) \
            if self.turns else np.zeros((0,), np.int32)
        resp = np.concatenate([np.asarray(t.action_tokens) for t in self.turns]) \
            if self.turns else np.zeros((0,), np.int32)
        lps = np.concatenate([np.asarray(t.logprobs) for t in self.turns]) \
            if self.turns else np.zeros((0,), np.float32)
        return Sample(
            sample_id=next_uid(), prompt_id=self.env_id, replica_idx=0,
            prompt_tokens=prompt, response_tokens=resp, logprobs=lps,
            reward=self.reward, version_started=self.version_started,
            version_finished=self.version_finished, group_id=self.group_id,
            is_positive=bool(self.reward and self.reward > 0),
        )


@dataclasses.dataclass
class GenerationRequest:
    """In-flight request inside the LLMProxy / engine."""
    request_id: int
    task: RolloutTask
    version_started: int
    callback: Callable[["GenerationResult"], None]
    # set on a resumed request: the retained (aborted) request_id whose
    # KV pages the engine re-attaches instead of prefilling the prompt.
    resume_from: Optional[int] = None
    # incremental-token subscriber: called from the proxy loop with the
    # request's NEWLY decoded tokens (a delta, this leg only) whenever
    # they grow.  None = no streaming overhead for this request.
    stream_cb: Optional[Callable[[Any], None]] = None
    streamed: int = 0                # tokens already pushed to stream_cb
    # SLO watchdog bookkeeping (proxy-loop private): decoded tokens seen at
    # the last watchdog tick, and the clock reading when they last grew.
    decoded_seen: int = 0
    last_progress: float = 0.0


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    task: RolloutTask
    tokens: Any                      # np int32 (R,)
    logprobs: Any                    # np f32 (R,)
    version_started: int
    aborted: bool = False
    partial: bool = False
    # ABORT with retained KV pages: the engine can resume this request
    # (by its request_id) without re-prefilling the decoded prefix.
    resumable: bool = False
    # filled by the RolloutClient on handle resolution: one (version,
    # num_tokens) entry per abort->resume leg the response was decoded
    # under.  None for raw engine/proxy results (single-leg, version ==
    # version_started).
    legs: Optional[List[tuple]] = None
    # SLO watchdog verdict: the request was force-resolved (deadline hit or
    # decode stalled).  Pages are RELEASED (not retained) — the partial
    # tokens are final and the client must not schedule a continuation.
    timed_out: bool = False


@dataclasses.dataclass
class Rejected(GenerationResult):
    """Typed admission-control outcome: the request never ran (or was shed
    from the queue).  Always ``aborted=True, partial=True`` with no tokens
    beyond previously-decoded legs; ``reason`` is one of ``"expired"``
    (deadline already/now past while queued), ``"queue_full"`` (per-class or
    total bound hit), or ``"shed"`` (evicted to admit higher-priority work)."""
    reason: str = ""
