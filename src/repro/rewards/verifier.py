"""RLVR reward workers: verifiable exact-match rewards.

Rewards are computed per-sample the moment its generation completes (queue
scheduling overlaps reward computation with ongoing decoding); the worker
is stateless and thread-safe.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.types import Sample
from repro.data.dataset import ArithmeticTask, decode_number


class ArithmeticVerifier:
    """Exact-match verifier: reward 1.0 iff the generated number equals the
    ground-truth answer parsed from the prompt itself.

    ``format_credit`` gives partial reward for a well-formed numeric answer
    (standard RLVR shaping — densifies the sparse exact-match signal so a
    small random-init policy can bootstrap)."""

    def __init__(self, task: Optional[ArithmeticTask] = None, *,
                 format_credit: float = 0.1):
        self.task = task or ArithmeticTask()
        self.format_credit = format_credit

    def __call__(self, sample: Sample) -> float:
        prob = self.task.problem_from_prompt(sample.prompt_tokens)
        if prob is None:
            return 0.0
        pred = decode_number(sample.response_tokens)
        if pred is None:
            return 0.0
        return 1.0 if pred == prob.answer else self.format_credit


class LengthPenaltyWrapper:
    """Optional shaping: subtract a small per-token cost (keeps responses
    short — useful to demonstrate reward composition)."""

    def __init__(self, inner, *, per_token: float = 0.0):
        self.inner = inner
        self.per_token = per_token

    def __call__(self, sample: Sample) -> float:
        r = self.inner(sample)
        return r - self.per_token * float(np.asarray(sample.response_tokens).size)
