from repro.rewards.verifier import ArithmeticVerifier, LengthPenaltyWrapper  # noqa: F401
