"""Concurrency correctness toolkit.

- :mod:`repro.analysis.static_check` — AST lock-discipline pass (layer 1),
  run via ``python tools/concheck.py``.
- :mod:`repro.analysis.sanitizer` — instrumented lock shim + dynamic
  lock-order graph (layer 2), activated by ``REPRO_SANITIZE=1`` or
  ``pytest --sanitize``.
- :mod:`repro.analysis.schedules` — seeded schedule perturbation that turns
  the test suite into a race fuzzer.
"""

from repro.analysis import sanitizer, schedules, static_check  # noqa: F401
