"""Runtime concurrency sanitizer: instrumented locks + dynamic lock-order graph.

Layer 2 of the concurrency toolkit (layer 1 is the static pass in
``static_check.py``).  Core modules create their locks through the factories
here::

    from repro.analysis.sanitizer import new_lock, new_rlock, new_condition

    self._lock = new_rlock("ProxyRouter._lock")

When the sanitizer is inactive (the default) the factories return plain
``threading`` primitives — zero overhead, byte-identical behaviour.  When
active (``REPRO_SANITIZE=1`` in the environment, ``pytest --sanitize``, or an
explicit :func:`enable` call *before* the objects under test are constructed)
they return tracked wrappers that record, per acquisition:

- the **dynamic lock-order graph**, keyed on the lock *name* (its lock class,
  e.g. ``"ProxyRouter._lock"``), not the instance — so an inversion between
  any two replicas' locks of the same class is still one edge;
- **order inversions**: acquiring ``b`` while holding ``a`` when the graph
  already contains a path ``b -> … -> a`` (the lockdep algorithm).  Nesting
  two *different instances* of the same lock class is reported as an
  inversion too (self-deadlock risk) — reentrant re-acquisition of the same
  instance is fine and ignored;
- **long hold times** (report-only): any hold exceeding
  ``REPRO_SANITIZE_HOLD_S`` seconds (default 0.2).

A :class:`~repro.analysis.schedules.SchedulePerturber` can be installed with
:func:`install_perturber`; it injects seeded randomized yields immediately
before every tracked acquisition, widening race windows so the ordinary test
suite doubles as a race fuzzer.

Thread-safety: the registry's own bookkeeping is guarded by an internal plain
``threading.Lock`` (never tracked, so it cannot recurse into itself); held
stacks are thread-local.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple

__all__ = [
    "enable",
    "enabled",
    "new_lock",
    "new_rlock",
    "new_condition",
    "install_perturber",
    "reset",
    "report",
    "assert_no_inversions",
    "graph_json",
    "TrackedLock",
    "TrackedRLock",
]

_active = os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def enable(flag: bool = True) -> None:
    """Turn tracking on/off for locks created *after* this call."""
    global _active
    _active = flag


def enabled() -> bool:
    return _active


class _HeldEntry:
    __slots__ = ("lock", "t_acquired", "count")

    def __init__(self, lock: "TrackedLock", t_acquired: float) -> None:
        self.lock = lock
        self.t_acquired = t_acquired
        self.count = 1


class _Registry:
    """Process-global dynamic lock-order graph + violation log."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._tls = threading.local()
        self.perturber: Optional[object] = None
        self.hold_threshold_s = float(os.environ.get("REPRO_SANITIZE_HOLD_S", "0.2"))
        self.reset()

    # -- per-thread held stack -------------------------------------------
    def _stack(self) -> List[_HeldEntry]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    # -- graph bookkeeping -----------------------------------------------
    def reset(self) -> None:
        with self._mu:
            # (held_name, acquired_name) -> observation count
            self.edges: Dict[Tuple[str, str], int] = {}
            self.inversions: List[dict] = []
            self.long_holds: List[dict] = []
            self.max_hold_s: Dict[str, float] = {}
            self.acquisitions = 0

    def _reachable(self, src: str, dst: str) -> bool:
        # DFS over the edge set; caller holds self._mu.
        seen = {src}
        frontier = [src]
        while frontier:
            node = frontier.pop()
            if node == dst:
                return True
            for a, b in self.edges:
                if a == node and b not in seen:
                    seen.add(b)
                    frontier.append(b)
        return dst in seen

    # -- hooks called by tracked locks -----------------------------------
    def before_acquire(self, lock: "TrackedLock") -> None:
        p = self.perturber
        if p is not None:
            p.maybe_yield(lock.name)  # type: ignore[attr-defined]

    def on_acquired(self, lock: "TrackedLock") -> None:
        st = self._stack()
        for entry in st:
            if entry.lock is lock:  # reentrant re-acquire of the same instance
                entry.count += 1
                return
        now = time.monotonic()
        held_names = [e.lock.name for e in st]
        with self._mu:
            self.acquisitions += 1
            for held in held_names:
                edge = (held, lock.name)
                if edge not in self.edges:
                    if held == lock.name or self._reachable(lock.name, held):
                        self.inversions.append(
                            {
                                "held": held,
                                "acquiring": lock.name,
                                "thread": threading.current_thread().name,
                                "held_stack": list(held_names),
                            }
                        )
                    self.edges[edge] = 0
                self.edges[edge] += 1
        st.append(_HeldEntry(lock, now))

    def on_release(self, lock: "TrackedLock") -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i].lock is lock:
                st[i].count -= 1
                if st[i].count == 0:
                    held = time.monotonic() - st[i].t_acquired
                    del st[i]
                    with self._mu:
                        if held > self.max_hold_s.get(lock.name, 0.0):
                            self.max_hold_s[lock.name] = held
                        if held > self.hold_threshold_s:
                            self.long_holds.append(
                                {
                                    "lock": lock.name,
                                    "held_s": round(held, 4),
                                    "thread": threading.current_thread().name,
                                }
                            )
                return
        # Release of a lock we never saw acquired (e.g. tracking enabled
        # mid-flight); ignore rather than corrupt the stack.

    # -- reporting --------------------------------------------------------
    def snapshot(self) -> dict:
        with self._mu:
            return {
                "edges": {f"{a} -> {b}": n for (a, b), n in sorted(self.edges.items())},
                "inversions": list(self.inversions),
                "long_holds": list(self.long_holds),
                "max_hold_s": dict(self.max_hold_s),
                "acquisitions": self.acquisitions,
            }


REGISTRY = _Registry()


class TrackedLock:
    """A named, non-reentrant mutex that reports to the global registry.

    Implements enough of the ``threading.Lock`` protocol to back a
    ``threading.Condition`` (which falls back to plain acquire/release when
    ``_release_save`` is absent — all of which route through our hooks, so a
    condition ``wait()`` correctly pops the lock from the held stack).
    """

    def __init__(self, name: str) -> None:
        self.name = name
        self._inner = threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        REGISTRY.before_acquire(self)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            REGISTRY.on_acquired(self)
        return ok

    def release(self) -> None:
        REGISTRY.on_release(self)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc: object) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<TrackedLock {self.name} locked={self.locked()}>"


class TrackedRLock:
    """A named reentrant mutex; implements the full Condition owner protocol."""

    def __init__(self, name: str) -> None:
        self.name = name
        self._inner = threading.RLock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        REGISTRY.before_acquire(self)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            REGISTRY.on_acquired(self)
        return ok

    def release(self) -> None:
        REGISTRY.on_release(self)
        self._inner.release()

    # Condition protocol: release the full recursion count around a wait.
    def _release_save(self):
        st = REGISTRY._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i].lock is self:
                count = st[i].count
                st[i].count = 1  # force on_release to fully pop the entry
                REGISTRY.on_release(self)
                state = self._inner._release_save()  # type: ignore[attr-defined]
                return (state, count)
        state = self._inner._release_save()  # type: ignore[attr-defined]
        return (state, 1)

    def _acquire_restore(self, saved) -> None:
        state, count = saved
        REGISTRY.before_acquire(self)
        self._inner._acquire_restore(state)  # type: ignore[attr-defined]
        REGISTRY.on_acquired(self)
        st = REGISTRY._stack()
        for entry in st:
            if entry.lock is self:
                entry.count = count
                break

    def _is_owned(self) -> bool:
        return self._inner._is_owned()  # type: ignore[attr-defined]

    def __enter__(self) -> "TrackedRLock":
        self.acquire()
        return self

    def __exit__(self, *exc: object) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<TrackedRLock {self.name}>"


# ---------------------------------------------------------------------------
# factories — what core modules call
# ---------------------------------------------------------------------------


def new_lock(name: str = "anonymous.Lock") -> threading.Lock:
    """A mutex: plain ``threading.Lock`` normally, tracked when sanitizing."""
    if not _active:
        return threading.Lock()
    return TrackedLock(name)  # type: ignore[return-value]


def new_rlock(name: str = "anonymous.RLock") -> threading.RLock:
    if not _active:
        return threading.RLock()
    return TrackedRLock(name)  # type: ignore[return-value]


def new_condition(lock=None, name: str = "anonymous.Condition"):
    """A condition variable, optionally sharing ``lock`` (tracked or plain).

    ``threading.Condition`` drives whatever lock it is given through the
    standard owner protocol, so handing it a tracked lock keeps the held
    stack correct across ``wait()``.
    """
    if lock is None and _active:
        lock = TrackedRLock(name + ".lock")
    return threading.Condition(lock)


def install_perturber(perturber) -> None:
    """Install (or clear, with ``None``) the schedule perturber."""
    REGISTRY.perturber = perturber


def reset() -> None:
    """Clear the recorded graph and violation log (e.g. between tests)."""
    REGISTRY.reset()


def report() -> dict:
    """Snapshot of edges, inversions, long holds and per-lock max hold."""
    return REGISTRY.snapshot()


def assert_no_inversions(context: str = "") -> None:
    rep = REGISTRY.snapshot()
    if rep["inversions"]:
        raise AssertionError(
            f"lock-order inversions detected{' in ' + context if context else ''}: "
            f"{rep['inversions']}"
        )


def graph_json() -> dict:
    """The dynamic lock-order graph in the same shape concheck emits."""
    rep = REGISTRY.snapshot()
    nodes = sorted(
        {a for (a, _b) in (e.split(" -> ") for e in rep["edges"])}
        | {b for (_a, b) in (e.split(" -> ") for e in rep["edges"])}
    )
    return {
        "source": "runtime",
        "nodes": nodes,
        "edges": [
            {"from": e.split(" -> ")[0], "to": e.split(" -> ")[1], "count": n}
            for e, n in rep["edges"].items()
        ],
        "inversions": rep["inversions"],
    }
