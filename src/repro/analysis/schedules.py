"""Seeded schedule perturbation: randomized yields at lock boundaries.

Installed into the runtime sanitizer (:mod:`repro.analysis.sanitizer`) with
``install_perturber``, a :class:`SchedulePerturber` sleeps for a small random
interval immediately before a fraction of tracked lock acquisitions.  That
widens the windows between "release lock" and "re-acquire lock" — exactly
where every hand-found race in PRs 4–7 lived — so running the ordinary test
suite under a perturber turns it into a race fuzzer.

Determinism: each thread gets its own ``random.Random`` seeded from
``(seed, thread_registration_order)``, so a given seed produces the same
per-thread decision *sequence* across runs.  (True interleavings still depend
on the OS scheduler; the seed makes the injected noise reproducible, not the
whole execution.)

Typical use::

    sanitizer.enable()
    sanitizer.install_perturber(SchedulePerturber(seed=7, p_yield=0.5))
    try:
        ...build components, run workload...
        sanitizer.assert_no_inversions()
    finally:
        sanitizer.install_perturber(None)
        sanitizer.enable(False)
"""

from __future__ import annotations

import random
import threading
import time
from typing import Optional

__all__ = ["SchedulePerturber"]


class SchedulePerturber:
    def __init__(
        self,
        seed: int = 0,
        p_yield: float = 0.1,
        max_sleep_s: float = 0.002,
        only_locks: Optional[set] = None,
    ) -> None:
        """
        Args:
          seed: base seed; combined with per-thread registration order.
          p_yield: probability of injecting a yield at each lock acquisition.
          max_sleep_s: injected sleeps are uniform in (0, max_sleep_s].
          only_locks: if given, only acquisitions of lock names in this set
            (exact match) are perturbed — lets a test target one component.
        """
        self.seed = seed
        self.p_yield = p_yield
        self.max_sleep_s = max_sleep_s
        self.only_locks = only_locks
        self._mu = threading.Lock()
        self._next_thread_idx = 0
        self._tls = threading.local()
        self.injected = 0  # total yields injected (approximate, unlocked add)

    def _rng(self) -> random.Random:
        rng = getattr(self._tls, "rng", None)
        if rng is None:
            with self._mu:
                idx = self._next_thread_idx
                self._next_thread_idx += 1
            rng = random.Random(self.seed * 1_000_003 + idx)
            self._tls.rng = rng
        return rng

    def maybe_yield(self, lock_name: str) -> None:
        if self.only_locks is not None and lock_name not in self.only_locks:
            return
        rng = self._rng()
        r = rng.random()
        if r < self.p_yield:
            self.injected += 1
            # Half the injections are pure scheduler yields, half real sleeps:
            # yields shuffle thread order cheaply, sleeps open wide windows.
            if r < self.p_yield * 0.5:
                time.sleep(0)
            else:
                time.sleep(rng.uniform(0.0, self.max_sleep_s) + 1e-5)
