"""Lock-discipline static analysis for the async fleet (stdlib ``ast`` only).

Layer 1 of the concurrency toolkit.  Driven by lightweight comment
directives in the source being checked:

``# guarded-by: <lock>``
    On a field assignment (usually in ``__init__``): every read/write of
    that ``self.<field>`` elsewhere in the class must happen inside a
    ``with self.<lock>`` scope.  ``<lock>`` may be dotted
    (``_client._lock``) to name a lock owned by a collaborator attribute.

``# holds: <lock>[, <lock>...]``
    On a ``def`` line: the method is documented to be called with the
    lock(s) already held (private helpers).  Checked bodies start with
    those locks in the held set.

``lock-order: A.x -> B.y`` (as a ``#``-comment)
    Module-level declaration of a cross-class acquisition edge the AST
    pass cannot see (e.g. a callback chain).  Participates in cycle
    detection.

``# concheck: disable=<rule>[,<rule>...]``
    Inline waiver for this line.  Always pair with a reason.

Rules
-----
- ``guarded-by``         guarded field accessed outside its lock
- ``lock-order``         cycle in the static lock-acquisition graph
- ``blocking-under-lock``  ``time.sleep`` / ``.wait()`` / ``.result()`` /
                         ``.join()`` / engine ``.step()`` while holding a lock
- ``cond-wait-loop``     ``Condition.wait`` not wrapped in a predicate loop
- ``thread-join``        ``threading.Thread`` started but never joined
- ``busy-wait``          polling loop (short constant ``time.sleep`` in a
                         ``while``, or ``while not x.wait(timeout=<short>)``)

Lock identity is canonical: ``ClassName.attr`` after resolving condition
aliases (``Condition(self._lock)`` counts as ``_lock``) and collaborator
types via ``__init__`` parameter annotations.  The extractor merges
with-statement nesting, same-class call-graph closure, ``# holds:``
context and declared ``# lock-order:`` edges into one graph and fails on
cycles.  Nested functions and lambdas are analyzed with an *empty* held
set (closures run later, possibly without the lock) — except predicates
passed to ``Condition.wait_for``, which run with the condition's lock held.
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["Violation", "CheckResult", "check_source", "check_paths", "RULES"]

RULES = (
    "guarded-by",
    "lock-order",
    "blocking-under-lock",
    "cond-wait-loop",
    "thread-join",
    "busy-wait",
)

_RE_DISABLE = re.compile(r"#\s*concheck:\s*disable=([\w\-, ]+)")
_RE_GUARDED = re.compile(r"#\s*guarded-by:\s*([\w.]+)")
_RE_HOLDS = re.compile(r"#\s*holds:\s*([\w., ]+)")
_RE_LOCK_ORDER = re.compile(r"#\s*lock-order:\s*([\w.]+)\s*->\s*([\w.]+)")

_LOCK_CTORS = {"Lock": "lock", "RLock": "rlock", "Condition": "condition", "Event": "event"}
_FACTORY_CTORS = {"new_lock": "lock", "new_rlock": "rlock", "new_condition": "condition"}

# Short sleeps/timeouts below these thresholds inside a loop are polling.
_BUSY_SLEEP_MAX_S = 0.05
_POLL_WAIT_MAX_S = 0.25


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


@dataclass
class CheckResult:
    violations: List[Violation]
    graph: dict

    @property
    def ok(self) -> bool:
        return not self.violations


@dataclass
class _ClassInfo:
    name: str
    path: str
    # attr -> kind ("lock" | "rlock" | "condition" | "event")
    locks: Dict[str, str] = field(default_factory=dict)
    # condition attr -> lock attr it wraps (Condition(self._lock))
    aliases: Dict[str, str] = field(default_factory=dict)
    # guarded field -> lock spec (possibly dotted), as written in the directive
    guarded: Dict[str, str] = field(default_factory=dict)
    # attr -> collaborator class name (from __init__ param annotations)
    attr_classes: Dict[str, str] = field(default_factory=dict)


class _FileCtx:
    def __init__(self, path: str, src: str) -> None:
        self.path = path
        self.lines = src.splitlines()
        self.tree = ast.parse(src, filename=path)

    def disabled(self, line: int) -> Set[str]:
        """Waivers on the reported line, or in pure-comment lines directly
        above it (room for a reasoned multi-line justification)."""
        out: Set[str] = set()
        if not 1 <= line <= len(self.lines):
            return out
        m = _RE_DISABLE.search(self.lines[line - 1])
        if m:
            out |= {r.strip() for r in m.group(1).split(",") if r.strip()}
        ln = line - 1
        while ln >= 1 and self.lines[ln - 1].lstrip().startswith("#"):
            m = _RE_DISABLE.search(self.lines[ln - 1])
            if m:
                out |= {r.strip() for r in m.group(1).split(",") if r.strip()}
            ln -= 1
        return out

    def line_directive(self, regex: re.Pattern, lo: int, hi: int) -> Optional[re.Match]:
        for ln in range(lo, min(hi, len(self.lines)) + 1):
            m = regex.search(self.lines[ln - 1])
            if m:
                return m
        return None


def _ann_to_name(node: Optional[ast.expr]) -> Optional[str]:
    """'RolloutClient' from annotations like RolloutClient, "RolloutClient",
    Optional["RolloutClient"]."""
    if node is None:
        return None
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        m = re.search(r"[A-Za-z_]\w*$", node.value.strip())
        return m.group(0) if m else None
    if isinstance(node, ast.Subscript):
        sl = node.slice
        if isinstance(sl, ast.Tuple) and sl.elts:
            return _ann_to_name(sl.elts[0])
        return _ann_to_name(sl)  # Optional[X] / list[X]
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _self_path(node: ast.expr) -> Optional[List[str]]:
    """['_client', '_lock'] for self._client._lock; None if not a self path."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name) and cur.id == "self":
        return list(reversed(parts))
    return None


def _const_number(node: ast.expr) -> Optional[float]:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return float(node.value)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _const_number(node.operand)
        return -inner if inner is not None else None
    return None


class _Analyzer:
    """Two-pass checker over a set of parsed files sharing a class registry."""

    def __init__(self) -> None:
        self.files: List[_FileCtx] = []
        self.classes: Dict[str, _ClassInfo] = {}
        self.violations: List[Violation] = []
        # lambdas already analyzed with a non-empty held set (wait_for preds)
        self._handled_lambdas: Set[int] = set()
        # canonical edges: (from, to) -> (path, line) of first observation
        self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        # (class, method) -> set of canonical locks acquired directly
        self.direct_acquires: Dict[Tuple[str, str], Set[str]] = {}
        # (class, method) -> set of same-class methods it calls
        self.self_calls: Dict[Tuple[str, str], Set[str]] = {}
        # deferred interprocedural edge requests:
        # (held snapshot, class, callee, path, line)
        self.deferred: List[Tuple[Set[str], str, str, str, int]] = []

    # ---------------- discovery ----------------

    def add_source(self, src: str, path: str) -> None:
        ctx = _FileCtx(path, src)
        self.files.append(ctx)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                self._discover_class(ctx, node)

    def _discover_class(self, ctx: _FileCtx, cls: ast.ClassDef) -> None:
        info = self.classes.setdefault(cls.name, _ClassInfo(cls.name, ctx.path))
        init_params: Dict[str, str] = {}
        for item in cls.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if item.name == "__init__":
                    for a in item.args.args + item.args.kwonlyargs:
                        nm = _ann_to_name(a.annotation)
                        if nm:
                            init_params[a.arg] = nm
                for sub in ast.walk(item):
                    if isinstance(sub, ast.Assign):
                        targets, value = sub.targets, sub.value
                    elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                        targets, value = [sub.target], sub.value
                    else:
                        continue
                    for tgt in targets:
                        p = _self_path(tgt)
                        if p is None or len(p) != 1:
                            continue
                        attr = p[0]
                        self._record_attr(ctx, info, init_params, attr, value, sub)

    def _record_attr(
        self,
        ctx: _FileCtx,
        info: _ClassInfo,
        init_params: Dict[str, str],
        attr: str,
        value: ast.expr,
        stmt: ast.stmt,
    ) -> None:
        end = getattr(stmt, "end_lineno", stmt.lineno) or stmt.lineno
        m = ctx.line_directive(_RE_GUARDED, stmt.lineno, end)
        if m:
            info.guarded[attr] = m.group(1)
        if isinstance(value, ast.Call):
            fn = value.func
            kind = None
            if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name) \
                    and fn.value.id == "threading" and fn.attr in _LOCK_CTORS:
                kind = _LOCK_CTORS[fn.attr]
            elif isinstance(fn, ast.Name) and fn.id in _LOCK_CTORS:
                kind = _LOCK_CTORS[fn.id]
            elif isinstance(fn, ast.Name) and fn.id in _FACTORY_CTORS:
                kind = _FACTORY_CTORS[fn.id]
            if kind:
                info.locks[attr] = kind
                if kind == "condition" and value.args:
                    wrapped = _self_path(value.args[0])
                    if wrapped and len(wrapped) == 1:
                        info.aliases[attr] = wrapped[0]
        elif isinstance(value, ast.Name) and value.id in init_params:
            info.attr_classes[attr] = init_params[value.id]

    # ---------------- lock identity ----------------

    def _canonical(self, cls: str, parts: List[str]) -> str:
        """Canonical lock id for a self-path within class ``cls``."""
        info = self.classes.get(cls)
        if info is None:
            return f"{cls}.{'.'.join(parts)}"
        if len(parts) == 1:
            attr = parts[0]
            seen = set()
            while attr in info.aliases and attr not in seen:
                seen.add(attr)
                attr = info.aliases[attr]
            return f"{cls}.{attr}"
        owner = info.attr_classes.get(parts[0])
        if owner is not None:
            return self._canonical(owner, parts[1:])
        return f"{cls}.{'.'.join(parts)}"

    def _lock_kind(self, cls: str, parts: List[str]) -> Optional[str]:
        info = self.classes.get(cls)
        if info is None:
            return None
        if len(parts) == 1:
            return info.locks.get(parts[0])
        owner = info.attr_classes.get(parts[0])
        if owner is not None:
            return self._lock_kind(owner, parts[1:])
        return None

    # ---------------- checking ----------------

    def check(self) -> CheckResult:
        for ctx in self.files:
            self._check_file(ctx)
        self._interprocedural_edges()
        self._cycle_check()
        self.violations.sort(key=lambda v: (v.path, v.line, v.rule))
        return CheckResult(self.violations, self._graph())

    def _check_file(self, ctx: _FileCtx) -> None:
        # declared cross-class edges
        for i, line in enumerate(ctx.lines, start=1):
            m = _RE_LOCK_ORDER.search(line)
            if m:
                self.edges.setdefault((m.group(1), m.group(2)), (ctx.path, i))
        self._check_thread_join(ctx)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        self._check_method(ctx, node.name, item)
        # loop rules also apply outside classes (module-level functions)
        for node in ctx.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._walk(ctx, cls=None, meth=node.name, body=node.body,
                           held=set(), in_while=False)

    def _report(self, ctx: _FileCtx, rule: str, line: int, msg: str) -> None:
        if rule in ctx.disabled(line):
            return
        self.violations.append(Violation(rule, ctx.path, line, msg))

    # -- per-method walk --

    def _check_method(
        self, ctx: _FileCtx, cls: str, fn: ast.FunctionDef
    ) -> None:
        held: Set[str] = set()
        end = fn.body[0].lineno if fn.body else fn.lineno
        m = ctx.line_directive(_RE_HOLDS, fn.lineno, max(fn.lineno, end - 1))
        if m:
            for spec in m.group(1).split(","):
                spec = spec.strip()
                if spec:
                    held.add(self._canonical(cls, spec.split(".")))
        key = (cls, fn.name)
        self.direct_acquires.setdefault(key, set())
        self.self_calls.setdefault(key, set())
        self._walk(ctx, cls, fn.name, fn.body, held, in_while=False,
                   skip_guard=(fn.name == "__init__"))

    def _walk(
        self,
        ctx: _FileCtx,
        cls: Optional[str],
        meth: str,
        body: List[ast.stmt],
        held: Set[str],
        in_while: bool,
        skip_guard: bool = False,
    ) -> None:
        for stmt in body:
            self._walk_stmt(ctx, cls, meth, stmt, held, in_while, skip_guard)

    def _walk_stmt(self, ctx, cls, meth, stmt, held, in_while, skip_guard) -> None:
        if isinstance(stmt, ast.With):
            new_held = set(held)
            for item in stmt.items:
                lock_id = self._with_lock_id(cls, item.context_expr)
                if lock_id is not None:
                    if cls is not None:
                        self.direct_acquires.setdefault((cls, meth), set()).add(lock_id)
                    for h in new_held:
                        if h != lock_id and (h, lock_id) not in self.edges:
                            self.edges[(h, lock_id)] = (ctx.path, stmt.lineno)
                    new_held = new_held | {lock_id}
                else:
                    self._walk_expr(ctx, cls, meth, item.context_expr, held,
                                    in_while, skip_guard)
            self._walk(ctx, cls, meth, stmt.body, new_held, in_while, skip_guard)
            return
        if isinstance(stmt, ast.While):
            self._check_busy_wait(ctx, cls, stmt, held)
            self._walk_expr(ctx, cls, meth, stmt.test, held, True, skip_guard)
            self._walk(ctx, cls, meth, stmt.body, held, True, skip_guard)
            self._walk(ctx, cls, meth, stmt.orelse, held, in_while, skip_guard)
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Nested function: runs later, not under current locks.
            inner_held: Set[str] = set()
            end = stmt.body[0].lineno if stmt.body else stmt.lineno
            m = ctx.line_directive(_RE_HOLDS, stmt.lineno, max(stmt.lineno, end - 1))
            if m and cls is not None:
                for spec in m.group(1).split(","):
                    if spec.strip():
                        inner_held.add(self._canonical(cls, spec.strip().split(".")))
            self._walk(ctx, cls, f"{meth}.<nested {stmt.name}>", stmt.body,
                       inner_held, False, skip_guard)
            return
        if isinstance(stmt, ast.ClassDef):
            return  # nested classes discovered separately
        # generic statement: walk its expressions/children
        for _child_field, value in ast.iter_fields(stmt):
            if isinstance(value, ast.expr):
                self._walk_expr(ctx, cls, meth, value, held, in_while, skip_guard)
            elif isinstance(value, list):
                if value and isinstance(value[0], ast.stmt):
                    self._walk(ctx, cls, meth, value, held, in_while, skip_guard)
                else:
                    for v in value:
                        if isinstance(v, ast.expr):
                            self._walk_expr(ctx, cls, meth, v, held, in_while,
                                            skip_guard)
                        elif isinstance(v, ast.stmt):
                            self._walk_stmt(ctx, cls, meth, v, held, in_while,
                                            skip_guard)
                        elif isinstance(v, ast.excepthandler):
                            self._walk(ctx, cls, meth, v.body, held, in_while,
                                       skip_guard)
                        elif isinstance(v, ast.withitem):  # pragma: no cover
                            self._walk_expr(ctx, cls, meth, v.context_expr, held,
                                            in_while, skip_guard)

    def _walk_expr(self, ctx, cls, meth, expr, held, in_while, skip_guard) -> None:
        for node in self._iter_expr(expr):
            if isinstance(node, ast.Lambda):
                if id(node) not in self._handled_lambdas:
                    self._walk_expr(ctx, cls, meth, node.body, set(), False,
                                    skip_guard)
                continue
            if isinstance(node, ast.Call):
                self._check_call(ctx, cls, meth, node, held, in_while)
            elif isinstance(node, ast.Attribute) and not skip_guard:
                self._check_guarded(ctx, cls, node, held)

    def _iter_expr(self, expr: ast.expr):
        """Walk an expression, NOT descending into lambdas (yielded whole) and
        special-casing Condition.wait_for predicates (handled in _check_call)."""
        stack = [expr]
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, ast.Lambda):
                continue  # caller decides the held set for the body
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    stack.append(child)
                elif isinstance(child, (ast.comprehension, ast.keyword,
                                        ast.FormattedValue)):
                    stack.append(child)  # type: ignore[arg-type]

    # -- rules --

    def _with_lock_id(self, cls: Optional[str], expr: ast.expr) -> Optional[str]:
        if cls is None:
            return None
        parts = _self_path(expr)
        if parts is None:
            return None
        kind = self._lock_kind(cls, parts)
        if kind in ("lock", "rlock", "condition"):
            return self._canonical(cls, parts)
        return None

    def _check_guarded(
        self, ctx: _FileCtx, cls: Optional[str], node: ast.Attribute, held: Set[str]
    ) -> None:
        if cls is None:
            return
        info = self.classes.get(cls)
        if info is None:
            return
        parts = _self_path(node)
        if parts is None or len(parts) != 1:
            return
        attr = parts[0]
        guard_spec = info.guarded.get(attr)
        if guard_spec is None:
            return
        guard_id = self._canonical(cls, guard_spec.split("."))
        if guard_id not in held:
            self._report(
                ctx, "guarded-by", node.lineno,
                f"{cls}.{attr} is guarded by {guard_id} but accessed without it "
                f"(held: {sorted(held) or 'nothing'})",
            )

    def _check_call(
        self, ctx: _FileCtx, cls: Optional[str], meth: str,
        node: ast.Call, held: Set[str], in_while: bool,
    ) -> None:
        fn = node.func
        # same-class call: defer interprocedural lock-order edges
        if (
            cls is not None
            and isinstance(fn, ast.Attribute)
            and isinstance(fn.value, ast.Name)
            and fn.value.id == "self"
        ):
            self.self_calls.setdefault((cls, meth), set()).add(fn.attr)
            if held:
                self.deferred.append((set(held), cls, fn.attr, ctx.path, node.lineno))

        if isinstance(fn, ast.Attribute):
            recv_parts = _self_path(fn.value) if cls is not None else None
            recv_kind = (
                self._lock_kind(cls, recv_parts) if (cls and recv_parts) else None
            )
            recv_is_held_cond = (
                recv_kind == "condition"
                and self._canonical(cls, recv_parts) in held  # type: ignore[arg-type]
            )
            # condition-wait predicate loop rule
            if fn.attr == "wait" and recv_kind == "condition" and not in_while:
                self._report(
                    ctx, "cond-wait-loop", node.lineno,
                    f"Condition.wait on self.{'.'.join(recv_parts)} outside a "
                    "while-predicate loop (spurious wakeups / missed signals)",
                )
            # wait_for predicates run WITH the condition's lock held
            if fn.attr == "wait_for" and recv_is_held_cond:
                lock_id = self._canonical(cls, recv_parts)  # type: ignore[arg-type]
                for arg in node.args:
                    if isinstance(arg, ast.Lambda):
                        self._handled_lambdas.add(id(arg))
                        self._walk_expr(ctx, cls, meth, arg.body,
                                        held | {lock_id}, in_while, False)
            # blocking-call-under-lock
            if held:
                self._check_blocking(ctx, fn, node, held, recv_is_held_cond)
        elif isinstance(fn, ast.Name) and held and fn.id == "sleep":
            self._report(
                ctx, "blocking-under-lock", node.lineno,
                f"sleep() while holding {sorted(held)}",
            )

    def _check_blocking(
        self, ctx: _FileCtx, fn: ast.Attribute, node: ast.Call,
        held: Set[str], recv_is_held_cond: bool,
    ) -> None:
        recv_src = ast.unparse(fn.value)
        if fn.attr == "sleep" and recv_src == "time":
            self._report(
                ctx, "blocking-under-lock", node.lineno,
                f"time.sleep while holding {sorted(held)}",
            )
        elif fn.attr in ("wait", "wait_for"):
            if not recv_is_held_cond:
                self._report(
                    ctx, "blocking-under-lock", node.lineno,
                    f"{recv_src}.{fn.attr}() while holding {sorted(held)} "
                    "(waiting on a foreign primitive under a lock can deadlock)",
                )
        elif fn.attr in ("result", "join"):
            self._report(
                ctx, "blocking-under-lock", node.lineno,
                f"{recv_src}.{fn.attr}() while holding {sorted(held)}",
            )
        elif fn.attr == "step" and ("engine" in recv_src or "proxy" in recv_src):
            self._report(
                ctx, "blocking-under-lock", node.lineno,
                f"engine step {recv_src}.step() while holding {sorted(held)}",
            )

    def _check_busy_wait(
        self, ctx: _FileCtx, cls: Optional[str], loop: ast.While, held: Set[str]
    ) -> None:
        # pattern A: while ...: time.sleep(<= _BUSY_SLEEP_MAX_S)
        stack: List[ast.AST] = [loop]
        flat: List[ast.AST] = []
        while stack:
            cur = stack.pop()
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)) \
                    and cur is not loop:
                continue  # closures run later, their sleeps aren't this loop's
            flat.append(cur)
            stack.extend(ast.iter_child_nodes(cur))
        for node in flat:
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
                f = node.func
                if f.attr == "sleep" and isinstance(f.value, ast.Name) \
                        and f.value.id == "time" and node.args:
                    val = _const_number(node.args[0])
                    if val is not None and 0 < val <= _BUSY_SLEEP_MAX_S:
                        self._report(
                            ctx, "busy-wait", node.lineno,
                            f"polling loop: time.sleep({val:g}) in a while loop — "
                            "use a Condition/Event wait",
                        )
        # pattern B: a short const-timeout .wait re-polled every iteration —
        # in the while-condition OR the loop body.  Timed waits on a HELD
        # condition are exempt: that is the correct predicate-loop shape.
        for node in flat:
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "wait":
                if cls is not None:
                    recv = _self_path(node.func.value)
                    if recv and self._lock_kind(cls, recv) == "condition" \
                            and self._canonical(cls, recv) in held:
                        continue
                timeout = None
                if node.args:
                    timeout = _const_number(node.args[0])
                for kw in node.keywords:
                    if kw.arg == "timeout":
                        timeout = _const_number(kw.value)
                if timeout is not None and 0 < timeout <= _POLL_WAIT_MAX_S:
                    self._report(
                        ctx, "busy-wait", loop.lineno,
                        f"timed-wait poll loop: every iteration re-polls "
                        f".wait(timeout={timeout:g}) — wake it by "
                        "event/abort instead",
                    )

    def _check_thread_join(self, ctx: _FileCtx) -> None:
        # aliases: `w = self._watchdog` means joining `w` joins `_watchdog`
        aliases: Dict[str, Set[str]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Attribute):
                aliases.setdefault(node.targets[0].id, set()).add(node.value.attr)
        joined: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "join":
                recv = node.func.value
                if isinstance(recv, ast.Attribute):
                    joined.add(recv.attr)
                elif isinstance(recv, ast.Name):
                    joined.add(recv.id)
                    joined |= aliases.get(recv.id, set())
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and self._is_thread_ctor(node.func)):
                continue
            target = self._thread_storage_name(ctx, node)
            if target is None:
                continue  # e.g. appended to a list; leak fixture still catches
            if target not in joined:
                self._report(
                    ctx, "thread-join", node.lineno,
                    f"threading.Thread stored in '{target}' is never joined in "
                    "this module — shutdown path leaks the thread",
                )

    @staticmethod
    def _is_thread_ctor(fn: ast.expr) -> bool:
        if isinstance(fn, ast.Attribute) and fn.attr == "Thread" \
                and isinstance(fn.value, ast.Name) and fn.value.id == "threading":
            return True
        return isinstance(fn, ast.Name) and fn.id == "Thread"

    def _thread_storage_name(self, ctx: _FileCtx, call: ast.Call) -> Optional[str]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and node.value is call:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Attribute):
                    return tgt.attr
                if isinstance(tgt, ast.Name):
                    return tgt.id
        return None

    # ---------------- lock graph ----------------

    def _interprocedural_edges(self) -> None:
        # transitive closure of same-class method acquire sets
        acquires = {k: set(v) for k, v in self.direct_acquires.items()}
        changed = True
        while changed:
            changed = False
            for (cls, meth), callees in self.self_calls.items():
                cur = acquires.setdefault((cls, meth), set())
                for callee in callees:
                    extra = acquires.get((cls, callee))
                    if extra and not extra <= cur:
                        cur |= extra
                        changed = True
        for held, cls, callee, path, line in self.deferred:
            for lock in acquires.get((cls, callee), set()):
                if lock in held:
                    continue  # already held → reentrant, not an ordering edge
                for h in held:
                    self.edges.setdefault((h, lock), (path, line))

    def _cycle_check(self) -> None:
        graph: Dict[str, List[str]] = {}
        for a, b in self.edges:
            graph.setdefault(a, []).append(b)
            graph.setdefault(b, [])
        color: Dict[str, int] = {}
        stack: List[str] = []

        def dfs(node: str) -> Optional[List[str]]:
            color[node] = 1
            stack.append(node)
            for nxt in graph[node]:
                if color.get(nxt, 0) == 1:
                    return stack[stack.index(nxt):] + [nxt]
                if color.get(nxt, 0) == 0:
                    cyc = dfs(nxt)
                    if cyc:
                        return cyc
            stack.pop()
            color[node] = 2
            return None

        for node in sorted(graph):
            if color.get(node, 0) == 0:
                cyc = dfs(node)
                if cyc:
                    closing = (cyc[-2], cyc[-1])
                    path, line = self.edges.get(closing, ("<lock-graph>", 0))
                    ctx = next((f for f in self.files if f.path == path), None)
                    if ctx is not None and "lock-order" in ctx.disabled(line):
                        return
                    self.violations.append(
                        Violation(
                            "lock-order", path, line,
                            "cycle in lock-acquisition graph: "
                            + " -> ".join(cyc),
                        )
                    )
                    return  # one cycle report is enough; fix and re-run

    def _graph(self) -> dict:
        nodes = sorted({n for e in self.edges for n in e})
        return {
            "source": "static",
            "nodes": nodes,
            "edges": [
                {"from": a, "to": b, "at": f"{p}:{ln}"}
                for (a, b), (p, ln) in sorted(self.edges.items())
            ],
        }


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def check_source(src: str, path: str = "<string>") -> CheckResult:
    """Check a single source string (used by the self-tests)."""
    an = _Analyzer()
    an.add_source(src, path)
    return an.check()


def check_paths(paths: List[str]) -> CheckResult:
    """Check every ``.py`` file under the given files/directories together
    (one shared class registry and lock graph)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for name in sorted(names):
                    if name.endswith(".py"):
                        files.append(os.path.join(root, name))
        elif p.endswith(".py"):
            files.append(p)
    an = _Analyzer()
    for f in sorted(set(files)):
        with open(f, "r", encoding="utf-8") as fh:
            an.add_source(fh.read(), f)
    return an.check()
