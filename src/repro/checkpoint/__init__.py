from repro.checkpoint.store import (  # noqa: F401
    latest_checkpoint, load_tree, save_checkpoint, save_tree)
