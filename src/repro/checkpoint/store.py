"""Checkpointing: msgpack-serialized param/optimizer pytrees.

Layout: <dir>/step_<n>/{tree.msgpack, meta.json}.  Arrays are stored as
(dtype, shape, raw bytes); bfloat16 round-trips via uint16 views.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(arr.shape),
                "data": arr.view(np.uint16).tobytes()}
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d: dict):
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])
    return jnp.asarray(arr)


def save_tree(path: str, tree: Any, *, meta: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = [_pack_leaf(l) for l in leaves]
    with open(os.path.join(path, "tree.msgpack"), "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"treedef": str(treedef), **(meta or {})}, f)


def load_tree(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype validated)."""
    with open(os.path.join(path, "tree.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(payload) == len(leaves_like), "checkpoint/tree mismatch"
    leaves = []
    for d, ref in zip(payload, leaves_like, strict=True):
        arr = _unpack_leaf(d)
        assert tuple(arr.shape) == tuple(ref.shape), (arr.shape, ref.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, state: Any, **meta) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    save_tree(path, state, meta={"step": step, **meta})
    return path


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None
