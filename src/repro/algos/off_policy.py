"""Off-policy objectives from the paper's §2.2 loss box.

All losses are token-level with per-sequence 1/|o| normalization (the
paper's GRPO-style averaging), masked to response tokens, and return
(scalar loss, metrics).  Sign convention: these are *losses* (minimize), the
negation of the J objectives in the paper.

Variants (``pg_variant`` in the launch config, as in the paper's appendix):
    ppo            standard clipped surrogate
    decoupled_ppo  Hilton et al. 2022: behaviour/proximal decoupling
    tis            Truncated IS (Munos et al. 2016): sg(clip(r, 0, c)) A log pi
    cispo          sg(clip(r, 1-eps_low, 1+eps_high)) A log pi
    topr           TOPR: T+ untruncated, T- truncated IS
    weighted_topr  ROLL Flash's stabilized TOPR with pos/neg weights
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

VARIANTS = ("ppo", "decoupled_ppo", "tis", "cispo", "topr", "weighted_topr")


@dataclasses.dataclass(frozen=True)
class LossConfig:
    pg_variant: str = "ppo"
    epsilon: float = 0.2           # PPO / decoupled-PPO clip
    eps_low: float = 0.2           # CISPO lower
    eps_high: float = 0.2          # CISPO upper (asymmetric allowed)
    c: float = 5.0                 # TIS / TOPR truncation threshold
    kl_beta: float = 0.0           # GRPO KL regularization weight
    topr_pos_weight: float = 1.0   # weighted TOPR
    topr_neg_weight: float = 1.0
    engine_mismatch_cap: float = 5.0  # eq. 12 (train-engine vs rollout-engine)
    # TIS cap for QUANTIZED rollouts (FlashRL): tightens the eq. 12
    # truncation threshold when the rollout engine generates from int8/fp8
    # weights — the mismatch ratio is then systematically off-center and a
    # loose cap lets a few tokens dominate the gradient.  None = use
    # engine_mismatch_cap unchanged; typical quantized setting: 2.0.
    tis_clip: "float | None" = None
    aux_loss_weight: float = 0.01  # MoE load-balance
    z_loss_weight: float = 0.001


def _masked_seq_mean(x, mask):
    """Per-sequence 1/|o| token average, then batch mean."""
    tok = (x * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    return tok.mean()


def kl_k3(logprobs, ref_logprobs, mask):
    """Schulman k3 estimator of KL(pi_theta || pi_ref), per-token >= 0."""
    d = ref_logprobs - logprobs
    return _masked_seq_mean(jnp.exp(d) - d - 1.0, mask)


def engine_mismatch_weight(train_logprobs, rollout_logprobs, cap,
                           tis_clip=None):
    """Eq. 12: min(pi_train/pi_rollout, C), stop-gradient.

    ``tis_clip`` (FlashRL's truncated-IS threshold for quantized rollouts)
    tightens the cap when set: the effective threshold is min(cap,
    tis_clip), or tis_clip alone when ``cap`` is None."""
    if tis_clip is not None:
        cap = tis_clip if cap is None else min(cap, tis_clip)
    r = jnp.exp(jax.lax.stop_gradient(train_logprobs) - rollout_logprobs)
    return jnp.minimum(r, cap)


def policy_loss(logprobs, old_logprobs, prox_logprobs, advantages, mask,
                is_positive, cfg: LossConfig):
    """Token-level off-policy policy-gradient loss.

    logprobs:      (B,S) log pi_theta(o_t|...)   — differentiable
    old_logprobs:  (B,S) behaviour policy (stale rollout policy), constant
    prox_logprobs: (B,S) proximal policy (decoupled PPO), constant
    advantages:    (B,S) token advantages (already broadcast)
    mask:          (B,S) response-token mask
    is_positive:   (B,)  TOPR T+/T- indicator (1.0 = positive trajectory)
    """
    v = cfg.pg_variant
    ratio = jnp.exp(logprobs - old_logprobs)
    metrics = {}

    if v == "ppo":
        clipped = jnp.clip(ratio, 1.0 - cfg.epsilon, 1.0 + cfg.epsilon)
        obj = jnp.minimum(ratio * advantages, clipped * advantages)
        metrics["clip_frac"] = _masked_seq_mean(
            (jnp.abs(ratio - 1.0) > cfg.epsilon).astype(jnp.float32), mask)
    elif v == "decoupled_ppo":
        # min( R r_theta/old , R (prox/old) clip(r_theta/prox, 1±eps) )
        behaviour = jnp.exp(prox_logprobs - old_logprobs)  # constant
        r_prox = jnp.exp(logprobs - prox_logprobs)
        clipped = jnp.clip(r_prox, 1.0 - cfg.epsilon, 1.0 + cfg.epsilon)
        obj = jnp.minimum(ratio * advantages, behaviour * clipped * advantages)
        metrics["clip_frac"] = _masked_seq_mean(
            (jnp.abs(r_prox - 1.0) > cfg.epsilon).astype(jnp.float32), mask)
    elif v == "tis":
        w = jax.lax.stop_gradient(jnp.clip(ratio, 0.0, cfg.c))
        obj = w * advantages * logprobs
        metrics["trunc_frac"] = _masked_seq_mean((ratio > cfg.c).astype(jnp.float32), mask)
    elif v == "cispo":
        w = jax.lax.stop_gradient(
            jnp.clip(ratio, 1.0 - cfg.eps_low, 1.0 + cfg.eps_high))
        obj = w * advantages * logprobs
        metrics["trunc_frac"] = _masked_seq_mean(
            ((ratio > 1.0 + cfg.eps_high) | (ratio < 1.0 - cfg.eps_low)).astype(jnp.float32), mask)
    elif v in ("topr", "weighted_topr"):
        w_pos = cfg.topr_pos_weight if v == "weighted_topr" else 1.0
        w_neg = cfg.topr_neg_weight if v == "weighted_topr" else 1.0
        trunc = jax.lax.stop_gradient(jnp.clip(ratio, 0.0, cfg.c))
        pos = is_positive[:, None]
        w = w_pos * pos + w_neg * (1.0 - pos) * trunc
        obj = w * advantages * logprobs
        metrics["trunc_frac"] = _masked_seq_mean(
            ((1.0 - pos) * (ratio > cfg.c)).astype(jnp.float32), mask)
    else:
        raise ValueError(f"unknown pg_variant {v!r}")

    loss = -_masked_seq_mean(obj, mask)
    metrics.update(
        ratio_mean=_masked_seq_mean(ratio, mask),
        ratio_max=jnp.max(jnp.where(mask > 0, ratio, 0.0)),
    )
    return loss, metrics
