"""Advantage estimation: GAE (PPO) and group-normalized rewards (GRPO, eq. 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gae(rewards, values, mask, *, gamma: float = 1.0, lam: float = 1.0):
    """Generalized Advantage Estimation.

    rewards/values/mask: (B, S).  values[:, t] = V(s_t); bootstrap value 0 at
    episode end (token-level MDP with terminal at last response token).
    Returns (advantages, returns), both (B, S).
    """
    b, s = rewards.shape
    next_values = jnp.concatenate([values[:, 1:], jnp.zeros((b, 1), values.dtype)], axis=1)
    deltas = (rewards + gamma * next_values * mask - values) * mask

    def step(carry, xs):
        delta_t, mask_t = xs
        adv = delta_t + gamma * lam * mask_t * carry
        return adv, adv

    _, advs = jax.lax.scan(step, jnp.zeros((b,), rewards.dtype),
                           (deltas.T, mask.T), reverse=True)
    advantages = advs.T * mask
    return advantages, advantages + values


def group_normalized_advantage(rewards, group_size: int, *, eps: float = 1e-6):
    """GRPO (eq. 2): A_i = (r_i - mean_group) / std_group.

    rewards: (N,) with N = num_prompts * group_size, grouped contiguously.
    Returns per-sequence advantages (N,).
    """
    n = rewards.shape[0]
    assert n % group_size == 0, (n, group_size)
    g = rewards.reshape(n // group_size, group_size)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    return ((g - mean) / (std + eps)).reshape(n)


def sequence_to_token_advantage(seq_adv, mask):
    """Broadcast per-sequence advantage over response tokens. mask: (B,S)."""
    return seq_adv[:, None] * mask


def reward_normalize(rewards, mode: str = "group", group_size: int = 1):
    if mode == "none":
        return rewards
    if mode == "group":
        return group_normalized_advantage(rewards, group_size)
    if mode == "batch":
        return (rewards - rewards.mean()) / (rewards.std() + 1e-6)
    raise ValueError(mode)
