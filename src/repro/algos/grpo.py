"""Full RL objective (GRPO eq. 3 generalized over pg_variants).

loss = policy_loss(variant) + beta * KL(pi || pi_ref) + moe aux losses
with optional engine-mismatch truncated IS (eq. 12) folded into advantages.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.algos.off_policy import LossConfig, engine_mismatch_weight, kl_k3, policy_loss


def token_logprobs(logits, tokens):
    """Gather log-softmax probabilities of realized tokens.

    logits: (B, S, V) fp32 *aligned with tokens* (logits[t] predicts tokens[t])
    tokens: (B, S) int32
    """
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)), axis=-1))
    picked = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    return picked - (logz + logits.max(-1))


def rl_loss(logprobs, batch, cfg: LossConfig, aux=None):
    """batch: dict with old_logprobs, prox_logprobs, ref_logprobs, advantages,
    mask, is_positive (see configs/shapes.train_inputs)."""
    adv = batch["advantages"]
    if cfg.engine_mismatch_cap is not None or cfg.tis_clip is not None:
        adv = adv * engine_mismatch_weight(logprobs, batch["old_logprobs"],
                                           cfg.engine_mismatch_cap,
                                           tis_clip=cfg.tis_clip)
    loss, metrics = policy_loss(
        logprobs, batch["old_logprobs"], batch["prox_logprobs"], adv,
        batch["mask"], batch["is_positive"], cfg)
    if cfg.kl_beta:
        kl = kl_k3(logprobs, batch["ref_logprobs"], batch["mask"])
        loss = loss + cfg.kl_beta * kl
        metrics["kl"] = kl
    if aux is not None:
        loss = (loss
                + cfg.aux_loss_weight * aux["load_balance_loss"]
                + cfg.z_loss_weight * aux["router_z_loss"])
        metrics["load_balance_loss"] = aux["load_balance_loss"]
    metrics["policy_loss"] = loss
    return loss, metrics
