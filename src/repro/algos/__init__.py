from repro.algos.advantages import (  # noqa: F401
    gae, group_normalized_advantage, reward_normalize, sequence_to_token_advantage)
from repro.algos.off_policy import LossConfig, VARIANTS, policy_loss, kl_k3  # noqa: F401
from repro.algos.grpo import rl_loss, token_logprobs  # noqa: F401
