from repro.data.dataset import (  # noqa: F401
    ArithmeticProblem, ArithmeticTask, BOS, EOS, PAD, VOCAB,
    decode_number, encode_number, pad_and_stack)
