"""Prompt datasets.

``ArithmeticTask`` is the synthetic DAPO-stand-in: verifiable math prompts
("a op b =") with exact-match rewards, sized so a ~100M model learns it in a
few hundred RL steps on CPU.  Token map (small closed vocab):

    0 pad | 1 bos | 2 eos | 3..12 digits 0-9 | 13 '+' | 14 '*' | 15 '=' | 16 '-'
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, List, Optional, Tuple

import numpy as np

PAD, BOS, EOS = 0, 1, 2
DIGIT0 = 3
PLUS, TIMES, EQUALS, MINUS = 13, 14, 15, 16
VOCAB = 32


def encode_number(n: int) -> List[int]:
    return [DIGIT0 + int(c) for c in str(int(n))]


def decode_number(tokens) -> Optional[int]:
    digits = []
    for t in np.asarray(tokens).ravel():
        t = int(t)
        if t == EOS:
            break
        if not (DIGIT0 <= t <= DIGIT0 + 9):
            return None
        digits.append(str(t - DIGIT0))
    if not digits:
        return None
    return int("".join(digits))


@dataclasses.dataclass(frozen=True)
class ArithmeticProblem:
    a: int
    b: int
    op: str

    @property
    def answer(self) -> int:
        return {"+": self.a + self.b, "*": self.a * self.b,
                "-": self.a - self.b}[self.op]

    def prompt_tokens(self) -> np.ndarray:
        op_tok = {"+": PLUS, "*": TIMES, "-": MINUS}[self.op]
        toks = [BOS] + encode_number(self.a) + [op_tok] + encode_number(self.b) + [EQUALS]
        return np.asarray(toks, np.int32)

    def answer_tokens(self) -> np.ndarray:
        return np.asarray(encode_number(self.answer) + [EOS], np.int32)


class ArithmeticTask:
    """Infinite stream of verifiable arithmetic prompts."""

    def __init__(self, *, max_operand: int = 20, ops: Tuple[str, ...] = ("+",),
                 seed: int = 0):
        self.max_operand = max_operand
        self.ops = ops
        self.rng = np.random.default_rng(seed)

    def sample_problem(self) -> ArithmeticProblem:
        a = int(self.rng.integers(0, self.max_operand + 1))
        b = int(self.rng.integers(0, self.max_operand + 1))
        op = str(self.rng.choice(list(self.ops)))
        if op == "-" and b > a:
            a, b = b, a
        return ArithmeticProblem(a, b, op)

    def problem_from_prompt(self, prompt_tokens) -> Optional[ArithmeticProblem]:
        toks = [int(t) for t in np.asarray(prompt_tokens).ravel() if t != PAD]
        if not toks or toks[0] != BOS or toks[-1] != EQUALS:
            return None
        body = toks[1:-1]
        for op_tok, op in ((PLUS, "+"), (TIMES, "*"), (MINUS, "-")):
            if op_tok in body:
                i = body.index(op_tok)
                a = decode_number(body[:i] + [EOS])
                b = decode_number(body[i + 1:] + [EOS])
                if a is None or b is None:
                    return None
                return ArithmeticProblem(a, b, op)
        return None

    def prompt_stream(self, *, group_size: int = 1) -> Iterator[Tuple[int, np.ndarray]]:
        """Yield (prompt_id, tokens); each prompt repeated group_size times
        consecutively (prompt replication for GRPO groups)."""
        for pid in itertools.count():
            prob = self.sample_problem()
            toks = prob.prompt_tokens()
            for _ in range(group_size):
                yield pid, toks


def pad_and_stack(seqs: List[np.ndarray], length: int, pad_value: int = PAD,
                  align: str = "right") -> np.ndarray:
    """Stack variable-length sequences to (N, length)."""
    out = np.full((len(seqs), length), pad_value, np.int32)
    for i, s in enumerate(seqs):
        s = np.asarray(s, np.int32)[:length]
        if align == "right":
            out[i, length - len(s):] = s
        else:
            out[i, :len(s)] = s
    return out
