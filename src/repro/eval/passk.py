"""Pass@k evaluation (the paper evaluates Pass@1 on math benchmarks).

Drives the DecodeEngine directly — the same serving path the rollout uses —
with k sampled candidates per prompt (temperature 1) plus a greedy Pass@1
mode, and the unbiased Chen et al. (2021) Pass@k estimator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.types import Sample, next_uid
from repro.data.dataset import ArithmeticTask, EOS
from repro.models.api import ModelAPI
from repro.rollout.engine import DecodeEngine


def pass_at_k_estimator(n: int, c: int, k: int) -> float:
    """Unbiased Pass@k: 1 - C(n-c, k)/C(n, k)."""
    if n - c < k:
        return 1.0
    return float(1.0 - np.prod(1.0 - k / np.arange(n - c + 1, n + 1)))


@dataclasses.dataclass
class EvalResult:
    num_prompts: int
    n_per_prompt: int
    pass_at_1: float
    pass_at_k: dict


def evaluate_passk(api: ModelAPI, params, *, task: Optional[ArithmeticTask] = None,
                   reward_fn: Optional[Callable] = None, num_prompts: int = 32,
                   n_per_prompt: int = 8, ks=(1, 4), max_new_tokens: int = 6,
                   num_slots: int = 16, max_total_len: int = 32,
                   temperature: float = 1.0, seed: int = 0) -> EvalResult:
    from repro.rewards.verifier import ArithmeticVerifier

    task = task or ArithmeticTask(max_operand=4, ops=("+",), seed=seed + 1)
    reward_fn = reward_fn or ArithmeticVerifier(task, format_credit=0.0)

    engine = DecodeEngine(api, params, num_slots=num_slots,
                          max_total_len=max_total_len, eos_id=EOS,
                          temperature=temperature, seed=seed)
    prompts = [task.sample_problem().prompt_tokens() for _ in range(num_prompts)]
    # queue (prompt_idx, candidate_idx) tasks through the engine
    pending = [(pi, ci) for pi in range(num_prompts) for ci in range(n_per_prompt)]
    rid_map = {}
    correct = np.zeros((num_prompts, n_per_prompt), bool)
    done = 0
    while done < len(rid_map) or pending:
        while pending and engine.num_free_slots > 0:
            pi, ci = pending.pop()
            rid = next_uid()
            rid_map[rid] = (pi, ci)
            engine.add_request(rid, prompts[pi], max_new_tokens)
        for rid, toks, lps in engine.step():
            pi, ci = rid_map[rid]
            s = Sample(sample_id=rid, prompt_id=pi, replica_idx=ci,
                       prompt_tokens=prompts[pi], response_tokens=toks,
                       logprobs=lps)
            correct[pi, ci] = reward_fn(s) >= 1.0
            done += 1
        if not engine.slots and not pending:
            break

    c = correct.sum(axis=1)
    p1 = float(np.mean([pass_at_k_estimator(n_per_prompt, int(ci), 1) for ci in c]))
    pk = {k: float(np.mean([pass_at_k_estimator(n_per_prompt, int(ci), k)
                            for ci in c]))
          for k in ks if k <= n_per_prompt}
    return EvalResult(num_prompts, n_per_prompt, p1, pk)
