from repro.eval.passk import EvalResult, evaluate_passk, pass_at_k_estimator  # noqa: F401
