import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this builds the right step function (train_step /
prefill_step / serve_step), shards every input with the production rules,
lowers and compiles it against 512 placeholder host devices, and records:

  * memory_analysis()   — per-device bytes (proves the config fits HBM)
  * cost_analysis()     — HLO FLOPs / bytes accessed (roofline numerator)
  * collective bytes    — parsed from the optimized HLO per collective kind

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.algos import LossConfig
from repro.configs import REGISTRY, SHAPES, InputShape, input_specs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import get_api, sharding as shd
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.trainer import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the optimized HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    shape_re = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m2 = re.search(r"=\s*.*?\b(all-gather|all-reduce|reduce-scatter|"
                       r"all-to-all|collective-permute)(-start|-done)?\(", stripped)
        if not m2 or m2.group(2) == "-done":
            continue
        kind = m2.group(1)
        m = shape_re.search(stripped)
        if not m:
            continue
        dt, dims = m.group(1), m.group(2)
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        out[kind] += size * _DTYPE_BYTES.get(dt, 4)
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# abstract state/input construction (ShapeDtypeStructs only, no allocation)
# ---------------------------------------------------------------------------

def abstract_train_state(api) -> Any:
    def build(key):
        params = api.init(key)
        return {"params": params, "opt": init_opt_state(params)}

    return jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_params(api) -> Any:
    return jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_cache(api, batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: api.init_cache(batch, max_len))


def _shardings(tree_specs, mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree_specs)


def build_combo(cfg: ModelConfig, shape: InputShape, mesh):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""
    api = get_api(cfg)
    dp = shd.batch_axes(mesh)
    batch_ok = shd.shardable_batch(mesh, shape.global_batch)
    bspec = dp if batch_ok else None

    def dspec(x):
        spec = [None] * len(x.shape)
        if len(spec) and x.shape[0] == shape.global_batch:
            spec[0] = bspec
        return P(*spec)

    inputs = input_specs(cfg, shape)

    if shape.kind == "train":
        state = abstract_train_state(api)
        state_spec = shd.param_specs(state, mesh)
        # MoE configs need grad accumulation to fit activations per chip
        mb = 4 if cfg.is_moe else 1
        fn = make_train_step(api, LossConfig(pg_variant="ppo", kl_beta=0.0),
                             OptConfig(), remat=True, moe_mode="ep",
                             microbatches=mb)
        in_shard = (_shardings(state_spec, mesh),
                    jax.tree_util.tree_map(lambda x: NamedSharding(mesh, dspec(x)), inputs))
        out_shard = (_shardings(state_spec, mesh), None)
        args = (state, inputs)
        return fn, args, in_shard, out_shard, (0,)

    if shape.kind == "prefill":
        params = abstract_params(api)
        pspec = shd.param_specs(params, mesh)
        cache = abstract_cache(api, shape.global_batch, shape.seq_len)
        cspec = shd.cache_specs(cache, mesh, shard_batch=batch_ok)

        def fn(params, batch, cache):
            return api.prefill(params, batch, cache)

        in_shard = (_shardings(pspec, mesh),
                    jax.tree_util.tree_map(lambda x: NamedSharding(mesh, dspec(x)), inputs),
                    _shardings(cspec, mesh))
        out_shard = (None, _shardings(cspec, mesh))
        args = (params, inputs, cache)
        return fn, args, in_shard, out_shard, (2,)

    # decode: serve_step — ONE new token against a seq_len cache
    params = abstract_params(api)
    pspec = shd.param_specs(params, mesh)
    cache = abstract_cache(api, shape.global_batch, shape.seq_len)
    cspec = shd.cache_specs(cache, mesh, shard_batch=batch_ok)

    def fn(params, token, pos, cache):
        return api.decode_step(params, token, pos, cache)

    in_shard = (_shardings(pspec, mesh),
                NamedSharding(mesh, P(bspec)), NamedSharding(mesh, P(bspec)),
                _shardings(cspec, mesh))
    out_shard = (None, _shardings(cspec, mesh))
    args = (params, inputs["token"], inputs["pos"], cache)
    return fn, args, in_shard, out_shard, (3,)


def run_combo(arch: str, shape_name: str, mesh_name: str,
              *, save: bool = True, verbose: bool = True) -> Dict[str, Any]:
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.time()
    try:
        fn, args, in_shard, out_shard, donate = build_combo(cfg, shape, mesh)
        with mesh:
            # sequence-parallel activation sharding: norms/MLP/projections are
            # per-position, so an S-sharded residual stream needs NO gather at
            # block boundaries (D-sharding forced an all-gather at every
            # consumer — §Perf iter 4c measured 3.4x lower collective bytes).
            shd.set_activation_sharding(
                P(shd.batch_axes(mesh) if shd.shardable_batch(mesh, shape.global_batch) else None,
                  "model", None))
            try:
                jitted = jax.jit(fn, in_shardings=in_shard,
                                 out_shardings=out_shard, donate_argnums=donate)
                lowered = jitted.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
            finally:
                shd.set_activation_sharding(None)

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = parse_collective_bytes(compiled.as_text())
        n_dev = int(np.prod(mesh.devices.shape))
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            devices=n_dev,
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                               + getattr(mem, "temp_size_in_bytes", 0)),
            },
            collectives=coll,
        )
        if verbose:
            print(f"[OK] {arch:24s} {shape_name:12s} {mesh_name:6s} "
                  f"lower {rec['lower_s']:6.1f}s compile {rec['compile_s']:6.1f}s "
                  f"flops/dev {rec['flops']:.3e} "
                  f"peak {rec['memory']['peak_bytes']/2**30:.2f} GiB "
                  f"coll {sum(coll[k] for k in _COLLECTIVES)/2**20:.1f} MiB")
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a finding
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {mesh_name}: {rec['error']}")

    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def run_pools(arch: str = "qwen3-8b") -> Dict[str, Any]:
    """Rollout-train decoupling at the RESOURCE level (paper Fig 3a): split
    the 512 chips into a trainer pool (8x16) and a rollout pool (16x16),
    compile train_step on one and serve_step on the other, and execute a
    real 3-phase weight sync (device_put of a smoke-size param tree across
    submeshes — the ICI-transfer path XLA takes on hardware)."""
    import numpy as _np

    from jax.sharding import PartitionSpec as _P

    from repro.launch.mesh import split_rollout_train_pools
    from repro.models import get_api

    train_mesh, infer_mesh = split_rollout_train_pools(
        train_chips=128, infer_chips=256, model_parallel=16)
    cfg = REGISTRY[arch]
    rec: Dict[str, Any] = {"arch": arch, "mode": "pools",
                           "train_mesh": str(train_mesh.devices.shape),
                           "infer_mesh": str(infer_mesh.devices.shape)}

    # trainer pool: full-size train_4k lower+compile
    shape_t = SHAPES["train_4k"]
    fn, args_, ins, outs, donate = build_combo(cfg, shape_t, train_mesh)
    with train_mesh:
        shd.set_activation_sharding(_P(("data",), "model", None))
        try:
            c1 = jax.jit(fn, in_shardings=ins, out_shardings=outs,
                         donate_argnums=donate).lower(*args_).compile()
        finally:
            shd.set_activation_sharding(None)
    rec["train_flops_dev"] = float(c1.cost_analysis().get("flops", 0))

    # rollout pool: full-size decode_32k lower+compile
    shape_d = SHAPES["decode_32k"]
    fn, args_, ins, outs, donate = build_combo(cfg, shape_d, infer_mesh)
    with infer_mesh:
        c2 = jax.jit(fn, in_shardings=ins, out_shardings=outs,
                     donate_argnums=donate).lower(*args_).compile()
    rec["serve_flops_dev"] = float(c2.cost_analysis().get("flops", 0))

    # REAL weight sync between pools (smoke-size params, actual buffers)
    api = get_api(cfg.smoke())
    params = api.init(jax.random.PRNGKey(0))
    train_sharded = jax.device_put(params, shd.param_shardings(params, train_mesh))
    t0 = time.time()
    infer_sharded = jax.device_put(train_sharded,
                                   shd.param_shardings(params, infer_mesh))
    jax.block_until_ready(infer_sharded)
    rec["weight_sync_s_host"] = round(time.time() - t0, 3)
    rec["weight_sync_bytes"] = int(sum(
        _np.prod(l.shape) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params)))
    rec["status"] = "ok"
    print(f"[OK] pools: train {rec['train_mesh']} + rollout {rec['infer_mesh']}; "
          f"weight sync {rec['weight_sync_bytes'] / 2**20:.1f} MiB across pools "
          f"in {rec['weight_sync_s_host']}s (host)")
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"pools__{arch}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(REGISTRY) + [None])
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--pools", action="store_true",
                    help="decoupled rollout/train pool demo (paper Fig 3a)")
    args = ap.parse_args()

    if args.pools:
        run_pools(args.arch or "qwen3-8b")
        return

    archs = sorted(REGISTRY) if (args.all or args.arch is None) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                results.append(run_combo(arch, shape, mesh))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\n=== dry-run: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed ===")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
