"""Pipeline assembly: wire models + rollout fleet + buffer + controller.

This is the host-level composition root used by `launch/train.py`, the
examples, and the integration tests.  Everything is config-driven, mirroring
the paper's appendix-A YAML (async_generation_ratio, pg_variant,
rollout_batch_size, num_return_sequences, actor_train/actor_infer split...).
``num_rollout_replicas`` sizes the rollout fleet: 1 (default) is the plain
single proxy/engine path; >= 2 shards slots/pages across N replicas behind
a ``ProxyRouter`` (queue scheduling, co-located groups/sessions,
cross-replica abort-resume migration).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Tuple, Union

import jax

from repro.algos import LossConfig
from repro.core.async_controller import AsyncController
from repro.core.env_manager import EnvManagerPool
from repro.core.llm_proxy import LLMProxy
from repro.core.router import AutoscalePolicy, ProxyRouter
from repro.core.sample_buffer import SampleBuffer
from repro.core.scheduler import RolloutProducer
from repro.core.slo import SLOConfig, without_admission
from repro.core.types import PRIORITY_NORMAL
from repro.data.dataset import ArithmeticTask, EOS
from repro.models import ModelConfig, get_api
from repro.rewards.verifier import ArithmeticVerifier
from repro.rollout.engine import DecodeEngine
from repro.rollout.paged_engine import PagedDecodeEngine
from repro.train.optimizer import OptConfig
from repro.train.trainer import HostTrainer, TrainerConfig

RolloutEngine = Union[DecodeEngine, PagedDecodeEngine]


@dataclasses.dataclass
class PipelineSettings:
    """The paper's launch-config surface (appendix A.1 naming)."""
    async_generation_ratio: float = 1.0    # 0 => Sync
    pg_variant: str = "ppo"
    rollout_batch_size: int = 16           # samples per train step
    num_return_sequences_in_group: int = 4
    is_num_return_sequences_expand: bool = True  # prompt replication
    max_new_tokens: int = 12
    max_seq_len: int = 32
    num_slots: int = 8                     # decode slots (infer "GPUs")
    minibatches: int = 1
    ppo_epochs: int = 1
    adv_estimator: str = "grpo"            # grpo (paper default) | gae (critic)
    kl_beta: float = 0.0
    learning_rate: float = 3e-3
    seed: int = 0
    # rollout engine selection: "auto" runs the paged COW engine for
    # attention families (dense/moe) and falls back to the slot engine for
    # families without positional KV (rwkv6 / rglru / encdec / vlm).
    rollout_engine: str = "auto"           # auto | paged | slot
    page_size: int = 16                    # paged engine: KV page tokens
    prefill_chunk: int = 16                # paged engine: prefill chunk tokens
    num_pages: Optional[int] = None        # paged engine: pool size (auto)
    attn_impl: str = "ref"                 # ref | kernel | kernel_interpret
    # automatic cross-prompt prefix caching (radix tree over KV pages).
    # "auto"/"on": enabled on the paged engine; "off": disabled.  The slot
    # engine has no page pool — the setting passes through as a no-op there.
    prefix_cache: str = "auto"             # auto | on | off
    # agentic rollouts: "turn" submits only each turn's observation; "full"
    # resubmits the growing conversation every turn, which the prefix cache
    # turns into incremental prefill (only the new suffix is computed).
    agentic_context: str = "turn"          # turn | full
    # weight synchronization (async modes only; alpha=0 always uses the
    # 3-phase suspend barrier): "overlapped" stages a per-proxy parameter
    # swap between engine steps — rollout never stops; "blocking" is the
    # 3-phase suspend -> update -> resume barrier.
    weight_sync: str = "overlapped"        # overlapped | blocking
    # max seconds to wait for every replica to acknowledge a staged
    # (overlapped) weight swap before declaring the fleet stalled.
    weight_sync_timeout: float = 60.0
    # rollout fleet size.  1 (default) keeps the single proxy/engine path
    # byte-identical to before; >= 2 shards num_slots/num_pages across N
    # replicas behind a ProxyRouter (per-request least-loaded queue
    # scheduling, GRPO-group/session co-location, cross-replica
    # abort-resume migration).
    num_rollout_replicas: int = 1
    # elasticity: autoscale_max_replicas > num_rollout_replicas arms
    # load-triggered scaling — the fleet grows toward the max under queue
    # pressure and drains/retires idle replicas back toward the min
    # (AutoscalePolicy hysteresis).  0 (default) disables the autoscaler.
    autoscale_max_replicas: int = 0
    autoscale_min_replicas: int = 1
    # crash detection: > 0 runs the router's background heartbeat monitor
    # at this period (seconds) — dead replicas are detected and their
    # in-flight work failed over without waiting for a dispatch to hit
    # them.  0 (default) relies on dispatch-time detection only.
    health_probe_interval: float = 0.0
    # fleet-global prefix cache (N >= 2 fleets with a prefix cache):
    # cache_aware_routing arms the router's FleetRadixIndex — placement
    # routes to the replica holding a prompt's longest cached prefix when
    # its load is within cache_affinity_slack tokens of the fleet minimum,
    # otherwise least-loaded wins and the prefix pages are pulled across
    # before admission (cache_pull).  Cross-replica migration always moves
    # retained pages when it can (page-transfer fast path).
    cache_aware_routing: bool = True
    cache_affinity_slack: int = 256
    cache_pull: bool = True
    # --- SLO layer (admission control / preemption / watchdog) ---
    # slo_enabled arms the layer; all numeric knobs use 0 = off/unbounded.
    # Queue bounds are enforced fleet-wide at the router front door (replicas
    # behind a router carry an admission-stripped copy so admitted work is
    # never double-rejected).
    slo_enabled: bool = False
    slo_queue_limit_per_class: int = 0     # pending bound per priority class
    slo_queue_limit_total: int = 0         # pending bound across classes
    slo_preempt: bool = True               # high-priority arrivals evict decodes
    slo_stall_timeout: float = 0.0         # s without decode progress => timeout
    slo_defer_after_tokens: int = 0        # long-tail defer threshold (tokens)
    slo_replica_stall: float = 0.0         # s of frozen replica steps => dead
    # default SLO class stamped on produced rollout tasks
    rollout_priority: int = PRIORITY_NORMAL
    rollout_deadline_ms: float = 0.0       # 0 = no deadline
    # --- quantized rollouts (FlashRL recipe) ---
    # rollout_quant quantizes rollout-engine WEIGHTS at every weight sync
    # (trainer stays full precision); kv_quant stores KV pages as int8 with
    # per-(page,slot,kv-head) scales (paged engine only).  tis_clip > 0
    # tightens the eq. 12 truncated-IS cap to absorb the resulting
    # train/rollout engine mismatch (0 = off).
    rollout_quant: str = "off"             # off | int8 | fp8
    kv_quant: str = "off"                  # off | int8
    tis_clip: float = 0.0                  # 0 = off; typical quantized: 2.0


def make_slo_config(s: PipelineSettings) -> Optional[SLOConfig]:
    """Translate the flat settings knobs into an ``SLOConfig`` (or None
    when the layer is disabled)."""
    if not s.slo_enabled:
        return None
    return SLOConfig(
        queue_limit_per_class=s.slo_queue_limit_per_class or None,
        queue_limit_total=s.slo_queue_limit_total or None,
        preempt=s.slo_preempt,
        stall_timeout_s=s.slo_stall_timeout or None,
        defer_after_tokens=s.slo_defer_after_tokens or None,
        replica_stall_s=s.slo_replica_stall or None)


def make_rollout_engine(api, params, s: PipelineSettings) -> RolloutEngine:
    """Construct the rollout engine per ``s.rollout_engine`` (see above)."""
    if s.prefix_cache not in ("auto", "on", "off"):
        raise ValueError(f"unknown prefix_cache {s.prefix_cache!r} "
                         "(expected auto | on | off)")
    choice = s.rollout_engine
    if choice == "auto":
        choice = "paged" if api.init_paged_cache is not None else "slot"
    if choice == "paged":
        return PagedDecodeEngine(
            api, params, num_slots=s.num_slots, max_total_len=s.max_seq_len,
            page_size=s.page_size, prefill_chunk=s.prefill_chunk,
            num_pages=s.num_pages, eos_id=EOS, seed=s.seed,
            attn_impl=s.attn_impl, prefix_cache=s.prefix_cache != "off",
            quant_mode=s.rollout_quant, kv_quant=s.kv_quant)
    if choice != "slot":
        raise ValueError(f"unknown rollout_engine {s.rollout_engine!r} "
                         "(expected auto | paged | slot)")
    if s.kv_quant != "off":
        raise ValueError("kv_quant requires the paged engine (the slot "
                         "engine has no page pool to quantize); set "
                         "rollout_engine='paged' or kv_quant='off'")
    return DecodeEngine(api, params, num_slots=s.num_slots,
                        max_total_len=s.max_seq_len, eos_id=EOS, seed=s.seed,
                        quant_mode=s.rollout_quant)


def make_rollout_fleet(api, params, s: PipelineSettings,
                       ) -> Tuple[List[RolloutEngine], List[LLMProxy],
                                  Optional[ProxyRouter]]:
    """Build ``s.num_rollout_replicas`` proxy/engine replicas.

    N = 1 (default) returns exactly the single-engine construction of old
    (no router — the producer talks straight to the proxy).  N >= 2 shards
    the decode capacity: each replica gets ceil(num_slots / N) slots and
    ceil(num_pages / N) pages (when pinned), and a ProxyRouter fronts the
    fleet with least-outstanding-tokens queue scheduling.

    With ``autoscale_max_replicas`` armed the router also gets a
    ``replica_factory`` (same shard shape, fresh per-replica seed) so
    ``add_replica``/scale-up can grow the fleet mid-run, plus the
    hysteresis policy driving load-triggered elasticity."""
    n = max(1, int(s.num_rollout_replicas))
    elastic = s.autoscale_max_replicas > n
    slo = make_slo_config(s)
    if n == 1 and not elastic:
        engine = make_rollout_engine(api, params, s)
        # a lone proxy IS the front door: it keeps the full SLO config,
        # queue bounds included
        return [engine], [LLMProxy(engine, slo=slo)], None
    # behind a router the queue bounds are enforced fleet-wide at the front
    # door; replicas keep the preemption/watchdog parts only
    replica_slo = without_admission(slo)
    shard = s if n == 1 else dataclasses.replace(
        s, num_slots=max(1, -(-s.num_slots // n)),
        num_pages=None if s.num_pages is None else max(2, -(-s.num_pages // n)))
    # per-replica sampler seeds: identical streams across replicas would
    # silently duplicate stochastic rollouts (greedy is seed-invariant)
    engines = [make_rollout_engine(api, params,
                                   dataclasses.replace(shard, seed=s.seed + i))
               for i in range(n)]
    proxies = [LLMProxy(e, name=f"llm_proxy_{i}", slo=replica_slo)
               for i, e in enumerate(engines)]
    counter = itertools.count(n)

    def factory() -> LLMProxy:
        i = next(counter)
        e = make_rollout_engine(api, params,
                                dataclasses.replace(shard, seed=s.seed + i))
        return LLMProxy(e, name=f"llm_proxy_{i}", slo=replica_slo)

    policy = AutoscalePolicy(
        min_replicas=max(1, s.autoscale_min_replicas),
        max_replicas=s.autoscale_max_replicas) if elastic else None
    return engines, proxies, ProxyRouter(
        proxies, replica_factory=factory, autoscale=policy, slo=slo,
        cache_aware=s.cache_aware_routing and s.prefix_cache != "off",
        cache_affinity_slack=s.cache_affinity_slack,
        cache_pull=s.cache_pull)


@dataclasses.dataclass
class RLVRPipeline:
    settings: PipelineSettings
    trainer: HostTrainer
    engine: RolloutEngine          # primary replica (engines[0])
    proxy: LLMProxy                # primary replica (proxies[0])
    buffer: SampleBuffer
    producer: RolloutProducer
    controller: AsyncController
    engines: List[RolloutEngine] = dataclasses.field(default_factory=list)
    proxies: List[LLMProxy] = dataclasses.field(default_factory=list)
    router: Optional[ProxyRouter] = None    # None on a 1-replica fleet
    chaos: List = dataclasses.field(default_factory=list)  # FaultInjectors

    def attach_chaos(self, injector) -> None:
        """Register a ``FaultInjector`` so ``shutdown()`` halts and joins
        it — chaos threads must not outlive the pipeline they torment."""
        self.chaos.append(injector)

    @property
    def client(self):
        """The handle-issuing RolloutClient over this pipeline's fleet."""
        return self.producer.client

    @property
    def rollout_target(self):
        """What producers submit to: the router, or the lone proxy."""
        return self.router if self.router is not None else self.proxy

    def run(self, num_steps: int, timeout: float = 600.0):
        if self.router is not None:
            self.router.start()
            if self.settings.health_probe_interval > 0:
                self.router.start_health_monitor(
                    self.settings.health_probe_interval)
        else:
            for p in (self.proxies or [self.proxy]):
                p.start()
        self.producer.start()
        try:
            return self.controller.train(num_steps, timeout=timeout)
        finally:
            self.shutdown()

    def shutdown(self):
        for inj in self.chaos:
            inj.stop()              # sets halt AND joins the chaos thread
        self.producer.stop()
        self.buffer.close()
        if self.producer.is_alive():
            self.producer.join(timeout=10)
        if self.router is not None:
            self.router.stop()      # joins the health monitor too
        else:
            for p in (self.proxies or [self.proxy]):
                p.stop()


def build_rlvr_pipeline(model_cfg: ModelConfig, s: PipelineSettings,
                        *, task: Optional[ArithmeticTask] = None,
                        reward_fn: Optional[Callable] = None) -> RLVRPipeline:
    task = task or ArithmeticTask(seed=s.seed)
    reward_fn = reward_fn or ArithmeticVerifier(task)
    api = get_api(model_cfg)

    loss_cfg = LossConfig(pg_variant=s.pg_variant, kl_beta=s.kl_beta,
                          tis_clip=s.tis_clip or None)
    opt_cfg = OptConfig(learning_rate=s.learning_rate, warmup_steps=5)
    tcfg = TrainerConfig(max_seq_len=s.max_seq_len,
                         group_size=s.num_return_sequences_in_group,
                         minibatches=s.minibatches, ppo_epochs=s.ppo_epochs,
                         adv_estimator=s.adv_estimator)
    trainer = HostTrainer(api, jax.random.PRNGKey(s.seed), loss_cfg, opt_cfg, tcfg)

    engines, proxies, router = make_rollout_fleet(api, trainer.get_weights(), s)
    alpha = s.async_generation_ratio
    buffer = SampleBuffer(batch_size=s.rollout_batch_size, alpha=alpha)
    producer = RolloutProducer(
        router if router is not None else proxies[0], buffer,
        task.prompt_stream(group_size=s.num_return_sequences_in_group),
        group_size=s.num_return_sequences_in_group,
        max_new_tokens=s.max_new_tokens, reward_fn=reward_fn,
        replicate=s.is_num_return_sequences_expand,
        priority=s.rollout_priority,
        deadline_ms=s.rollout_deadline_ms or None)
    controller = AsyncController(buffer, proxies, trainer.train_on_samples,
                                 trainer.get_weights, alpha=alpha,
                                 weight_sync=s.weight_sync,
                                 weight_sync_timeout=s.weight_sync_timeout,
                                 router=router)
    return RLVRPipeline(s, trainer, engines[0], proxies[0], buffer, producer,
                        controller, engines=engines, proxies=proxies,
                        router=router)


@dataclasses.dataclass
class AgenticPipeline:
    settings: PipelineSettings
    trainer: HostTrainer
    engine: RolloutEngine          # primary replica (engines[0])
    proxy: LLMProxy                # primary replica (proxies[0])
    buffer: SampleBuffer
    pool: EnvManagerPool
    controller: AsyncController
    engines: List[RolloutEngine] = dataclasses.field(default_factory=list)
    proxies: List[LLMProxy] = dataclasses.field(default_factory=list)
    router: Optional[ProxyRouter] = None    # None on a 1-replica fleet
    chaos: List = dataclasses.field(default_factory=list)  # FaultInjectors

    def attach_chaos(self, injector) -> None:
        """Register a ``FaultInjector`` so ``shutdown()`` halts and joins
        it — chaos threads must not outlive the pipeline they torment."""
        self.chaos.append(injector)

    @property
    def client(self):
        """The handle-issuing RolloutClient shared by the env-manager pool."""
        return self.pool.client

    @property
    def rollout_target(self):
        """What env managers submit to: the router, or the lone proxy."""
        return self.router if self.router is not None else self.proxy

    def run(self, num_steps: int, timeout: float = 600.0):
        if self.router is not None:
            self.router.start()
            if self.settings.health_probe_interval > 0:
                self.router.start_health_monitor(
                    self.settings.health_probe_interval)
        else:
            for p in (self.proxies or [self.proxy]):
                p.start()
        self.pool.start()
        try:
            return self.controller.train(num_steps, timeout=timeout)
        finally:
            self.shutdown()

    def shutdown(self):
        for inj in self.chaos:
            inj.stop()              # sets halt AND joins the chaos thread
        self.pool.stop(join=False)  # stop flag + abort every in-flight turn
        self.buffer.close()         # wake managers parked in begin_generation
        # join managers BEFORE stopping the proxies: an aborted turn still
        # needs a live proxy to resolve its handle, and env-manager threads
        # must not outlive the pipeline (leak-checked by the test suite).
        self.pool.stop(join=True)
        if self.router is not None:
            self.router.stop()      # joins the health monitor too
        else:
            for p in (self.proxies or [self.proxy]):
                p.stop()


def build_agentic_pipeline(model_cfg: ModelConfig, s: PipelineSettings, *,
                           make_env: Callable, num_env_groups: int,
                           group_size: int, max_env_steps: int = 8) -> AgenticPipeline:
    api = get_api(model_cfg)
    loss_cfg = LossConfig(pg_variant=s.pg_variant, kl_beta=s.kl_beta,
                          tis_clip=s.tis_clip or None)
    opt_cfg = OptConfig(learning_rate=s.learning_rate, warmup_steps=5)
    tcfg = TrainerConfig(max_seq_len=s.max_seq_len, group_size=group_size,
                         minibatches=s.minibatches, ppo_epochs=s.ppo_epochs,
                         adv_estimator=s.adv_estimator)
    trainer = HostTrainer(api, jax.random.PRNGKey(s.seed), loss_cfg, opt_cfg, tcfg)
    engines, proxies, router = make_rollout_fleet(api, trainer.get_weights(), s)
    buffer = SampleBuffer(batch_size=s.rollout_batch_size,
                          alpha=s.async_generation_ratio)
    pool = EnvManagerPool(make_env, router if router is not None else proxies[0],
                          buffer,
                          num_env_groups=num_env_groups, group_size=group_size,
                          max_steps=max_env_steps,
                          max_new_tokens=s.max_new_tokens,
                          context_mode=s.agentic_context,
                          max_context_tokens=s.max_seq_len - s.max_new_tokens)
    controller = AsyncController(buffer, proxies, trainer.train_on_samples,
                                 trainer.get_weights,
                                 alpha=s.async_generation_ratio,
                                 weight_sync=s.weight_sync,
                                 weight_sync_timeout=s.weight_sync_timeout,
                                 router=router)
    return AgenticPipeline(s, trainer, engines[0], proxies[0], buffer, pool,
                           controller, engines=engines, proxies=proxies,
                           router=router)
