"""Production mesh construction + rollout/train pool partitioning.

Everything here is a FUNCTION — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e: one pod = 16x16 = 256 chips (data, model); two pods add a
    leading `pod` axis (pure DP across the cross-pod DCI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Degenerate 1x1 mesh over the local device (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def split_rollout_train_pools(*, train_chips: int, infer_chips: int,
                              model_parallel: int = 16) -> Tuple[Mesh, Mesh]:
    """Rollout-train decoupling at the resource level (paper Fig 3a: e.g.
    16Train24Infer): partition the device list into two disjoint meshes.

    The trainer mesh is (train_chips/model, model); the rollout mesh is
    (infer_chips/model, model) — weight sync is a device_put of the param
    tree from one submesh to the other (ICI transfers under XLA).
    """
    devs = np.asarray(jax.devices())
    assert train_chips + infer_chips <= devs.size, (
        f"need {train_chips + infer_chips} devices, have {devs.size}")
    assert train_chips % model_parallel == 0 and infer_chips % model_parallel == 0
    train_devs = devs[:train_chips].reshape(train_chips // model_parallel,
                                            model_parallel)
    infer_devs = devs[train_chips:train_chips + infer_chips].reshape(
        infer_chips // model_parallel, model_parallel)
    return (Mesh(train_devs, ("data", "model")),
            Mesh(infer_devs, ("data", "model")))
