"""End-to-end RLVR training driver (the paper's launch entry point).

Runs the full asynchronous architecture — DecodeEngine + LLMProxy +
SampleBuffer(alpha) + RolloutProducer + AsyncController + HostTrainer — on a
synthetic verifiable-math task.  Model size is a preset: `demo` (~3M params,
CPU-friendly), `rl_100m` (~100M, the by-the-book e2e scale).

  PYTHONPATH=src python -m repro.launch.train \
      --steps 60 --async-ratio 2 --pg-variant tis --group-size 4

Set --async-ratio 0 for the synchronous baseline (same code path, suspend
after get_batch — the paper's switch).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.configs import REGISTRY
from repro.data.dataset import VOCAB
from repro.launch.pipeline import PipelineSettings, build_rlvr_pipeline

PRESETS = {
    # name: (d_model, layers, heads, kv, d_ff)  -- vocab = arithmetic VOCAB
    "demo": (128, 2, 4, 2, 512),
    "rl_10m": (256, 4, 4, 2, 1024),
    "rl_100m": (768, 12, 12, 4, 2048),
}


def build_model_cfg(arch: str, preset: str):
    d, l, h, kv, ff = PRESETS[preset]
    base = REGISTRY[arch].smoke()
    return dataclasses.replace(
        base, num_layers=l, d_model=d, num_heads=h, num_kv_heads=kv,
        head_dim=d // h, d_ff=ff, vocab_size=VOCAB,
        num_experts=min(base.num_experts, 4) if base.is_moe else 0,
        moe_d_ff=min(ff // 2, 512) if base.is_moe else 0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=sorted(REGISTRY))
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--async-ratio", type=float, default=2.0)
    ap.add_argument("--pg-variant", default="ppo",
                    choices=["ppo", "decoupled_ppo", "tis", "cispo", "topr",
                             "weighted_topr"])
    ap.add_argument("--rollout-batch-size", type=int, default=16)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--num-slots", type=int, default=16)
    ap.add_argument("--rollout-replicas", type=int, default=1,
                    help="rollout fleet size: >=2 shards --num-slots across "
                         "N proxy/engine replicas behind a ProxyRouter "
                         "(queue scheduling)")
    ap.add_argument("--autoscale-max", type=int, default=0,
                    help="arm load-triggered elasticity: let the fleet grow "
                         "up to this many replicas under queue pressure and "
                         "drain idle ones back down (0 = off)")
    ap.add_argument("--health-probe-interval", type=float, default=0.0,
                    help="run the fleet heartbeat monitor at this period in "
                         "seconds: crashed replicas are detected and their "
                         "in-flight work failed over (0 = dispatch-time "
                         "detection only)")
    ap.add_argument("--slo", action="store_true",
                    help="arm the SLO layer: priority-aware admission, "
                         "preemption, and the deadline/stall watchdog")
    ap.add_argument("--slo-queue-limit", type=int, default=0,
                    help="fleet-wide pending bound per priority class; "
                         "overflow is resolved as a typed Rejected result "
                         "(0 = unbounded)")
    ap.add_argument("--slo-stall-timeout", type=float, default=0.0,
                    help="seconds without decode progress before an active "
                         "request is force-resolved timed_out (0 = off)")
    ap.add_argument("--slo-defer-after", type=int, default=0,
                    help="long-tail watchdog: park a decode that reached "
                         "this many tokens while work queues, so tails "
                         "never block batch completion (0 = off)")
    ap.add_argument("--rollout-quant", default="off",
                    choices=["off", "int8", "fp8"],
                    help="quantize rollout-engine weights at every weight "
                         "sync (trainer stays full precision); pair with "
                         "--tis-clip to absorb the engine mismatch")
    ap.add_argument("--kv-quant", default="off", choices=["off", "int8"],
                    help="store paged-engine KV pages as int8 with "
                         "per-(page,slot,kv-head) scales (~1.8x effective "
                         "KV capacity)")
    ap.add_argument("--tis-clip", type=float, default=0.0,
                    help="truncated-IS cap on the train/rollout engine "
                         "mismatch ratio (FlashRL); 0 = off, typical "
                         "quantized setting: 2.0")
    ap.add_argument("--cache-aware", dest="cache_aware", default=True,
                    action="store_true",
                    help="fleet-global prefix index: route to the replica "
                         "holding a prompt's longest cached prefix when "
                         "loads allow, pull pages across otherwise (default)")
    ap.add_argument("--no-cache-aware", dest="cache_aware",
                    action="store_false",
                    help="disable cache-aware routing (pure least-loaded)")
    ap.add_argument("--cache-affinity-slack", type=int, default=256,
                    help="load band (tokens over the fleet minimum) within "
                         "which the prefix-holding replica wins placement")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write step stats JSON here")
    args = ap.parse_args()

    cfg = build_model_cfg(args.arch, args.preset)
    settings = PipelineSettings(
        async_generation_ratio=args.async_ratio,
        pg_variant=args.pg_variant,
        rollout_batch_size=args.rollout_batch_size,
        num_return_sequences_in_group=args.group_size,
        num_slots=args.num_slots,
        num_rollout_replicas=args.rollout_replicas,
        autoscale_max_replicas=args.autoscale_max,
        health_probe_interval=args.health_probe_interval,
        slo_enabled=args.slo,
        slo_queue_limit_per_class=args.slo_queue_limit,
        slo_stall_timeout=args.slo_stall_timeout,
        slo_defer_after_tokens=args.slo_defer_after,
        rollout_quant=args.rollout_quant,
        kv_quant=args.kv_quant,
        tis_clip=args.tis_clip,
        cache_aware_routing=args.cache_aware,
        cache_affinity_slack=args.cache_affinity_slack,
        max_new_tokens=args.max_new_tokens,
        max_seq_len=32,
        learning_rate=args.lr,
        seed=args.seed,
    )
    pipe = build_rlvr_pipeline(cfg, settings)
    mode = "sync" if args.async_ratio == 0 else f"async(alpha={args.async_ratio})"
    print(f"[train] arch={args.arch} preset={args.preset} {mode} "
          f"variant={args.pg_variant} B={args.rollout_batch_size} "
          f"G={args.group_size}")
    if args.rollout_quant != "off" or args.kv_quant != "off":
        print(f"[train] quant: rollout={args.rollout_quant} "
              f"kv={args.kv_quant} tis_clip={args.tis_clip or 'off'}")

    t0 = time.time()
    stats = pipe.run(args.steps)
    wall = time.time() - t0

    rewards = [s.reward_mean for s in stats]
    k = max(1, len(rewards) // 5)
    print(f"[train] {len(stats)} steps in {wall:.1f}s "
          f"({wall / max(len(stats), 1):.2f}s/step)")
    print(f"[train] reward first-{k}: {sum(rewards[:k]) / k:.3f}  "
          f"last-{k}: {sum(rewards[-k:]) / k:.3f}")
    print(f"[train] staleness max: {max(s.staleness_max for s in stats)}  "
          f"samples produced/consumed: {pipe.buffer.total_produced}/"
          f"{pipe.buffer.total_consumed}")
    if pipe.router is not None:
        r = pipe.router
        print(f"[train] fleet: replicas={r.num_replicas} "
              f"alive={r.replicas_alive} added={r.replicas_added} "
              f"failed={r.replicas_failed} failovers={r.failovers} "
              f"lost_tokens={r.lost_tokens} migrations={r.migrations}")
        print(f"[train] fleet cache: cache_routed={r.cache_routed} "
              f"cache_pulls={r.cache_pulls} "
              f"pages_transferred={r.pages_transferred} "
              f"transfer_bytes={r.transfer_bytes}")
    if args.slo and stats:
        last = stats[-1]
        print(f"[train] slo: deadline_misses={last.deadline_misses} "
              f"preemptions={last.preemptions} rejected={last.rejected} "
              f"queue_depth_by_class={last.queue_depth_by_class}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([dataclasses.asdict(s) for s in stats], f, indent=1)


if __name__ == "__main__":
    main()
