"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t), with
a_t = exp(-c * softplus(Lambda) * r_t), r/i input-dependent sigmoid gates.
Training uses `jax.lax.associative_scan` (linear recurrence); decode is a
single fused step.  The block is: linear -> causal depthwise conv(4) ->
RG-LRU on one branch, linear -> GeLU on the other, merged multiplicatively.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import module
from repro.models.config import ModelConfig

_C = 8.0


class RGLRUState(NamedTuple):
    h: jax.Array     # (B, W) fp32 recurrent state
    conv: jax.Array  # (B, conv_width-1, W) previous conv inputs


def init_rglru_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, w), jnp.dtype(cfg.dtype)),
    )


def init_recurrent_block(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "wx": module.dense_init(ks[0], d, w, dt),       # conv/LRU branch in
        "wy": module.dense_init(ks[1], d, w, dt),       # gate branch in
        "wo": module.dense_init(ks[2], w, d, dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "lam": jnp.full((w,), 2.0, jnp.float32),        # softplus(2)~2.1 -> slow decay
        "wa": module.dense_init(ks[4], w, w, dt, scale=0.01),
        "ba": jnp.zeros((w,), jnp.float32),
        "wi": module.dense_init(ks[5], w, w, dt, scale=0.01),
        "bi": jnp.zeros((w,), jnp.float32),
    }


def _causal_conv(p, x, conv_state):
    """Depthwise causal conv width K. x: (B,S,W); conv_state: (B,K-1,W)."""
    k = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_state, x], axis=1)  # (B, K-1+S, W)
    out = p["conv_b"]
    s = x.shape[1]
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        acc = acc + full[:, i:i + s, :].astype(jnp.float32) * p["conv_w"][k - 1 - i].astype(jnp.float32)
    new_state = full[:, -(k - 1):, :]
    return (acc + out).astype(x.dtype), new_state


def _gates(p, xc):
    r = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * xc.astype(jnp.float32))


def rglru_scan(a, b, h0):
    """h_t = a_t h_{t-1} + b_t via associative scan. a,b: (B,S,W); h0: (B,W)."""
    # prepend h0 as an element with a=0, b=h0
    a_ext = jnp.concatenate([jnp.zeros_like(h0)[:, None, :], a], axis=1)
    b_ext = jnp.concatenate([h0[:, None, :], b], axis=1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a_ext, b_ext), axis=1)
    return hs[:, 1:, :]  # (B,S,W)


def recurrent_block(p, cfg: ModelConfig, x, state: RGLRUState):
    """x: (B,S,D) -> (B,S,D), new state."""
    gate = jax.nn.gelu(x @ p["wy"], approximate=True)
    xb = x @ p["wx"]
    xc, conv_state = _causal_conv(p, xb, state.conv)
    a, b = _gates(p, xc)
    hs = rglru_scan(a, b, state.h)
    out = (hs.astype(x.dtype) * gate) @ p["wo"]
    return out, RGLRUState(h=hs[:, -1, :], conv=conv_state)


def recurrent_step(p, cfg: ModelConfig, x, state: RGLRUState):
    """Decode: x (B,1,D)."""
    gate = jax.nn.gelu(x @ p["wy"], approximate=True)
    xb = x @ p["wx"]
    xc, conv_state = _causal_conv(p, xb, state.conv)
    a, b = _gates(p, xc)  # (B,1,W)
    h = a[:, 0] * state.h + b[:, 0]
    out = (h[:, None, :].astype(x.dtype) * gate) @ p["wo"]
    return out, RGLRUState(h=h, conv=conv_state)
