"""Path-based parameter sharding rules.

Parameters are nested dicts; rules regex-match the '/'-joined tree path and
yield a PartitionSpec *template* for the trailing dims.  Layer stacking
prepends axes (blocks are stacked over layers/groups), so templates are
right-aligned: a rank-3 array matched by a rank-2 template gets `None`
prepended.  Any dim not divisible by its mesh axis falls back to replication
(GQA kv projections with few heads, tiny LoRA factors, ...).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on path, right-aligned spec template). First match wins.
# Two-axis sharding: the tensor-parallel dim shards over `model`, the other
# big dim shards over `data` (FSDP/ZeRO-style — essential for the 235B MoE
# optimizer state to fit per-chip HBM).  Divisibility fallback per-dim.
PARAM_RULES: list[tuple[str, tuple]] = [
    # --- MoE (expert-parallel over `model`, FSDP over d_model/d_ff) ---
    (r"moe/router$", ("data", None)),
    (r"moe/w_(gate|up|down)$", ("model", "data", None)),
    # --- channel-mix down-proj before generic wv rule ---
    (r"channel_mix/wv$", ("model", "data")),
    (r"channel_mix/w[kr]$", ("data", "model")),
    # --- attention / generic projections ---
    (r"(attn|cross)/w[qkv]$", ("data", "model")),
    (r"(attn|cross)/wo$", ("model", "data")),
    # --- MLP ---
    (r"wi_(gate|up)$", ("data", "model")),
    (r"mlp/wo$", ("model", "data")),
    # --- RWKV time-mix ---
    (r"time_mix/w[rkvg]$", ("data", "model")),
    (r"time_mix/wo$", ("model", "data")),
    (r"time_mix/(mix_[ab]|decay_[ab]|u|ln_scale|ln_bias)$", ()),  # replicate
    # --- RG-LRU ---
    # RG-LRU branch: weights are tiny (W^2) next to its fp32 activations
    # (B_loc*S = 16x W), so tensor-parallel W sharding made GSPMD bounce
    # 1 GiB (B,S,W) f32 tensors between every producer/consumer (§Perf
    # iter 4, two refuted attempts in EXPERIMENTS.md).  FSDP-only sharding
    # gathers ~32 MiB weights per use instead — activations stay local.
    (r"rec/w[xy]$", ("data", None)),
    (r"rec/wo$", (None, "data")),
    (r"rec/w[ai]$", ("data", None)),
    (r"rec/conv_w$", (None, "model")),
    # --- embeddings / head ---
    (r"embed$", ("model", "data")),
    (r"lm_head$", ("data", "model")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path: str, shape: tuple, mesh_axes: dict[str, int]) -> P:
    for pat, template in PARAM_RULES:
        if re.search(pat, path):
            if not template:
                return P()
            spec = [None] * (len(shape) - len(template)) + list(template)
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                # the FSDP dim shards over (data, pod): ZeRO across pods —
                # without it the multi-pod mesh replicates the fp32 optimizer
                # per pod and 235B-scale training cannot fit (§Perf iter 7)
                if ax == "data" and "pod" in mesh_axes:
                    ax = ("data", "pod")
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= mesh_axes.get(a, 1)
                if shape[i] % size != 0:
                    # retry without the pod axis before full fallback
                    size = mesh_axes.get(axes[0], 1)
                    ax = axes[0]
                    if shape[i] % size != 0:
                        spec[i] = None
                        continue
                spec[i] = ax
            return P(*spec)
    return P()  # replicate by default (norm scales, biases, small factors)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)  # works for Mesh and AbstractMesh


def param_specs(params: Any, mesh: Mesh):
    """Tree of PartitionSpec matching `params` (arrays or ShapeDtypeStructs)."""
    sizes = mesh_axis_sizes(mesh)

    def one(path, leaf):
        return _spec_for(_path_str(path), tuple(leaf.shape), sizes)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, mesh: Mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), param_specs(params, mesh))


def batch_axes(mesh: Mesh):
    """Mesh axes used for data parallelism, e.g. ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_spec(mesh: Mesh, rank: int, *, batch_dim: int = 0, shard_batch: bool = True) -> P:
    """PartitionSpec for an activation/input of given rank: batch over dp axes."""
    spec = [None] * rank
    if shard_batch:
        spec[batch_dim] = batch_axes(mesh)
    return P(*spec)


def shardable_batch(mesh: Mesh, batch: int) -> bool:
    sizes = mesh_axis_sizes(mesh)
    dp = int(np.prod([sizes[a] for a in batch_axes(mesh)]))
    return batch % dp == 0


# ---------------------------------------------------------------------------
# cache / state sharding: batch-shard everything with a leading (L, B, ...)
# or (B, ...) layout; fall back to replication when batch is unshardable
# (long_500k, B=1) — the model axis still shards params.
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# activation sharding hook: the launcher installs a spec; transformer scan
# bodies constrain the residual stream with it (sequence-parallel-style
# activation sharding keeps remat-saved activations within per-chip HBM).
# ---------------------------------------------------------------------------

_ACTIVATION_SPEC: list = [None]


def set_activation_sharding(spec) -> None:
    """Install (or clear with None) a PartitionSpec for (B, S, D) activations."""
    _ACTIVATION_SPEC[0] = spec


def constrain_activation(x):
    spec = _ACTIVATION_SPEC[0]
    if spec is None or x.ndim != 3:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # no mesh context (unit tests)


def cache_specs(cache: Any, mesh: Mesh, *, shard_batch: bool = True):
    sizes = mesh_axis_sizes(mesh)
    dp = int(np.prod([sizes[a] for a in batch_axes(mesh)])) or 1

    md = sizes.get("model", 1)

    def one(path, leaf):
        rank = len(leaf.shape)
        spec = [None] * rank
        # stacked caches are (L, B, ...); hybrid "tail" entries are (B, ...)
        bd = 0 if "tail" in _path_str(path) else 1
        bd = min(bd, rank - 1)
        if shard_batch and leaf.shape[bd] % dp == 0 and leaf.shape[bd] >= dp:
            spec[bd] = batch_axes(mesh)
        elif rank >= bd + 2:
            # batch unshardable (long_500k, B=1): context-parallel fallback —
            # shard the sequence axis of KV caches over `data`
            sd = bd + 1
            d_size = sizes.get("data", 1)
            if leaf.shape[sd] % d_size == 0 and leaf.shape[sd] >= d_size and leaf.shape[sd] > md:
                spec[sd] = "data"
        # tensor-parallel one more axis — KV caches at 32k x 128B do not fit
        # per-chip HBM under batch sharding alone.  Prefer the LARGEST
        # still-unsharded axis (the sequence axis for KV caches): decode
        # attention REDUCES over it, which GSPMD turns into cheap partial-
        # softmax all-reduces, whereas sharding head_dim forced full-tensor
        # resharding at every GQA reshape (§Perf iter 2).
        if rank >= bd + 3 and not np.issubdtype(leaf.dtype, np.integer):
            cands = [i for i in range(bd + 1, rank) if spec[i] is None]
            cands.sort(key=lambda i: -leaf.shape[i])
            for i in cands:
                if leaf.shape[i] % md == 0 and leaf.shape[i] >= md:
                    spec[i] = "model"
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)
