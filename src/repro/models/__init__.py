from repro.models.config import ModelConfig  # noqa: F401
from repro.models.api import ModelAPI, get_api  # noqa: F401
