"""Uniform model API across families.

Everything downstream (trainer, rollout engine, dry-run launcher) talks to
models only through this facade:

    api = get_api(cfg)
    params = api.init(key)
    logits, aux = api.apply(params, batch)                 # train forward
    logits, cache = api.prefill(params, batch, cache)      # fill caches
    logits, cache = api.decode_step(params, token, pos, cache)
    cache = api.init_cache(batch_size, max_len)

`batch` is a dict; which keys exist depends on family:
    tokens          (B, S) int32          all families
    frames          (B, T, D)             audio (stubbed frontend output)
    patches         (B, P, D)             vlm   (stubbed vision embeddings)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]
    apply: Callable[..., Any]        # (params, batch, remat=, moe_mode=) -> (logits, aux)
    prefill: Callable[..., Any]      # (params, batch, cache) -> (logits, cache)
    decode_step: Callable[..., Any]  # (params, token, pos, cache) -> (logits, cache)
    init_cache: Callable[..., Any]   # (batch, max_len) -> cache


def get_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "audio":
        def init(key):
            return encdec.init_encdec(key, cfg)

        def apply(params, batch, *, remat=False, moe_mode="ep",
                  return_features=False):
            return encdec.encdec_apply(params, cfg, batch["frames"], batch["tokens"],
                                       remat=remat, return_features=return_features)

        def prefill(params, batch, cache, *, moe_mode="ep"):
            del moe_mode  # enc-dec backbone is dense
            return encdec.encdec_prefill(params, cfg, batch["frames"], batch["tokens"], cache)

        def decode_step(params, token, pos, cache, *, moe_mode="ep"):
            del moe_mode
            return encdec.encdec_decode_step(params, cfg, token, pos, cache)

        def init_cache(batch, max_len):
            return encdec.init_dec_cache(cfg, batch, max_len, cfg.encoder_frames)

        return ModelAPI(cfg, init, apply, prefill, decode_step, init_cache)

    # decoder-only families (dense / moe / ssm / hybrid / vlm)
    def init(key):
        return transformer.init_lm(key, cfg)

    def apply(params, batch, *, remat=False, moe_mode="ep",
              return_features=False):
        return transformer.lm_apply(params, cfg, batch["tokens"],
                                    prefix_embeds=batch.get("patches"),
                                    remat=remat, moe_mode=moe_mode,
                                    return_features=return_features)

    def prefill(params, batch, cache, *, moe_mode="ep"):
        return transformer.lm_prefill(params, cfg, batch["tokens"], cache,
                                      prefix_embeds=batch.get("patches"),
                                      moe_mode=moe_mode,
                                      valid=batch.get("valid"))

    def decode_step(params, token, pos, cache, *, moe_mode="ep"):
        return transformer.lm_decode_step(params, cfg, token, pos, cache,
                                          moe_mode=moe_mode)

    def init_cache(batch, max_len):
        extra = cfg.num_image_tokens if cfg.family == "vlm" else 0
        return transformer.init_cache(cfg, batch, max_len + extra)

    return ModelAPI(cfg, init, apply, prefill, decode_step, init_cache)
