"""Uniform model API across families.

Everything downstream (trainer, rollout engine, dry-run launcher) talks to
models only through this facade:

    api = get_api(cfg)
    params = api.init(key)
    logits, aux = api.apply(params, batch)                 # train forward
    logits, cache = api.prefill(params, batch, cache)      # fill caches
    logits, cache = api.decode_step(params, token, pos, cache)
    cache = api.init_cache(batch_size, max_len)

`batch` is a dict; which keys exist depends on family:
    tokens          (B, S) int32          all families
    frames          (B, T, D)             audio (stubbed frontend output)
    patches         (B, P, D)             vlm   (stubbed vision embeddings)

Attention families (dense/moe) additionally expose the paged-KV views used
by the paged continuous-batching engine (rollout/paged_engine.py): the KV
cache is a shared page pool indexed through per-request block tables, and
prefill happens in fixed-size chunks instead of one variable-length call.
Families without positional KV (ssm/hybrid/audio/vlm) leave these None.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


from repro.models import encdec, paged, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]
    apply: Callable[..., Any]        # (params, batch, remat=, moe_mode=) -> (logits, aux)
    prefill: Callable[..., Any]      # (params, batch, cache) -> (logits, cache)
    decode_step: Callable[..., Any]  # (params, token, pos, cache) -> (logits, cache)
    init_cache: Callable[..., Any]   # (batch, max_len) -> cache
    # paged-KV views (None for families without positional KV caches)
    init_paged_cache: Optional[Callable[..., Any]] = None  # (num_pages, page_size, kv_quant=) -> PagedKVCache
    prefill_chunk: Optional[Callable[..., Any]] = None     # (params, tokens, valid, start, block_row, cache) -> (logits, cache)
    decode_paged: Optional[Callable[..., Any]] = None      # (params, token, pos, cache, block_tables, attn_impl=) -> (logits, cache)
    cache_view: Optional[Callable[..., Any]] = None        # (layer_pages, block_row) -> (k, v, valid) dense per-request view


def get_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "audio":
        def init(key):
            return encdec.init_encdec(key, cfg)

        def apply(params, batch, *, remat=False, moe_mode="ep",
                  return_features=False):
            return encdec.encdec_apply(params, cfg, batch["frames"], batch["tokens"],
                                       remat=remat, return_features=return_features)

        def prefill(params, batch, cache, *, moe_mode="ep"):
            del moe_mode  # enc-dec backbone is dense
            return encdec.encdec_prefill(params, cfg, batch["frames"], batch["tokens"], cache)

        def decode_step(params, token, pos, cache, *, moe_mode="ep"):
            del moe_mode
            return encdec.encdec_decode_step(params, cfg, token, pos, cache)

        def init_cache(batch, max_len):
            return encdec.init_dec_cache(cfg, batch, max_len, cfg.encoder_frames)

        return ModelAPI(cfg, init, apply, prefill, decode_step, init_cache)

    # decoder-only families (dense / moe / ssm / hybrid / vlm)
    def init(key):
        return transformer.init_lm(key, cfg)

    def apply(params, batch, *, remat=False, moe_mode="ep",
              return_features=False):
        return transformer.lm_apply(params, cfg, batch["tokens"],
                                    prefix_embeds=batch.get("patches"),
                                    remat=remat, moe_mode=moe_mode,
                                    return_features=return_features)

    def prefill(params, batch, cache, *, moe_mode="ep"):
        return transformer.lm_prefill(params, cfg, batch["tokens"], cache,
                                      prefix_embeds=batch.get("patches"),
                                      moe_mode=moe_mode,
                                      valid=batch.get("valid"))

    def decode_step(params, token, pos, cache, *, moe_mode="ep"):
        return transformer.lm_decode_step(params, cfg, token, pos, cache,
                                          moe_mode=moe_mode)

    def init_cache(batch, max_len):
        extra = cfg.num_image_tokens if cfg.family == "vlm" else 0
        return transformer.init_cache(cfg, batch, max_len + extra)

    if not paged.supports_paged(cfg):
        return ModelAPI(cfg, init, apply, prefill, decode_step, init_cache)

    def init_paged_cache(num_pages, page_size, kv_quant="off"):
        return paged.init_paged_cache(cfg, num_pages, page_size,
                                      kv_quant=kv_quant)

    def prefill_chunk(params, tokens, valid, start, block_row, cache, *,
                      moe_mode="ep"):
        return paged.paged_prefill_chunk(params, cfg, tokens, valid, start,
                                         block_row, cache, moe_mode=moe_mode)

    def decode_paged(params, token, pos, cache, block_tables, *,
                     moe_mode="ep", attn_impl="ref"):
        return paged.paged_decode_step(params, cfg, token, pos, cache,
                                       block_tables, moe_mode=moe_mode,
                                       attn_impl=attn_impl)

    return ModelAPI(cfg, init, apply, prefill, decode_step, init_cache,
                    init_paged_cache=init_paged_cache,
                    prefill_chunk=prefill_chunk, decode_paged=decode_paged,
                    cache_view=paged.gather_request_view)
