"""Minimal pure-JAX module substrate.

Parameters are nested dicts of jnp arrays.  ``init_*`` functions build the
tree; ``apply``-style functions consume it.  No flax — the tree layout is
the API, and `models/sharding.py` pattern-matches tree paths to produce
PartitionSpecs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(cfg_dtype):
    return jnp.dtype(cfg_dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    """Truncated-normal dense kernel (d_in, d_out)."""
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d_model), jnp.float32)).astype(dtype)


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


def rmsnorm_head(scale, x, eps: float = 1e-6):
    """RMSNorm over the trailing head_dim (qk-norm), scale shape (head_dim,)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    inv_freq = jnp.asarray(rope_frequencies(head_dim, theta))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., seq, half)
    angles = angles[..., None, :]  # (..., seq, 1, half) broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params)))


def fold_key(key, i: int):
    return jax.random.fold_in(key, i)
