"""RWKV-6 "Finch" block: data-dependent token-shift + decay linear attention.

Faithful to arXiv:2404.05892: time-mixing with LoRA-modulated token shift,
per-channel data-dependent decay w_t = exp(-exp(.)), bonus u, per-head WKV
state S in R^{hd x hd}; channel-mixing with squared-ReLU.

Training path runs `jax.lax.scan` over time (the Pallas kernel in
`kernels/rwkv6_scan.py` is the TPU hot-spot version; this module is the
XLA-lowering path used by pjit).  Decode carries {wkv, tm_prev, cm_prev}.
"""
from __future__ import annotations

from typing import NamedTuple

import functools

import jax
import jax.numpy as jnp

from repro.models import module
from repro.models.config import ModelConfig

_MIX_LORA = 32
_DECAY_LORA = 64


class RWKVState(NamedTuple):
    wkv: jax.Array      # (B, H, hd, hd) fp32
    tm_prev: jax.Array  # (B, D)
    cm_prev: jax.Array  # (B, D)


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RWKVState:
    h, hd, d = cfg.num_rwkv_heads, cfg.rwkv_head_size, cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    return RWKVState(
        wkv=jnp.zeros((batch, h, hd, hd), jnp.float32),
        tm_prev=jnp.zeros((batch, d), dt),
        cm_prev=jnp.zeros((batch, d), dt),
    )


def init_time_mix(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    d, h, hd = cfg.d_model, cfg.num_rwkv_heads, cfg.rwkv_head_size
    ks = jax.random.split(key, 12)
    return {
        "mu_x": jnp.zeros((d,), dt), "mu_w": jnp.zeros((d,), dt),
        "mu_k": jnp.zeros((d,), dt), "mu_v": jnp.zeros((d,), dt),
        "mu_r": jnp.zeros((d,), dt), "mu_g": jnp.zeros((d,), dt),
        # token-shift LoRA: (D, 5*r) tanh (5, r, D)
        "mix_a": module.dense_init(ks[0], d, 5 * _MIX_LORA, dt, scale=0.01),
        "mix_b": (jax.random.normal(ks[1], (5, _MIX_LORA, d)) * 0.01).astype(dt),
        # decay: w = exp(-exp(w0 + tanh(x@da)@db))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_a": module.dense_init(ks[2], d, _DECAY_LORA, dt, scale=0.01),
        "decay_b": (jax.random.normal(ks[3], (_DECAY_LORA, d)) * 0.01).astype(dt),
        "u": (jax.random.normal(ks[4], (h, hd)) * 0.1).astype(jnp.float32),
        "wr": module.dense_init(ks[5], d, d, dt),
        "wk": module.dense_init(ks[6], d, d, dt),
        "wv": module.dense_init(ks[7], d, d, dt),
        "wg": module.dense_init(ks[8], d, d, dt),
        "wo": module.dense_init(ks[9], d, d, dt),
        "ln_scale": jnp.ones((h, hd), jnp.float32),
        "ln_bias": jnp.zeros((h, hd), jnp.float32),
    }


def init_channel_mix(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dt), "mu_r": jnp.zeros((d,), dt),
        "wk": module.dense_init(ks[0], d, f, dt),
        "wv": module.dense_init(ks[1], f, d, dt),
        "wr": module.dense_init(ks[2], d, d, dt),
    }


def _head_groupnorm(p, y, eps=1e-5):
    """y: (..., H, hd) layernorm per head."""
    yf = y.astype(jnp.float32)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    return ((yf - mean) * jax.lax.rsqrt(var + eps) * p["ln_scale"] + p["ln_bias"])


def _token_shift_inputs(p, x, prev):
    """Finch data-dependent token shift.

    x: (B,S,D); prev: (B,D) state (token before x[:,0]).
    Returns xw, xk, xv, xr, xg each (B,S,D), plus new prev (B,D).
    """
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = shifted - x
    xxx = x + sx * p["mu_x"]
    a = jnp.tanh(xxx @ p["mix_a"])                   # (B,S,5r)
    b, s, _ = a.shape
    a = a.reshape(b, s, 5, _MIX_LORA)
    adj = jnp.einsum("bsnr,nrd->bsnd", a, p["mix_b"])  # (B,S,5,D)
    mus = jnp.stack([p["mu_w"], p["mu_k"], p["mu_v"], p["mu_r"], p["mu_g"]])
    mixed = x[:, :, None, :] + sx[:, :, None, :] * (mus + adj)
    xw, xk, xv, xr, xg = [mixed[:, :, i, :] for i in range(5)]
    return xw, xk, xv, xr, xg, x[:, -1, :]


def _decay(p, xw):
    """w in (0,1): (B,S,D) fp32."""
    lora = jnp.tanh(xw @ p["decay_a"]).astype(jnp.float32) @ p["decay_b"].astype(jnp.float32)
    return jnp.exp(-jnp.exp(p["w0"] + lora))


_WKV_CHUNK = 256


def wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence.

    r,k,v: (B,S,H,hd); w: (B,S,H,hd) decay in (0,1); u: (H,hd);
    state: (B,H,hd,hd).  Returns y (B,S,H,hd) fp32, new state.

    Time is chunked with `jax.checkpoint` around each chunk: naive scan AD
    saves the (B,H,hd,hd) carry at EVERY step (~43 GiB/device at 4k train,
    §Perf iter 5); chunking saves it only at chunk boundaries and
    rematerializes inside, bounding residuals to chunk-local.
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        a = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., :, None] * a)
        s = wt[..., :, None] * s + a
        return s, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))  # (S,B,H,hd)
    s_len = xs[0].shape[0]
    if s_len <= _WKV_CHUNK or s_len % _WKV_CHUNK != 0:
        state, ys = jax.lax.scan(step, state, xs)
        return ys.transpose(1, 0, 2, 3), state

    nc = s_len // _WKV_CHUNK
    xs_c = tuple(t.reshape((nc, _WKV_CHUNK) + t.shape[1:]) for t in xs)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(s, inp):
        s, ys = jax.lax.scan(step, s, inp)
        return s, ys

    state, ys = jax.lax.scan(chunk_body, state, xs_c)
    ys = ys.reshape((s_len,) + ys.shape[2:])
    return ys.transpose(1, 0, 2, 3), state


def time_mix(p, cfg: ModelConfig, x, prev, wkv_state):
    b, s, d = x.shape
    h, hd = cfg.num_rwkv_heads, cfg.rwkv_head_size
    xw, xk, xv, xr, xg, new_prev = _token_shift_inputs(p, x, prev)
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(b, s, h, hd)
    y, new_state = wkv_scan(r, k, v, w, p["u"], wkv_state)
    y = _head_groupnorm(p, y).reshape(b, s, d).astype(x.dtype)
    return (y * g) @ p["wo"], new_prev, new_state


def channel_mix(p, x, prev):
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = shifted - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    v = k @ p["wv"]
    return jax.nn.sigmoid(xr @ p["wr"]) * v, x[:, -1, :]


# ---------------------------------------------------------------------------
# full block (pre-norm residual, as upstream RWKV)
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": module.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "ln2": module.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "time_mix": init_time_mix(ks[0], cfg),
        "channel_mix": init_channel_mix(ks[1], cfg),
    }


def block(p, cfg: ModelConfig, x, state: RWKVState):
    y, tm_prev, wkv = time_mix(p["time_mix"], cfg, module.rmsnorm(p["ln1"], x, cfg.norm_eps),
                               state.tm_prev, state.wkv)
    x = x + y
    y, cm_prev = channel_mix(p["channel_mix"], module.rmsnorm(p["ln2"], x, cfg.norm_eps),
                             state.cm_prev)
    x = x + y
    return x, RWKVState(wkv=wkv, tm_prev=tm_prev, cm_prev=cm_prev)
