"""Model configuration for all assigned architecture families.

One dataclass covers the six families (dense / moe / ssm / hybrid / vlm /
audio): family-specific fields are simply unused elsewhere.  Configs are
plain data — no jax imports here — so importing a config never touches
device state (required by the dry-run contract).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    # trunk
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention flavour
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # tokens; None = full attention
    rope_theta: float = 10_000.0
    attn_logit_softcap: Optional[float] = None

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0  # per-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    # SSM / RWKV6
    rwkv_head_size: int = 64

    # hybrid (RecurrentGemma): block pattern repeated over depth,
    # e.g. ("rglru", "rglru", "attn")
    block_pattern: Optional[Tuple[str, ...]] = None
    lru_width: Optional[int] = None
    conv_width: int = 4

    # enc-dec (audio)
    num_encoder_layers: int = 0
    encoder_frames: int = 1024  # stubbed audio frontend output length

    # vlm
    num_image_tokens: int = 0

    # activations / norms
    mlp_activation: str = "swiglu"  # swiglu | geglu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # numerics
    dtype: str = "bfloat16"

    # --- derived ---
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM / hybrid / sliding-window archs."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        head_dim = d_model // n_heads
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        pattern = self.block_pattern
        num_layers = 2 if pattern is None else len(pattern)
        return dataclasses.replace(
            self,
            num_layers=num_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.is_moe else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2) if self.is_moe else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.is_moe else 0,
            rwkv_head_size=min(self.rwkv_head_size, 32),
            lru_width=min(self.lru_width, 256) if self.lru_width else None,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_frames=min(self.encoder_frames, 64),
            num_image_tokens=min(self.num_image_tokens, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
        )
