"""GQA attention: full-sequence (train/prefill), decode-step, cross-attention.

Design notes
------------
* KV caches are statically shaped ``(B, S_max, n_kv, head_dim)`` plus an
  int32 position map ``(B, S_max)`` (−1 = empty).  Sliding-window archs
  allocate ``S_max = window`` and write at ``pos % S_max`` (ring buffer);
  the position map makes masking uniform across full and ring caches.
* Full-sequence attention uses an online-softmax scan over KV blocks
  (flash-style in pure jnp) so prefill at 32k never materialises the
  (S, S) score matrix.  Small sequences take the direct einsum path.
* GQA is expressed by reshaping queries to (B, S, n_kv, group, head_dim);
  KV heads are never repeated in memory.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import module
from repro.models.config import ModelConfig

_DIRECT_PATH_MAX_SEQ = 2048  # below this, materialise scores directly
_KV_BLOCK = 1024


def init_attention(key, cfg: ModelConfig, *, cross: bool = False):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    hd = cfg.resolved_head_dim
    p = {
        "wq": module.dense_init(ks[0], cfg.d_model, cfg.q_dim, dt),
        "wk": module.dense_init(ks[1], cfg.d_model, cfg.kv_dim, dt),
        "wv": module.dense_init(ks[2], cfg.d_model, cfg.kv_dim, dt),
        "wo": module.dense_init(ks[3], cfg.q_dim, cfg.d_model, dt),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array    # (B, S_max, n_kv, head_dim)
    v: jax.Array    # (B, S_max, n_kv, head_dim)
    pos: jax.Array  # (B, S_max) int32, -1 = empty


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *, window: Optional[int] = None) -> KVCache:
    w = window if window is not None else cfg.sliding_window
    s = min(max_len, w) if w is not None else max_len
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return KVCache(
        k=jnp.zeros((batch, s, cfg.num_kv_heads, hd), dt),
        v=jnp.zeros((batch, s, cfg.num_kv_heads, hd), dt),
        pos=jnp.full((batch, s), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# core attend
# ---------------------------------------------------------------------------

def _soft_cap(logits, cap):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def _attend_direct(q, k, v, q_pos, kv_pos, kv_valid, *, window, softcap):
    """q: (B,Sq,KV,G,hd); k/v: (B,Skv,KV,hd). Positions int32.

    Materialises the score tensor — only for short sequences / decode.
    """
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    logits = _soft_cap(logits, softcap)
    mask = kv_valid[:, None, None, None, :] & (kv_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None])
    if window is not None:
        mask &= (q_pos[:, None, None, :, None] - kv_pos[:, None, None, None, :]) < window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", probs.astype(v.dtype), v)
    return out


_Q_CHUNK = 2048


def _attend_blockwise(q, k, v, q_pos, kv_pos, kv_valid, **kwargs):
    """Two-level memory-efficient attention.

    Outer: lax.map over query chunks (rematerialized — flash-style backward
    recomputes each chunk's KV sweep instead of saving S x S residuals).
    Inner: online-softmax scan over KV blocks.  Peak live logits are
    (B, H, q_chunk, block_k) instead of (B, H, S, block_k) — at 32k this is
    the difference between ~1 TiB and a few GiB per device (§Perf iter 1).
    """
    b, sq, nkv, g, hd = q.shape
    if sq <= _Q_CHUNK:
        return _attend_kv_scan(q, k, v, q_pos, kv_pos, kv_valid, **kwargs)
    nqc = -(-sq // _Q_CHUNK)
    pad = nqc * _Q_CHUNK - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    qc = q.reshape(b, nqc, _Q_CHUNK, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    pc = q_pos.reshape(b, nqc, _Q_CHUNK).transpose(1, 0, 2)

    def body(chunk):
        qi, pi = chunk
        return _attend_kv_scan(qi, k, v, pi, kv_pos, kv_valid, **kwargs)

    out = jax.lax.map(jax.checkpoint(body, prevent_cse=False), (qc, pc))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nqc * _Q_CHUNK, nkv, g, hd)
    return out[:, :sq]


def _attend_kv_scan(q, k, v, q_pos, kv_pos, kv_valid, *, window, softcap, block=_KV_BLOCK):
    """Online-softmax scan over KV blocks. Same field order as _attend_direct."""
    b, sq, nkv, g, hd = q.shape
    skv = k.shape[1]
    nblk = -(-skv // block)
    pad = nblk * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)), constant_values=False)

    kb = k.reshape(b, nblk, block, nkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block, nkv, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(b, nblk, block).transpose(1, 0, 2)
    mb = kv_valid.reshape(b, nblk, block).transpose(1, 0, 2)

    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    def step(carry, blk):
        m, l, acc = carry
        kj, vj, pj, vmj = blk
        logits = jnp.einsum("bqkgd,btkd->bkgqt", qf, kj.astype(jnp.float32))
        logits = _soft_cap(logits, softcap)
        mask = vmj[:, None, None, None, :] & (pj[:, None, None, None, :] <= q_pos[:, None, None, :, None])
        if window is not None:
            mask &= (q_pos[:, None, None, :, None] - pj[:, None, None, None, :]) < window
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bkgqt,btkd->bkgqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, nkv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb, mb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,KV,G,hd)


def attend(q, k, v, q_pos, kv_pos, kv_valid, *, window=None, softcap=None):
    if k.shape[1] <= _DIRECT_PATH_MAX_SEQ:
        return _attend_direct(q, k, v, q_pos, kv_pos, kv_valid, window=window, softcap=softcap)
    return _attend_blockwise(q, k, v, q_pos, kv_pos, kv_valid, window=window, softcap=softcap)


# ---------------------------------------------------------------------------
# layer-level entry points
# ---------------------------------------------------------------------------

def _project_q(p, cfg: ModelConfig, x, positions, *, rope=True):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    if cfg.qk_norm and "q_norm" in p:
        q = module.rmsnorm_head(p["q_norm"], q, cfg.norm_eps)
    if rope:
        q = module.apply_rope(q, positions, cfg.rope_theta)
    return q.reshape(b, s, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, hd)


def _project_kv(p, cfg: ModelConfig, x, positions, *, rope=True):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm and "k_norm" in p:
        k = module.rmsnorm_head(p["k_norm"], k, cfg.norm_eps)
    if rope:
        k = module.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def self_attention(p, cfg: ModelConfig, x, positions, *, causal=True, window="cfg"):
    """Full-sequence self-attention. x: (B,S,D); positions: (B,S) int32."""
    b, s, _ = x.shape
    w = cfg.sliding_window if window == "cfg" else window
    q = _project_q(p, cfg, x, positions)
    k, v = _project_kv(p, cfg, x, positions)
    kv_valid = jnp.ones((b, s), bool)
    q_pos = positions if causal else jnp.full_like(positions, jnp.iinfo(jnp.int32).max)
    out = attend(q, k, v, q_pos, positions, kv_valid, window=w, softcap=cfg.attn_logit_softcap)
    return out.reshape(b, s, cfg.q_dim) @ p["wo"]


def prefill_attention(p, cfg: ModelConfig, x, positions, cache: KVCache, *,
                      window="cfg", valid=None):
    """Causal self-attention that also writes the KV cache.

    Requires cache S_max >= S for full caches; ring caches keep the last
    `window` tokens.  `valid` (B,S) masks right-padded prompt slots: invalid
    positions are excluded from attention and written with pos=-1.
    """
    b, s, _ = x.shape
    w = cfg.sliding_window if window == "cfg" else window
    q = _project_q(p, cfg, x, positions)
    k, v = _project_kv(p, cfg, x, positions)
    kv_valid = jnp.ones((b, s), bool) if valid is None else valid
    smax = cache.k.shape[1]
    idx = positions % smax  # (B,S)
    bidx = jnp.arange(b)[:, None]
    write_pos = jnp.where(kv_valid, positions, -1)
    new_cache = KVCache(
        k=cache.k.at[bidx, idx].set(k),
        v=cache.v.at[bidx, idx].set(v),
        pos=cache.pos.at[bidx, idx].set(write_pos),
    )
    out = attend(q, k, v, positions, positions, kv_valid, window=w, softcap=cfg.attn_logit_softcap)
    return out.reshape(b, s, cfg.q_dim) @ p["wo"], new_cache


def decode_attention(p, cfg: ModelConfig, x, pos, cache: KVCache, *, window="cfg"):
    """One-token decode. x: (B,1,D); pos: (B,) int32 current positions."""
    b = x.shape[0]
    w = cfg.sliding_window if window == "cfg" else window
    positions = pos[:, None]
    q = _project_q(p, cfg, x, positions)
    k_new, v_new = _project_kv(p, cfg, x, positions)
    smax = cache.k.shape[1]
    idx = (pos % smax)[:, None]
    bidx = jnp.arange(b)[:, None]
    cache = KVCache(
        k=cache.k.at[bidx, idx].set(k_new),
        v=cache.v.at[bidx, idx].set(v_new),
        pos=cache.pos.at[bidx, idx].set(positions),
    )
    kv_valid = cache.pos >= 0
    out = _attend_direct(q, cache.k, cache.v, positions, cache.pos, kv_valid,
                         window=w, softcap=cfg.attn_logit_softcap)
    return out.reshape(b, 1, cfg.q_dim) @ p["wo"], cache


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_attention(p, cfg: ModelConfig, x, memory, memory_valid=None):
    """x: (B,S,D) decoder states; memory: (B,T,D) encoder output (no rope)."""
    b, s, _ = x.shape
    t = memory.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    q = q.reshape(b, s, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, hd)
    k = (memory @ p["wk"]).reshape(b, t, cfg.num_kv_heads, hd)
    v = (memory @ p["wv"]).reshape(b, t, cfg.num_kv_heads, hd)
    if memory_valid is None:
        memory_valid = jnp.ones((b, t), bool)
    q_pos = jnp.full((b, s), jnp.iinfo(jnp.int32).max, jnp.int32)
    kv_pos = jnp.zeros((b, t), jnp.int32)
    out = attend(q, k, v, q_pos, kv_pos, memory_valid, window=None, softcap=None)
    return out.reshape(b, s, cfg.q_dim) @ p["wo"]
