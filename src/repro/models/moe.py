"""Mixture-of-Experts layer.

Two execution paths:

* ``dense`` — every expert processes every token, gate-combined.  O(E) FLOPs;
  used only for tiny smoke configs and as the numerical oracle.
* ``ep`` (default) — capacity-factor top-k dispatch via one-hot einsums over
  token groups (t5x/switch style), TPU-native: expert weights are sharded
  over the ``model`` mesh axis (expert parallelism) and GSPMD inserts the
  all-to-all-shaped collectives at the dispatch/combine einsums.

Tokens are reshaped into groups of ``_GROUP`` along the sequence so the
dispatch tensors stay O(S) rather than O(S^2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module
from repro.models.config import ModelConfig

_GROUP = 512


def init_moe(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    return {
        "router": module.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.truncated_normal(ks[1], -2, 2, (e, d, f)) * scale).astype(dt),
        "w_up": (jax.random.truncated_normal(ks[2], -2, 2, (e, d, f)) * scale).astype(dt),
        "w_down": (jax.random.truncated_normal(ks[3], -2, 2, (e, f, d)) * (1.0 / jnp.sqrt(f))).astype(dt),
    }


def _router(p, cfg: ModelConfig, x):
    """Returns (gates, indices): top-k normalized gate weights, fp32."""
    logits = x.astype(jnp.float32) @ p["router"]  # (..., E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return logits, probs, gates, idx


def _aux_losses(cfg: ModelConfig, logits, probs, idx):
    # load-balance: E * sum_e f_e * P_e  (Switch Transformer eq. 4-6)
    e = cfg.num_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)     # (..., k, E)
    frac = onehot.sum(-2).reshape(-1, e).mean(0)           # fraction routed per expert
    prob = probs.reshape(-1, e).mean(0)
    lb = e * jnp.sum(frac * prob)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return {"load_balance_loss": lb, "router_z_loss": z}


def _expert_ffn(p, cfg: ModelConfig, h):
    """h: (..., E, C, d) -> (..., E, C, d) through per-expert SwiGLU."""
    gate = jnp.einsum("...ecd,edf->...ecf", h, p["w_gate"])
    up = jnp.einsum("...ecd,edf->...ecf", h, p["w_up"])
    act = jax.nn.silu(gate) * up
    return jnp.einsum("...ecf,efd->...ecd", act, p["w_down"])


def moe_dense(p, cfg: ModelConfig, x):
    """Oracle path: all experts on all tokens. x: (B,S,d)."""
    logits, probs, gates, idx = _router(p, cfg, x)
    gate = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    up = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    act = jax.nn.silu(gate) * up
    out_e = jnp.einsum("bsef,efd->bsed", act, p["w_down"])  # (B,S,E,d)
    k_onehot = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32)  # (B,S,k,E)
    weights = jnp.einsum("bske,bsk->bse", k_onehot, gates)
    out = jnp.einsum("bsed,bse->bsd", out_e.astype(jnp.float32), weights)
    return out.astype(x.dtype), _aux_losses(cfg, logits, probs, idx)


def moe_ep(p, cfg: ModelConfig, x):
    """Capacity-dispatch path. x: (B,S,d)."""
    b, s, d = x.shape
    gs = min(s, _GROUP)
    assert s % gs == 0, f"seq {s} not divisible by moe group {gs}"
    ng = s // gs
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = max(1, int(gs * k / e * cfg.capacity_factor))

    xg = x.reshape(b, ng, gs, d)
    logits, probs, gates, idx = _router(p, cfg, xg)  # idx: (b,ng,gs,k)

    # position of each (token, k) assignment inside its expert's buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)        # (b,ng,gs,k,E)
    flat = onehot.reshape(b, ng, gs * k, e)
    pos = jnp.cumsum(flat, axis=2) - 1.0                       # (b,ng,gs*k,E)
    pos = pos.reshape(b, ng, gs, k, e)
    keep = ((pos < cap) & (onehot > 0)).astype(jnp.float32)

    # dispatch/combine WITHOUT materializing the (.., k, E, C) one-hot
    # (686 GB global for qwen3-moe train — §Perf iter 6): unroll the small
    # top-k axis, keeping only (.., E, C)-sized live tensors.
    disp = jnp.zeros((b, ng, gs, e, cap), jnp.float32)
    comb = jnp.zeros((b, ng, gs, e, cap), jnp.float32)
    for j in range(k):
        oj = onehot[..., j, :] * keep[..., j, :]               # (b,ng,gs,E)
        cap_oh_j = jax.nn.one_hot(pos[..., j, :].astype(jnp.int32), cap,
                                  dtype=jnp.float32)           # (b,ng,gs,E,C)
        dj = oj[..., None] * cap_oh_j
        disp = disp + dj
        comb = comb + dj * gates[..., j, None, None]

    h = jnp.einsum("bgsec,bgsd->bgecd", disp.astype(x.dtype), xg)           # (b,ng,E,C,d)
    out_e = _expert_ffn(p, cfg, h)
    out = jnp.einsum("bgecd,bgsec->bgsd", out_e.astype(jnp.float32), comb)
    return out.reshape(b, s, d).astype(x.dtype), _aux_losses(cfg, logits, probs, idx)


def moe_apply(p, cfg: ModelConfig, x, *, mode: str = "ep"):
    if mode == "dense":
        return moe_dense(p, cfg, x)
    return moe_ep(p, cfg, x)
