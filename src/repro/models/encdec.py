"""Encoder–decoder transformer backbone (seamless-m4t-medium).

The audio frontend (mel + conv feature extractor) is STUBBED per the task
carve-out: the encoder consumes precomputed frame embeddings
``(B, T_frames, d_model)`` from ``input_specs``.  The decoder is a standard
causal transformer with cross-attention; cross K/V are computed once at
encode time and carried in the cache for decode.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention, ffn, module
from repro.models.sharding import constrain_activation
from repro.models.config import ModelConfig


def _init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "ln2": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attention.init_attention(ks[0], cfg),
        "mlp": ffn.init_mlp(ks[1], cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "ln2": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "ln3": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attention.init_attention(ks[0], cfg),
        "cross": attention.init_attention(ks[1], cfg, cross=True),
        "mlp": ffn.init_mlp(ks[2], cfg),
    }


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": module.embed_init(ks[2], cfg.vocab_size, cfg.d_model, jnp.dtype(cfg.dtype)),
        "lm_head": module.dense_init(ks[3], cfg.d_model, cfg.vocab_size, jnp.dtype(cfg.dtype)),
        "enc_norm": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "final_norm": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
    }


def encode(params, cfg: ModelConfig, frames, *, remat: bool = False):
    """frames: (B, T, D) stubbed frontend output -> memory (B, T, D)."""
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(h, lp):
        h = constrain_activation(h)
        y = attention.self_attention(lp["attn"], cfg, module.rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                     positions, causal=False, window=None)
        h = h + y
        h = h + ffn.mlp(lp["mlp"], cfg, module.rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, None

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, _ = jax.lax.scan(fn, frames.astype(jnp.dtype(cfg.dtype)), params["encoder"])
    return module.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(params, cfg: ModelConfig, memory):
    """Precompute stacked cross K/V: (L, B, T, KV, hd) each."""
    hd = cfg.resolved_head_dim
    b, t, _ = memory.shape

    def body(_, lp):
        k = (memory @ lp["cross"]["wk"]).reshape(b, t, cfg.num_kv_heads, hd)
        v = (memory @ lp["cross"]["wv"]).reshape(b, t, cfg.num_kv_heads, hd)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"])
    return ks, vs


def _cross_attend(lp, cfg: ModelConfig, x, ck, cv):
    b, s, _ = x.shape
    t = ck.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ lp["cross"]["wq"]).reshape(b, s, cfg.num_kv_heads,
                                        cfg.num_heads // cfg.num_kv_heads, hd)
    valid = jnp.ones((b, t), bool)
    q_pos = jnp.full((b, s), jnp.iinfo(jnp.int32).max, jnp.int32)
    kv_pos = jnp.zeros((b, t), jnp.int32)
    out = attention.attend(q, ck, cv, q_pos, kv_pos, valid, window=None, softcap=None)
    return out.reshape(b, s, cfg.q_dim) @ lp["cross"]["wo"]


def init_dec_cache(cfg: ModelConfig, batch: int, max_len: int, enc_frames: int):
    one = attention.init_kv_cache(cfg, batch, max_len)
    self_cache = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "self": self_cache,
        "cross_k": jnp.zeros((cfg.num_layers, batch, enc_frames, cfg.num_kv_heads, hd), dt),
        "cross_v": jnp.zeros((cfg.num_layers, batch, enc_frames, cfg.num_kv_heads, hd), dt),
    }


def _dec_layer(lp, cfg, x, positions, ck, cv, *, cache=None, pos=None, mode="full"):
    h = module.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if mode == "full":
        y = attention.self_attention(lp["attn"], cfg, h, positions, window=None)
        new_cache = None
    elif mode == "prefill":
        y, new_cache = attention.prefill_attention(lp["attn"], cfg, h, positions, cache, window=None)
    else:  # decode
        y, new_cache = attention.decode_attention(lp["attn"], cfg, h, pos, cache, window=None)
    x = x + y
    x = x + _cross_attend(lp, cfg, module.rmsnorm(lp["ln2"], x, cfg.norm_eps), ck, cv)
    x = x + ffn.mlp(lp["mlp"], cfg, module.rmsnorm(lp["ln3"], x, cfg.norm_eps))
    return x, new_cache


def encdec_apply(params, cfg: ModelConfig, frames, tokens, *, remat: bool = False,
                 return_features: bool = False):
    """Teacher-forcing forward. Returns (logits fp32, aux); with
    ``return_features`` the final-norm hidden states instead (see
    transformer.lm_apply)."""
    memory = encode(params, cfg, frames, remat=remat)
    ck_all, cv_all = _cross_kv(params, cfg, memory)
    x = params["embed"][tokens]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, inp):
        lp, ck, cv = inp
        h2, _ = _dec_layer(lp, cfg, constrain_activation(h), positions, ck, cv, mode="full")
        return h2, None

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, _ = jax.lax.scan(fn, x, (params["decoder"], ck_all, cv_all))
    x = module.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
           "router_z_loss": jnp.zeros((), jnp.float32)}
    if return_features:
        return x, aux
    return (x @ params["lm_head"]).astype(jnp.float32), aux


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, cache):
    """Encode + prefill decoder self-cache. Returns (logits, cache)."""
    memory = encode(params, cfg, frames)
    ck_all, cv_all = _cross_kv(params, cfg, memory)
    cache = dict(cache, cross_k=ck_all, cross_v=cv_all)
    x = params["embed"][tokens]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, inp):
        lp, ck, cv, c = inp
        h2, c2 = _dec_layer(lp, cfg, h, positions, ck, cv, cache=c, mode="prefill")
        return h2, c2

    x, self_cache = jax.lax.scan(body, x, (params["decoder"], ck_all, cv_all, cache["self"]))
    cache["self"] = self_cache
    # last-position logits only (see transformer._last_position_logits)
    x_last = module.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    return (x_last[:, 0] @ params["lm_head"]).astype(jnp.float32), cache


def encdec_decode_step(params, cfg: ModelConfig, token, pos, cache):
    """One decoder token; cross K/V already in cache."""
    x = params["embed"][token][:, None, :]

    def body(h, inp):
        lp, ck, cv, c = inp
        h2, c2 = _dec_layer(lp, cfg, h, None, ck, cv, cache=c, pos=pos, mode="decode")
        return h2, c2

    x, self_cache = jax.lax.scan(
        body, x, (params["decoder"], cache["cross_k"], cache["cross_v"], cache["self"]))
    cache = dict(cache, self=self_cache)
    x = module.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return (x @ params["lm_head"]).astype(jnp.float32)[:, 0, :], cache
