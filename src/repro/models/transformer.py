"""Decoder-only LM assembly for all families (dense / moe / ssm / hybrid / vlm).

Layers are *stacked* (leading layer axis) and executed with
``jax.lax.scan`` so that 94-layer configs lower to a single while-loop body
— essential for compile time on the 512-device dry-run.  Hybrid archs
(RecurrentGemma) scan over pattern *groups* plus an unrolled tail.

Entry points:
    init_lm / init_cache
    lm_apply(params, cfg, tokens, ...)          -> (logits, aux)      # train
    lm_prefill(params, cfg, tokens, cache, ...) -> (logits, cache)    # prefill
    lm_decode_step(params, cfg, token, pos, cache) -> (logits, cache) # decode
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, ffn, moe, module, rglru, rwkv6
from repro.models.sharding import constrain_activation
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ModelConfig, *, use_moe: bool):
    ks = jax.random.split(key, 2)
    p = {
        "ln1": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "ln2": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attention.init_attention(ks[0], cfg),
    }
    if use_moe:
        p["moe"] = moe.init_moe(ks[1], cfg)
    else:
        p["mlp"] = ffn.init_mlp(ks[1], cfg)
    return p


def _init_rglru_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "ln2": module.rmsnorm_init(cfg.d_model, cfg.dtype),
        "rec": rglru.init_recurrent_block(ks[0], cfg),
        "mlp": ffn.init_mlp(ks[1], cfg),
    }


def _layer_init_fn(cfg: ModelConfig, kind: str):
    if kind == "attn":
        return functools.partial(_init_attn_block, cfg=cfg, use_moe=cfg.is_moe)
    if kind == "rwkv":
        return functools.partial(rwkv6.init_block, cfg=cfg)
    if kind == "rglru":
        return functools.partial(_init_rglru_block, cfg=cfg)
    raise ValueError(kind)


def _stacked_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _hybrid_layout(cfg: ModelConfig):
    pattern = cfg.block_pattern
    n_groups = cfg.num_layers // len(pattern)
    tail = tuple(pattern[: cfg.num_layers - n_groups * len(pattern)])
    return pattern, n_groups, tail


def init_lm(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": module.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": module.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = module.dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stacked_init(ks[2], cfg.num_layers, _layer_init_fn(cfg, "attn"))
    elif cfg.family == "ssm":
        params["blocks"] = _stacked_init(ks[2], cfg.num_layers, _layer_init_fn(cfg, "rwkv"))
    elif cfg.family == "hybrid":
        pattern, n_groups, tail = _hybrid_layout(cfg)
        gk = jax.random.split(ks[2], len(pattern))
        params["blocks"] = {
            f"{i}_{kind}": _stacked_init(gk[i], n_groups, _layer_init_fn(cfg, kind))
            for i, kind in enumerate(pattern)
        }
        tk = jax.random.split(ks[3], max(1, len(tail)))
        params["tail"] = [
            _layer_init_fn(cfg, kind)(tk[i]) for i, kind in enumerate(tail)
        ]
    else:
        raise ValueError(f"init_lm does not handle family {cfg.family}")
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _stack_cache(make_one, n: int):
    one = make_one()
    return jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm"):
        return _stack_cache(lambda: attention.init_kv_cache(cfg, batch, max_len), cfg.num_layers)
    if cfg.family == "ssm":
        return _stack_cache(lambda: rwkv6.init_rwkv_state(cfg, batch), cfg.num_layers)
    if cfg.family == "hybrid":
        pattern, n_groups, tail = _hybrid_layout(cfg)

        def one(kind):
            if kind == "attn":
                return lambda: attention.init_kv_cache(cfg, batch, max_len)
            return lambda: rglru.init_rglru_state(cfg, batch)

        cache = {
            f"{i}_{kind}": _stack_cache(one(kind), n_groups) for i, kind in enumerate(pattern)
        }
        cache["tail"] = [one(kind)() for kind in tail]
        return cache
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# per-layer apply (three modes: full, prefill, decode)
# ---------------------------------------------------------------------------

_ZERO_AUX = {"load_balance_loss": jnp.zeros((), jnp.float32),
             "router_z_loss": jnp.zeros((), jnp.float32)}


def _attn_block_apply(p, cfg: ModelConfig, x, positions, *, moe_mode: str):
    x = constrain_activation(x)
    y = attention.self_attention(p["attn"], cfg, module.rmsnorm(p["ln1"], x, cfg.norm_eps), positions)
    x = x + y
    h = module.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe.moe_apply(p["moe"], cfg, h, mode=moe_mode)
    else:
        y, aux = ffn.mlp(p["mlp"], cfg, h), _ZERO_AUX
    return x + y, aux


def _attn_block_prefill(p, cfg: ModelConfig, x, positions, cache, *, moe_mode: str,
                        valid=None):
    y, cache = attention.prefill_attention(p["attn"], cfg, module.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                           positions, cache, valid=valid)
    x = x + y
    h = module.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe.moe_apply(p["moe"], cfg, h, mode=moe_mode)
    else:
        y = ffn.mlp(p["mlp"], cfg, h)
    return x + y, cache


def _attn_block_decode(p, cfg: ModelConfig, x, pos, cache, *, moe_mode: str):
    y, cache = attention.decode_attention(p["attn"], cfg, module.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                          pos, cache)
    x = x + y
    h = module.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe.moe_apply(p["moe"], cfg, h, mode=moe_mode)
    else:
        y = ffn.mlp(p["mlp"], cfg, h)
    return x + y, cache


def _rglru_block_apply(p, cfg: ModelConfig, x, state, *, decode: bool):
    if not decode:
        x = constrain_activation(x)
    fn = rglru.recurrent_step if decode else rglru.recurrent_block
    y, state = fn(p["rec"], cfg, module.rmsnorm(p["ln1"], x, cfg.norm_eps), state)
    x = x + y
    x = x + ffn.mlp(p["mlp"], cfg, module.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, state


# ---------------------------------------------------------------------------
# trunk apply
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens, prefix_embeds):
    x = params["embed"][tokens]
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma embed scale
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def _unembed(params, cfg: ModelConfig, x):
    x = module.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32)


def _default_positions(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))


def lm_apply(params, cfg: ModelConfig, tokens, *, positions=None,
             prefix_embeds=None, remat: bool = False, moe_mode: str = "ep",
             return_features: bool = False):
    """Full-sequence causal forward.

    Returns (logits fp32, aux dict) — or, with ``return_features``, the
    final-norm hidden states (B, S, D) instead of logits, so the caller can
    fuse the unembedding with the loss (chunked cross-entropy: materializing
    (B, S, V) fp32 at 4k x 256k vocab costs ~1 TiB global — §Perf iter 3)."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = _default_positions(b, s)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, lp):
            h2, aux = _attn_block_apply(lp, cfg, h, positions, moe_mode=moe_mode)
            return h2, aux
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        aux = jax.tree_util.tree_map(jnp.mean, auxs)
    elif cfg.family == "ssm":
        state0 = init_cache(cfg, b, s)

        def body(h, inp):
            lp, st = inp
            h2, _ = rwkv6.block(lp, cfg, constrain_activation(h), st)
            return h2, None
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, (params["blocks"], state0))
        aux = dict(_ZERO_AUX)
    elif cfg.family == "hybrid":
        pattern, n_groups, tail = _hybrid_layout(cfg)
        states = init_cache(cfg, b, s)

        def group_body(h, inp):
            for i, kind in enumerate(pattern):
                lp = inp[f"{i}_{kind}"]
                if kind == "attn":
                    h, _ = _attn_block_apply(lp, cfg, h, positions, moe_mode=moe_mode)
                else:
                    h, _ = _rglru_block_apply(lp, cfg, h, inp[f"state_{i}"], decode=False)
            return h, None

        xs = {f"{i}_{kind}": params["blocks"][f"{i}_{kind}"] for i, kind in enumerate(pattern)}
        xs.update({f"state_{i}": states[f"{i}_{kind}"]
                   for i, kind in enumerate(pattern) if kind != "attn"})
        gb = jax.checkpoint(group_body, prevent_cse=False) if remat else group_body
        x, _ = jax.lax.scan(gb, x, xs)
        for tp, st, kind in zip(params["tail"], states["tail"], tail,
                                strict=True):
            if kind == "attn":
                x, _ = _attn_block_apply(tp, cfg, x, positions, moe_mode=moe_mode)
            else:
                x, _ = _rglru_block_apply(tp, cfg, x, st, decode=False)
        aux = dict(_ZERO_AUX)
    else:
        raise ValueError(cfg.family)

    if return_features:
        return module.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux
    return _unembed(params, cfg, x), aux


def unembedding_matrix(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _last_position_logits(params, cfg: ModelConfig, x, valid):
    """Unembed ONLY each row's last real position -> (B, V) fp32.

    Serving prefill needs just the next-token distribution; materializing
    (B, S, V) fp32 logits at 32k x 256k vocab is ~1 TiB and was the dominant
    memory+collective term of every prefill combo (EXPERIMENTS.md §Perf
    iter 1)."""
    b, s, _ = x.shape
    if valid is None:
        last = jnp.full((b,), s - 1, jnp.int32)
    else:
        last = jnp.maximum(valid.sum(axis=1).astype(jnp.int32) - 1, 0)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    x_last = module.rmsnorm(params["final_norm"], x_last[:, None, :], cfg.norm_eps)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x_last @ head).astype(jnp.float32)


def lm_prefill(params, cfg: ModelConfig, tokens, cache, *, positions=None,
               prefix_embeds=None, moe_mode: str = "ep", valid=None):
    """Causal forward that fills the cache.

    Returns (last-position logits (B, V) fp32, cache).

    `valid` (B, S_tokens) marks real (non-pad) token positions; only
    meaningful for attention families (recurrent state ingests every
    position, so recurrent archs must prefill exact-length prompts)."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = _default_positions(b, s)
    if valid is not None and cfg.family == "vlm" and valid.shape[1] != s:
        valid = jnp.concatenate(
            [jnp.ones((b, s - valid.shape[1]), bool), valid], axis=1)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, inp):
            lp, c = inp
            h2, c2 = _attn_block_prefill(lp, cfg, h, positions, c, moe_mode=moe_mode,
                                         valid=valid)
            return h2, c2
        x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    elif cfg.family == "ssm":
        def body(h, inp):
            lp, st = inp
            h2, st2 = rwkv6.block(lp, cfg, h, st)
            return h2, st2
        x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    elif cfg.family == "hybrid":
        pattern, n_groups, tail = _hybrid_layout(cfg)

        def group_body(h, inp):
            outs = {}
            for i, kind in enumerate(pattern):
                lp = inp[f"{i}_{kind}"]
                if kind == "attn":
                    h, c2 = _attn_block_prefill(lp, cfg, h, positions, inp[f"cache_{i}"],
                                                moe_mode=moe_mode)
                else:
                    h, c2 = _rglru_block_apply(lp, cfg, h, inp[f"cache_{i}"], decode=False)
                outs[f"cache_{i}"] = c2
            return h, outs

        xs = {f"{i}_{kind}": params["blocks"][f"{i}_{kind}"] for i, kind in enumerate(pattern)}
        xs.update({f"cache_{i}": cache[f"{i}_{kind}"] for i, kind in enumerate(pattern)})
        x, new_stacked = jax.lax.scan(group_body, x, xs)
        new_cache = {f"{i}_{kind}": new_stacked[f"cache_{i}"] for i, kind in enumerate(pattern)}
        new_tail = []
        for tp, st, kind in zip(params["tail"], cache["tail"], tail,
                                strict=True):
            if kind == "attn":
                x, st2 = _attn_block_prefill(tp, cfg, x, positions, st, moe_mode=moe_mode)
            else:
                x, st2 = _rglru_block_apply(tp, cfg, x, st, decode=False)
            new_tail.append(st2)
        new_cache["tail"] = new_tail
        cache = new_cache
    else:
        raise ValueError(cfg.family)

    vlm_valid = valid
    if cfg.family == "vlm" and valid is not None and valid.shape[1] != x.shape[1]:
        b = x.shape[0]
        vlm_valid = jnp.concatenate(
            [jnp.ones((b, x.shape[1] - valid.shape[1]), bool), valid], axis=1)
    return _last_position_logits(params, cfg, x, vlm_valid), cache


def lm_decode_step(params, cfg: ModelConfig, token, pos, cache, *,
                   prefix_embeds=None, moe_mode: str = "ep"):
    """One-token decode. token: (B,) int32; pos: (B,) int32.

    Returns (logits (B, V) fp32, new cache).
    """
    x = params["embed"][token][:, None, :]  # (B,1,D)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, inp):
            lp, c = inp
            h2, c2 = _attn_block_decode(lp, cfg, h, pos, c, moe_mode=moe_mode)
            return h2, c2
        x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    elif cfg.family == "ssm":
        def body(h, inp):
            lp, st = inp
            h2, st2 = rwkv6.block(lp, cfg, h, st)
            return h2, st2
        x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    elif cfg.family == "hybrid":
        pattern, n_groups, tail = _hybrid_layout(cfg)

        def group_body(h, inp):
            outs = {}
            for i, kind in enumerate(pattern):
                lp = inp[f"{i}_{kind}"]
                if kind == "attn":
                    h, c2 = _attn_block_decode(lp, cfg, h, pos, inp[f"cache_{i}"],
                                               moe_mode=moe_mode)
                else:
                    h, c2 = _rglru_block_apply(lp, cfg, h, inp[f"cache_{i}"], decode=True)
                outs[f"cache_{i}"] = c2
            return h, outs

        xs = {f"{i}_{kind}": params["blocks"][f"{i}_{kind}"] for i, kind in enumerate(pattern)}
        xs.update({f"cache_{i}": cache[f"{i}_{kind}"] for i, kind in enumerate(pattern)})
        x, new_stacked = jax.lax.scan(group_body, x, xs)
        new_cache = {f"{i}_{kind}": new_stacked[f"cache_{i}"] for i, kind in enumerate(pattern)}
        new_tail = []
        for tp, st, kind in zip(params["tail"], cache["tail"], tail,
                                strict=True):
            if kind == "attn":
                x, st2 = _attn_block_decode(tp, cfg, x, pos, st, moe_mode=moe_mode)
            else:
                x, st2 = _rglru_block_apply(tp, cfg, x, st, decode=True)
            new_tail.append(st2)
        new_cache["tail"] = new_tail
        cache = new_cache
    else:
        raise ValueError(cfg.family)

    return _unembed(params, cfg, x)[:, 0, :], cache
