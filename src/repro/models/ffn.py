"""Gated MLPs (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module
from repro.models.config import ModelConfig


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    dt = jnp.dtype(cfg.dtype)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": module.dense_init(ks[0], cfg.d_model, d_ff, dt),
        "wi_up": module.dense_init(ks[1], cfg.d_model, d_ff, dt),
        "wo": module.dense_init(ks[2], d_ff, cfg.d_model, dt),
    }


def mlp(p, cfg: ModelConfig, x):
    gate = x @ p["wi_gate"]
    up = x @ p["wi_up"]
    if cfg.mlp_activation == "geglu":
        act = jax.nn.gelu(gate, approximate=True)
    else:
        act = jax.nn.silu(gate)
    return (act * up) @ p["wo"]
