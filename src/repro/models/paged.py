"""Paged KV cache: block tables over a shared page pool (TPU-native vLLM).

The slot engine's cache is ``(num_slots, S_max, ...)`` — every slot owns a
full-length row, admission prefills the whole prompt in one variable-length
call, and an aborted request's KV is gone.  Here the KV lives in a shared
page pool per layer::

    k_pages / v_pages : (num_layers, num_pages, page_size, n_kv, head_dim)

and each request owns an int32 *block table* row ``(pages_per_seq,)`` of
physical page indices (−1 = unassigned).  Page 0 is reserved as a garbage
page: writes from masked-out lanes are redirected there so every engine
step keeps static shapes.

Two jit-able forwards, both with fixed shapes so one compiled executable
serves every prompt length / fill level:

* ``paged_prefill_chunk`` — one fixed-size chunk of prompt tokens for ONE
  request (batch=1), attending to the request's previously written pages
  plus in-chunk causality.  Chunked prefill means admitting a long prompt
  costs one chunk per engine step instead of stalling the whole batch.
* ``paged_decode_step`` — one token for EVERY slot, gathering K/V through
  the block tables (pure-JAX gather here; the Pallas kernel in
  ``repro.kernels.paged_decode_attention`` is the accelerator path).

Supported families: dense / moe (decoder-only attention).  Recurrent and
hybrid families keep per-request state, not positional KV — paging does
not apply to them.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, ffn, module, moe
from repro.models.config import ModelConfig


class PagedKVCache(NamedTuple):
    k_pages: jax.Array  # (num_layers, num_pages, page_size, n_kv, head_dim)
    v_pages: jax.Array
    # int8 KV quantization (kv_quant="int8"): pages hold int8 codes and the
    # per-(page, slot, kv-head) fp32 scales live beside the pool —
    # (num_layers, num_pages, page_size, n_kv).  None = full precision.
    # Scales are indexed by PHYSICAL page exactly like the pages, so every
    # pool mechanism (COW fork, radix prefix cache, abort→resume retention)
    # carries them for free: aliasing a page through a block table aliases
    # its scales.
    k_scales: Optional[jax.Array] = None
    v_scales: Optional[jax.Array] = None

    @property
    def layer_pages(self):
        """Per-layer scan operands: (k, v) or (k, v, k_scales, v_scales)."""
        if self.k_scales is None:
            return (self.k_pages, self.v_pages)
        return (self.k_pages, self.v_pages, self.k_scales, self.v_scales)


def _cache_from_layers(pages) -> PagedKVCache:
    """Rebuild a cache from scanned per-layer operands (2- or 4-tuple)."""
    if len(pages) == 2:
        return PagedKVCache(k_pages=pages[0], v_pages=pages[1])
    return PagedKVCache(k_pages=pages[0], v_pages=pages[1],
                        k_scales=pages[2], v_scales=pages[3])


GARBAGE_PAGE = 0  # physical page 0 is never allocated to a request

_KV_SCALE_EPS = 1e-12  # zero-row guard for per-token absmax scales


def quantize_kv(x):
    """Symmetric int8 per-(token, kv-head) quantization of a K/V tensor.

    x: (..., n_kv, head_dim) -> (int8 codes same shape, fp32 scales
    (..., n_kv)).  One scale per token per KV head — fine enough that
    greedy decode survives (the head_dim absmax sets the grid), and small
    enough (4 bytes per 32+ stored) that int8 pages still roughly halve
    bf16 page memory."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, _KV_SCALE_EPS) / 127.0
    codes = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return codes.astype(jnp.int8), scale


class PagePool:
    """Reference-counted host-side allocator over the physical page pool.

    Copy-on-write prefix sharing for GRPO prompt groups: the G candidates of
    one prompt alias the prompt's fully-filled pages (refcount G) and own
    only their partial tail page + decode region privately.  A page returns
    to the free list when its last reference is released, so any mix of
    finish / abort / retain / resume orderings across the group composes —
    the refcount IS the ownership protocol.

    Page 0 stays the reserved garbage target (never allocated, refcount
    pinned to 0): masked-out engine lanes keep writing there.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("pool needs >= 2 pages (page 0 is garbage)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._ref = np.zeros((num_pages,), np.int32)
        self._free: List[int] = list(range(1, num_pages))
        self.peak_pages_in_use = 0

    # ------------------------------------------------------------- counters
    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    @property
    def pages_shared(self) -> int:
        """Pages aliased by >= 2 holders (COW prompt prefixes)."""
        return int((self._ref >= 2).sum())

    @property
    def pages_private(self) -> int:
        """Pages exclusively owned by one lane / retained record."""
        return int((self._ref == 1).sum())

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    # ----------------------------------------------------------- operations
    def alloc(self, n: int) -> List[int]:
        assert n <= len(self._free), "page pool exhausted"
        pages, self._free = self._free[:n], self._free[n:]
        for p in pages:
            self._ref[p] = 1
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        return pages

    def share(self, pages: List[int]) -> None:
        """Add one reference to each page (must already be allocated)."""
        for p in pages:
            assert self._ref[p] > 0, f"share of unallocated page {p}"
            self._ref[p] += 1

    def release(self, pages: List[int]) -> None:
        """Drop one reference per page; last reference frees the page."""
        for p in pages:
            assert self._ref[p] > 0, f"double release of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)

    def fork_prefix(self, block_pages: List[int],
                    upto_token: int) -> Tuple[List[int], Optional[int]]:
        """COW fork of a lane's prefix covering positions [0, upto_token).

        Fully-filled pages are shared in place (one new reference each); the
        partial tail page — the only page the forked lane will keep writing —
        cannot be aliased.  Returns ``(shared_pages, tail_src)`` where
        ``tail_src`` is the physical page the caller must copy into a freshly
        owned page (None when upto_token lands exactly on a page boundary).
        """
        full = upto_token // self.page_size
        shared = list(block_pages[:full])
        self.share(shared)
        tail_src = (int(block_pages[full]) if upto_token % self.page_size
                    else None)
        return shared, tail_src


class _RadixNode:
    """One fully-filled page of cached KV.  The node's *path* from the root
    spells the token prefix the page's KV was computed under — KV at position
    i depends on the whole token prefix [0, i], so content-addressing must
    key on the path, which a radix tree gives for free."""

    __slots__ = ("key", "page", "children", "parent", "last_used")

    def __init__(self, key, page: int, parent, last_used: int):
        self.key = key                       # tuple of page_size token ids
        self.page = page                     # physical page holding the KV
        self.children: Dict[tuple, "_RadixNode"] = {}
        self.parent = parent
        self.last_used = last_used


class RadixCache:
    """Automatic cross-prompt prefix cache over the refcounted ``PagePool``.

    vLLM-style automatic prefix caching at page granularity: finished (or
    aborted) requests insert their fully-filled pages into a radix tree
    keyed on token content; a new request walks the tree to find the longest
    cached page-aligned prefix and aliases those pages into its block table
    (COW through the pool refcounts) instead of re-prefilling them.  The
    cache holds exactly ONE reference per tree node — live requests stack
    their own references on top, so any mix of finish/abort/retain/resume
    composes, and a cached page is evictable precisely when its refcount
    is 1 (only the cache holds it).

    LRU eviction walks leaves first, cascading upward as children disappear.
    A node is *freeable* iff only the cache holds its page (refcount 1) AND
    its whole subtree is freeable — a refcount-1 interior node pinned by a
    live descendant (possible via mid-prefill extension, which shares only
    the continuation pages, not the path above them) can never become a
    leaf, so it must not be promised to admission control.
    ``evictable_pages`` counts exactly the set ``evict()`` can reach.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self.root = _RadixNode(key=None, page=-1, parent=None, last_used=0)
        # Optional observer of tree mutations (duck-typed: ``on_insert(path)``
        # per new node, ``on_evict(path)`` per dropped node, ``on_clear()``
        # on flush; ``path`` = tuple of page keys root→node).  The fleet
        # router hangs its global prefix index here.  Callbacks fire on the
        # replica's own loop thread with no cache-side lock held — the
        # listener does its own synchronization.
        self.listener = None
        self._clock = 0
        self.lookups = 0          # admission-time matches
        self.hits = 0             # admission-time matches that returned pages
        self.ext_hits = 0         # mid-prefill extensions that returned pages
        self.hit_tokens = 0       # tokens skipped (admission + extension)
        self.inserted_pages = 0
        self.evicted_pages = 0
        self.flushes = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _page_key(self, tokens, i: int) -> tuple:
        ps = self.page_size
        return tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])

    # ------------------------------------------------------------- queries
    def _walk(self, tokens) -> List[_RadixNode]:
        """Longest cached path covering full pages of ``tokens`` (no side
        effects beyond nothing; callers bump LRU stamps)."""
        node, path = self.root, []
        for i in range(len(tokens) // self.page_size):
            child = node.children.get(self._page_key(tokens, i))
            if child is None:
                break
            path.append(child)
            node = child
        return path

    def peek(self, tokens) -> int:
        """Number of cached full pages matching ``tokens`` (no refcounts)."""
        return len(self._walk(tokens))

    def match(self, tokens, from_page: int = 0, *,
              extend: bool = False) -> List[int]:
        """Pages ``[from_page, k)`` of the longest cached page-aligned
        prefix of ``tokens`` (k = matched full pages).

        Shares each returned page (the caller owns one new reference per
        page — releasing them composes through the pool) and bumps the whole
        matched path's LRU stamps.  ``from_page`` supports mid-prefill
        extension: a request that already wrote pages [0, from_page) asks
        only for the cached continuation.  Extension probes run once per
        prefill chunk and mostly return nothing — with ``extend=True`` they
        skip the lookup/hit counters (``ext_hits`` records the productive
        ones) so hit-rate stats keep meaning one-admission-one-lookup."""
        if not extend:
            self.lookups += 1
        path = self._walk(tokens)
        stamp = self._tick()
        for n in path:
            n.last_used = stamp
        pages = [n.page for n in path[from_page:]]
        if pages:
            if extend:
                self.ext_hits += 1
            else:
                self.hits += 1
            self.hit_tokens += len(pages) * self.page_size
            self.pool.share(pages)
        return pages

    # ----------------------------------------------------------- mutation
    def insert(self, tokens, pages: List[int]) -> int:
        """Insert ``pages[i]`` (KV of ``tokens[i*ps:(i+1)*ps]`` computed
        under the preceding prefix) for every fully-filled page.

        The cache takes its OWN reference on each newly inserted page (the
        caller keeps and later releases its reference as usual).  Pages whose
        content is already cached are skipped — the caller's duplicate copy
        is freed whenever the caller releases it.  Returns #new nodes."""
        node = self.root
        stamp = self._tick()
        new = 0
        path: List[tuple] = []
        for i, page in enumerate(pages):
            key = self._page_key(tokens, i)
            path.append(key)
            child = node.children.get(key)
            if child is None:
                child = _RadixNode(key=key, page=int(page), parent=node,
                                   last_used=stamp)
                node.children[key] = child
                self.pool.share([int(page)])
                self.inserted_pages += 1
                new += 1
                if self.listener is not None:
                    self.listener.on_insert(tuple(path))
            else:
                child.last_used = stamp
            node = child
        return new

    def evict(self, want_pages: int) -> int:
        """Free up to ``want_pages`` pages by dropping LRU leaves whose page
        only the cache still holds, cascading upward as parents become
        childless.  One tree walk + a heap — not one walk per page freed.
        Returns the number actually freed."""
        heap: List[Tuple[int, int, _RadixNode]] = []
        tie = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                if c.children:
                    stack.append(c)
                elif self.pool.refcount(c.page) == 1:
                    heap.append((c.last_used, tie, c))
                    tie += 1
        heapq.heapify(heap)
        freed = 0
        while freed < want_pages and heap:
            _, _, leaf = heapq.heappop(heap)
            parent = leaf.parent
            if self.listener is not None:
                self.listener.on_evict(self._node_path(leaf))
            del parent.children[leaf.key]
            self.pool.release([leaf.page])
            self.evicted_pages += 1
            freed += 1
            if (parent is not self.root and not parent.children
                    and self.pool.refcount(parent.page) == 1):
                heapq.heappush(heap, (parent.last_used, tie, parent))
                tie += 1
        return freed

    def clear(self) -> None:
        """Drop every cache hold (e.g. on a weight update: all cached KV was
        computed under the old policy).  Pages still aliased by running
        requests stay allocated until their holders release them."""
        stack = [self.root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                stack.append(c)
                self.pool.release([c.page])
        self.root.children = {}
        self.flushes += 1
        if self.listener is not None:
            self.listener.on_clear()

    # ---------------------------------------------------------- enumeration
    @staticmethod
    def _node_path(node: _RadixNode) -> tuple:
        """Tuple of page keys root→``node`` (the node's content address)."""
        keys = []
        while node is not None and node.parent is not None:
            keys.append(node.key)
            node = node.parent
        return tuple(reversed(keys))

    def paths(self) -> List[tuple]:
        """Every node's root path — the cache's full content listing, used
        by ``fleet_audit`` to cross-check the router's global index."""
        out: List[tuple] = []
        stack: List[Tuple[_RadixNode, tuple]] = [(self.root, ())]
        while stack:
            n, prefix = stack.pop()
            for c in n.children.values():
                p = prefix + (c.key,)
                out.append(p)
                stack.append((c, p))
        return out

    # ------------------------------------------------------------ counters
    @property
    def num_nodes(self) -> int:
        count, stack = 0, [self.root]
        while stack:
            n = stack.pop()
            count += len(n.children)
            stack.extend(n.children.values())
        return count

    @property
    def evictable_pages(self) -> int:
        """Pages freeable by (cascading) leaf-first eviction: nodes whose
        page only the cache holds AND whose entire subtree is likewise
        cache-only (a pinned descendant keeps an ancestor from ever
        becoming a leaf).  Exactly what ``evict()`` can deliver — admission
        control must not be promised more, or ``pool.alloc`` would assert
        instead of queueing the request."""
        count = 0

        def freeable(n: _RadixNode) -> bool:
            nonlocal count
            ok = all([freeable(c) for c in n.children.values()])
            if n is self.root:
                return ok
            ok = ok and self.pool.refcount(n.page) == 1
            if ok:
                count += 1
            return ok

        freeable(self.root)
        return count

    def held_pages(self) -> List[int]:
        """Every physical page the cache holds a reference on (audit)."""
        pages, stack = [], [self.root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                stack.append(c)
                pages.append(c.page)
        return pages


def supports_paged(cfg: ModelConfig) -> bool:
    return cfg.family in ("dense", "moe")


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     kv_quant: str = "off") -> PagedKVCache:
    if not supports_paged(cfg):
        raise ValueError(f"paged KV cache requires an attention family, got {cfg.family}")
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads, hd)
    if kv_quant == "off":
        dt = jnp.dtype(cfg.dtype)
        return PagedKVCache(k_pages=jnp.zeros(shape, dt),
                            v_pages=jnp.zeros(shape, dt))
    if kv_quant != "int8":
        raise ValueError(f"unknown kv_quant {kv_quant!r} (expected off | int8)")
    sshape = shape[:-1]
    return PagedKVCache(k_pages=jnp.zeros(shape, jnp.int8),
                        v_pages=jnp.zeros(shape, jnp.int8),
                        k_scales=jnp.zeros(sshape, jnp.float32),
                        v_scales=jnp.zeros(sshape, jnp.float32))


def pages_per_seq(max_total_len: int, page_size: int) -> int:
    return -(-max_total_len // page_size)


# ---------------------------------------------------------------------------
# per-request dense view (debug / tests / reference attention)
# ---------------------------------------------------------------------------

def gather_request_view(layer_pages, block_row):
    """Dense (S_view, n_kv, hd) K/V view of one request's table row.

    ``layer_pages`` is one layer's ``(k_pages, v_pages)`` — or the 4-tuple
    with per-page scales under ``kv_quant="int8"``, in which case the view
    is dequantized to fp32.  ``S_view = pages_per_seq * page_size``;
    positions beyond the request's written length hold stale pool contents
    — callers must mask by length."""
    k_pages, v_pages = layer_pages[0], layer_pages[1]
    k_scales = layer_pages[2] if len(layer_pages) > 2 else None
    v_scales = layer_pages[3] if len(layer_pages) > 2 else None
    page_size = k_pages.shape[1]
    idx = jnp.maximum(block_row, 0)
    nkv, hd = k_pages.shape[2], k_pages.shape[3]
    k = k_pages[idx].reshape(-1, nkv, hd)
    v = v_pages[idx].reshape(-1, nkv, hd)
    if k_scales is not None:
        k = k.astype(jnp.float32) * k_scales[idx].reshape(-1, nkv)[..., None]
        v = v.astype(jnp.float32) * v_scales[idx].reshape(-1, nkv)[..., None]
    valid = jnp.repeat(block_row >= 0, page_size)
    return k, v, valid


class PageTransfer(NamedTuple):
    """Host-side buffer of extracted physical pages — the unit of
    cross-replica KV movement.

    Holds the raw page contents for every layer (int8 codes under
    ``kv_quant="int8"``, the residual dtype otherwise) **plus the
    per-(page, slot, kv-head) fp32 scales** when quantized: a page without
    its scales dequantizes to garbage, so the scales travel in the same
    buffer and re-admit in the same scatter.  Shapes mirror the pool with
    the page axis narrowed to the extracted set::

        k / v           : (num_layers, n, page_size, n_kv, head_dim)
        k/v_scales      : (num_layers, n, page_size, n_kv)   (int8 only)
    """

    k: np.ndarray
    v: np.ndarray
    k_scales: Optional[np.ndarray] = None
    v_scales: Optional[np.ndarray] = None

    @property
    def num_pages(self) -> int:
        return int(self.k.shape[1])

    @property
    def nbytes(self) -> int:
        n = self.k.nbytes + self.v.nbytes
        if self.k_scales is not None:
            n += self.k_scales.nbytes + self.v_scales.nbytes
        return n


def export_pages(cache: PagedKVCache, pages) -> PageTransfer:
    """Extract physical pages into one host-side ``PageTransfer``.

    One batched gather per tensor (``cache.k_pages[:, idx]``) followed by a
    single ``jax.device_get`` of the whole bundle — never a per-page
    dispatch.  Device→host→device is the portable route today; on
    multi-device topologies the same buffers can ride ``jax.device_put``
    P2P without changing callers."""
    idx = jnp.asarray(pages, jnp.int32)
    if cache.k_scales is None:
        k, v = jax.device_get((cache.k_pages[:, idx], cache.v_pages[:, idx]))
        return PageTransfer(k=np.asarray(k), v=np.asarray(v))
    k, v, ks, vs = jax.device_get(
        (cache.k_pages[:, idx], cache.v_pages[:, idx],
         cache.k_scales[:, idx], cache.v_scales[:, idx]))
    return PageTransfer(k=np.asarray(k), v=np.asarray(v),
                        k_scales=np.asarray(ks), v_scales=np.asarray(vs))


def import_pages(cache: PagedKVCache, dst_pages,
                 transfer: PageTransfer) -> PagedKVCache:
    """Re-admit an exported buffer into this pool's ``dst_pages``.

    The mirror of :func:`export_pages`: one batched scatter per tensor
    (the ``copy_pages`` idiom with a host-side source), scales included —
    an imported page dequantizes byte-identically to its source pool's
    copy.  ``len(dst_pages)`` must equal ``transfer.num_pages``; the
    source and destination pools must agree on quantization mode."""
    dst = jnp.asarray(dst_pages, jnp.int32)
    if dst.shape[0] != transfer.num_pages:
        raise ValueError(
            f"import of {transfer.num_pages} pages into {dst.shape[0]} slots")
    if (cache.k_scales is None) != (transfer.k_scales is None):
        raise ValueError("kv_quant mismatch between transfer and pool")
    k = cache.k_pages.at[:, dst].set(
        jnp.asarray(transfer.k, cache.k_pages.dtype))
    v = cache.v_pages.at[:, dst].set(
        jnp.asarray(transfer.v, cache.v_pages.dtype))
    if cache.k_scales is None:
        return PagedKVCache(k_pages=k, v_pages=v)
    ks = cache.k_scales.at[:, dst].set(
        jnp.asarray(transfer.k_scales, jnp.float32))
    vs = cache.v_scales.at[:, dst].set(
        jnp.asarray(transfer.v_scales, jnp.float32))
    return PagedKVCache(k_pages=k, v_pages=v, k_scales=ks, v_scales=vs)


def copy_pages(cache: PagedKVCache, src, dst) -> PagedKVCache:
    """Copy whole physical pages ``src[i] -> dst[i]`` across every layer.

    The device half of a COW fork: the group's partial prompt-tail page is
    duplicated into each forked lane's privately owned page (src/dst: (N,)
    int32 page ids).  Everything else in the fork is pure block-table /
    refcount bookkeeping — the attention kernels never change.  Quantized
    pools copy the per-page scales alongside the int8 codes — a forked
    page dequantizes identically to its source."""
    k = cache.k_pages.at[:, dst].set(cache.k_pages[:, src])
    v = cache.v_pages.at[:, dst].set(cache.v_pages[:, src])
    if cache.k_scales is None:
        return PagedKVCache(k_pages=k, v_pages=v)
    ks = cache.k_scales.at[:, dst].set(cache.k_scales[:, src])
    vs = cache.v_scales.at[:, dst].set(cache.v_scales[:, src])
    return PagedKVCache(k_pages=k, v_pages=v, k_scales=ks, v_scales=vs)


# ---------------------------------------------------------------------------
# chunked prefill (batch=1, one chunk of one request)
# ---------------------------------------------------------------------------

def _paged_attn_prefill(p, cfg: ModelConfig, x, positions, valid, layer_pages,
                        block_row):
    """x: (1, C, D); positions/valid: (1, C); block_row: (P,).

    Writes the chunk's K/V into the request's pages (invalid lanes land in
    the garbage page) and attends causally over the request's whole table
    — earlier chunks included."""
    q = attention._project_q(p, cfg, x, positions)
    k, v = attention._project_kv(p, cfg, x, positions)
    k_pages, v_pages = layer_pages[0], layer_pages[1]
    quantized = len(layer_pages) > 2
    page_size = k_pages.shape[1]

    logical = positions[0] // page_size                      # (C,)
    logical = jnp.clip(logical, 0, block_row.shape[0] - 1)
    phys = jnp.where(valid[0], block_row[logical], GARBAGE_PAGE)
    phys = jnp.maximum(phys, GARBAGE_PAGE)                   # -1 -> garbage
    off = positions[0] % page_size
    if quantized:
        k_scales, v_scales = layer_pages[2], layer_pages[3]
        kq, ks = quantize_kv(k[0])
        vq, vs = quantize_kv(v[0])
        k_pages = k_pages.at[phys, off].set(kq)
        v_pages = v_pages.at[phys, off].set(vq)
        k_scales = k_scales.at[phys, off].set(ks)
        v_scales = v_scales.at[phys, off].set(vs)
        layer_pages = (k_pages, v_pages, k_scales, v_scales)
    else:
        k_pages = k_pages.at[phys, off].set(k[0].astype(k_pages.dtype))
        v_pages = v_pages.at[phys, off].set(v[0].astype(v_pages.dtype))
        layer_pages = (k_pages, v_pages)

    # in-chunk queries read their own K/V back through the (possibly
    # quantized) pool — prefill attends to exactly what decode will see.
    kd, vd, page_valid = gather_request_view(layer_pages, block_row)
    s_view = kd.shape[0]
    kv_pos = jnp.arange(s_view, dtype=jnp.int32)[None, :]
    kv_valid = page_valid[None, :]
    # causality (kv_pos <= q_pos) masks every not-yet-written position: the
    # request fills its table contiguously, so any stale pool content sits
    # at kv_pos > q_pos.  Invalid query lanes get q_pos = -1 (fully masked).
    q_pos = jnp.where(valid, positions, -1)
    out = attention.attend(q, kd[None], vd[None], q_pos, kv_pos, kv_valid,
                           window=cfg.sliding_window,
                           softcap=cfg.attn_logit_softcap)
    c = x.shape[1]
    # the dequantized fp32 view promotes the attention output; cast back to
    # the residual dtype (identity when unquantized — same jaxpr as before)
    return out.reshape(1, c, cfg.q_dim).astype(x.dtype) @ p["wo"], layer_pages


def _paged_block_prefill(p, cfg: ModelConfig, x, positions, valid, layer_pages,
                         block_row, *, moe_mode: str):
    y, layer_pages = _paged_attn_prefill(
        p["attn"], cfg, module.rmsnorm(p["ln1"], x, cfg.norm_eps),
        positions, valid, layer_pages, block_row)
    x = x + y
    h = module.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe.moe_apply(p["moe"], cfg, h, mode=moe_mode)
    else:
        y = ffn.mlp(p["mlp"], cfg, h)
    return x + y, layer_pages


def paged_prefill_chunk(params, cfg: ModelConfig, tokens, valid, start,
                        block_row, cache: PagedKVCache, *, moe_mode: str = "ep"):
    """One prefill chunk of one request.

    tokens/valid: (1, C); start: scalar int32 (chunk's first position);
    block_row: (pages_per_seq,) int32.  Returns (last-valid-position logits
    (1, V) fp32, cache)."""
    x = params["embed"][tokens]
    c = tokens.shape[1]
    positions = start + jnp.arange(c, dtype=jnp.int32)[None, :]

    def body(h, inp):
        lp, pages = inp
        h2, pages2 = _paged_block_prefill(lp, cfg, h, positions, valid, pages,
                                          block_row, moe_mode=moe_mode)
        return h2, pages2

    x, pages = jax.lax.scan(body, x, (params["blocks"], cache.layer_pages))
    from repro.models.transformer import _last_position_logits
    return (_last_position_logits(params, cfg, x, valid),
            _cache_from_layers(pages))


# ---------------------------------------------------------------------------
# decode (one token for every slot, through the block tables)
# ---------------------------------------------------------------------------

def _paged_attn_decode(p, cfg: ModelConfig, x, pos, layer_pages, block_tables,
                       *, attn_impl: str):
    """x: (B, 1, D); pos: (B,); block_tables: (B, P) (-1 rows = masked slot)."""
    b = x.shape[0]
    positions = pos[:, None]
    q = attention._project_q(p, cfg, x, positions)           # (B,1,KV,G,hd)
    k_new, v_new = attention._project_kv(p, cfg, x, positions)
    k_pages, v_pages = layer_pages[0], layer_pages[1]
    quantized = len(layer_pages) > 2
    k_scales = layer_pages[2] if quantized else None
    v_scales = layer_pages[3] if quantized else None
    page_size = k_pages.shape[1]

    logical = jnp.clip(pos // page_size, 0, block_tables.shape[1] - 1)
    phys = jnp.take_along_axis(block_tables, logical[:, None], axis=1)[:, 0]
    phys = jnp.maximum(phys, GARBAGE_PAGE)                   # masked -> garbage
    off = pos % page_size
    if quantized:
        kq, ks = quantize_kv(k_new[:, 0])
        vq, vs = quantize_kv(v_new[:, 0])
        k_pages = k_pages.at[phys, off].set(kq)
        v_pages = v_pages.at[phys, off].set(vq)
        k_scales = k_scales.at[phys, off].set(ks)
        v_scales = v_scales.at[phys, off].set(vs)
        out_pages = (k_pages, v_pages, k_scales, v_scales)
    else:
        k_pages = k_pages.at[phys, off].set(k_new[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[phys, off].set(v_new[:, 0].astype(v_pages.dtype))
        out_pages = (k_pages, v_pages)

    if attn_impl in ("kernel", "kernel_interpret"):
        from repro.kernels.paged_decode_attention import paged_decode_attention
        hd = cfg.resolved_head_dim
        qh = q.reshape(b, cfg.num_heads, hd)
        out = paged_decode_attention(
            qh, k_pages, v_pages, block_tables, pos + 1,
            k_scales=k_scales, v_scales=v_scales,
            softcap=cfg.attn_logit_softcap,
            interpret=(attn_impl == "kernel_interpret"))
        out = out.reshape(b, 1, cfg.q_dim)
    else:
        nkv, hd = k_pages.shape[2], k_pages.shape[3]
        idx = jnp.maximum(block_tables, 0)
        kd = k_pages[idx].reshape(b, -1, nkv, hd)
        vd = v_pages[idx].reshape(b, -1, nkv, hd)
        if quantized:
            kd = (kd.astype(jnp.float32)
                  * k_scales[idx].reshape(b, -1, nkv)[..., None])
            vd = (vd.astype(jnp.float32)
                  * v_scales[idx].reshape(b, -1, nkv)[..., None])
        s_view = kd.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(s_view, dtype=jnp.int32)[None, :],
                                  (b, s_view))
        kv_valid = jnp.repeat(block_tables >= 0, page_size, axis=1)
        out = attention._attend_direct(q, kd, vd, positions, kv_pos, kv_valid,
                                       window=cfg.sliding_window,
                                       softcap=cfg.attn_logit_softcap)
        out = out.reshape(b, 1, cfg.q_dim)
    # cast back to the residual dtype (identity when unquantized)
    return out.astype(x.dtype) @ p["wo"], out_pages


def _paged_block_decode(p, cfg: ModelConfig, x, pos, layer_pages, block_tables,
                        *, moe_mode: str, attn_impl: str):
    y, layer_pages = _paged_attn_decode(
        p["attn"], cfg, module.rmsnorm(p["ln1"], x, cfg.norm_eps),
        pos, layer_pages, block_tables, attn_impl=attn_impl)
    x = x + y
    h = module.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe.moe_apply(p["moe"], cfg, h, mode=moe_mode)
    else:
        y = ffn.mlp(p["mlp"], cfg, h)
    return x + y, layer_pages


def paged_decode_step(params, cfg: ModelConfig, token, pos, cache: PagedKVCache,
                      block_tables, *, moe_mode: str = "ep",
                      attn_impl: str = "ref"):
    """One-token decode for every slot. token/pos: (B,) int32;
    block_tables: (B, P) int32 (pass -1 rows for slots that must not step).
    Returns (logits (B, V) fp32, cache)."""
    x = params["embed"][token][:, None, :]

    def body(h, inp):
        lp, pages = inp
        h2, pages2 = _paged_block_decode(lp, cfg, h, pos, pages, block_tables,
                                         moe_mode=moe_mode, attn_impl=attn_impl)
        return h2, pages2

    x, pages = jax.lax.scan(body, x, (params["blocks"], cache.layer_pages))
    from repro.models.transformer import _unembed
    return (_unembed(params, cfg, x)[:, 0, :],
            _cache_from_layers(pages))
