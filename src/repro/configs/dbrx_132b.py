"""DBRX-132B [hf:databricks/dbrx-base]: fine-grained MoE, 16 experts top-4,
GQA kv=8, 40 layers."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    qk_norm=False,
    rope_theta=500_000.0,
    mlp_activation="swiglu",
    num_experts=16,
    num_experts_per_tok=4,
    moe_d_ff=10752,
    capacity_factor=1.25,
)
