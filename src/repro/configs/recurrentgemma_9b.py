"""RecurrentGemma-9B [arXiv:2402.19427]: RG-LRU + local attention, pattern
(recurrent, recurrent, local-attn), MQA kv=1, window 2048. Runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    qk_norm=False,
    sliding_window=2048,
    rope_theta=10_000.0,
    mlp_activation="geglu",
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    conv_width=4,
)
