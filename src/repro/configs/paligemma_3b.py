"""PaliGemma-3B [arXiv:2407.07726]: SigLIP (stubbed) + gemma decoder, MQA kv=1.

The ViT/SigLIP frontend is a stub: `input_specs` provides 256 precomputed,
projected patch embeddings (B, 256, d_model)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    qk_norm=False,
    rope_theta=10_000.0,
    mlp_activation="geglu",
    num_image_tokens=256,
)
