"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B]: the paper's Table-1 model-size ablation."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_activation="swiglu",
)
