"""Granite-8B code [arXiv:2405.04324]: llama-arch dense, GQA kv=8."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    qk_norm=False,
    rope_theta=10_000_000.0,
    mlp_activation="swiglu",
)
