"""H2O-Danube-3-4B [arXiv:2401.16818]: llama+mistral mix with sliding-window
attention (window 4096) — the dense arch that runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    qk_norm=False,
    sliding_window=4096,
    rope_theta=10_000.0,
    mlp_activation="swiglu",
)
