"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

`input_specs` never allocates: everything is jax.ShapeDtypeStruct (weak-type
correct, shardable), following the shannon/kernels dry-run pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def model_inputs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Forward-pass inputs (tokens + modality-stub embeddings)."""
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        text = seq - cfg.num_image_tokens
        assert text > 0
        out["tokens"] = _sds((batch, text), jnp.int32)
        out["patches"] = _sds((batch, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
    elif cfg.family == "audio":
        out["tokens"] = _sds((batch, seq), jnp.int32)
        out["frames"] = _sds((batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    else:
        out["tokens"] = _sds((batch, seq), jnp.int32)
    return out


def train_inputs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """RL train_step inputs: rollout tokens + per-token RL fields."""
    out = model_inputs(cfg, batch, seq)
    tok_seq = out["tokens"].shape[1]
    f32 = jnp.float32
    out.update(
        mask=_sds((batch, tok_seq), f32),           # response-token mask
        advantages=_sds((batch, tok_seq), f32),
        old_logprobs=_sds((batch, tok_seq), f32),   # behaviour policy (rollout engine)
        prox_logprobs=_sds((batch, tok_seq), f32),  # proximal policy (decoupled PPO)
        ref_logprobs=_sds((batch, tok_seq), f32),   # reference policy (KL term)
        is_positive=_sds((batch,), f32),            # TOPR T+/T- split
    )
    return out


def decode_inputs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    return {
        "token": _sds((batch,), jnp.int32),
        "pos": _sds((batch,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """All host-provided step inputs for (arch, shape) — ShapeDtypeStructs only.

    The decode cache itself is produced via `jax.eval_shape` in the launcher
    (it is carried state, not a host input).
    """
    if shape.kind == "train":
        return train_inputs(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        return model_inputs(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "decode":
        return decode_inputs(cfg, shape.global_batch)
    raise ValueError(shape.kind)


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (see DESIGN.md skip notes)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k decode skipped (DESIGN.md §long_500k)"
    return True, ""
