"""SeamlessM4T-medium backbone [arXiv:2308.11596]: enc-dec, 12+12 layers.

The mel/conv audio frontend is a stub: the encoder consumes precomputed
frame embeddings (B, encoder_frames, d_model) from `input_specs`."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="audio",
    num_layers=12,            # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    qk_norm=False,
    rope_theta=10_000.0,
    mlp_activation="swiglu",
    encoder_frames=1024,
)
