"""RWKV6-3B "Finch" [arXiv:2404.05892]: attention-free SSM, data-dependent
decay, head size 64. Runs long_500k (O(1) decode state)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,     # = d_model / rwkv_head_size (attention unused)
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_size=64,
    mlp_activation="swiglu",
)
