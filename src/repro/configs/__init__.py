"""Architecture registry: `--arch <id>` resolves here."""
from __future__ import annotations

from repro.configs import shapes  # noqa: F401
from repro.configs.shapes import SHAPES, InputShape, input_specs, shape_applicable  # noqa: F401
from repro.models.config import ModelConfig

from repro.configs.qwen3_0_6b import CONFIG as _qwen3_0_6b
from repro.configs.qwen3_1_7b import CONFIG as _qwen3_1_7b
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.qwen3_8b import CONFIG as _qwen3_8b
from repro.configs.granite_8b import CONFIG as _granite_8b
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.dbrx_132b import CONFIG as _dbrx

REGISTRY: dict[str, ModelConfig] = {
    c.arch_id: c
    for c in (
        _qwen3_4b, _qwen3_8b, _granite_8b, _danube, _paligemma,
        _seamless, _qwen3_moe, _rgemma, _rwkv6, _dbrx,
        # beyond the assigned pool: the paper's Table-1 ablation sizes
        _qwen3_0_6b, _qwen3_1_7b,
    )
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def list_archs() -> list[str]:
    return sorted(REGISTRY)
