"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family]: 128 experts top-8,
GQA kv=4, qk_norm, 94 layers."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_activation="swiglu",
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=1536,
    capacity_factor=1.25,
)
