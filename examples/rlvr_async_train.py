"""End-to-end RLVR driver: async GRPO-style training that actually LEARNS
the verifiable arithmetic task, comparing sync (alpha=0) vs async (alpha=2).

The async mode runs on the handle-based client API end to end: the
RolloutProducer consumes GenerationHandles (abort→resume continuation and
budget clamping live in the RolloutClient), and the controller uses the
OVERLAPPED weight sync — params are staged per-proxy between engine steps,
so rollout never suspends (pass --weight-sync blocking for the 3-phase
barrier).

This is the e2e deliverable driver; `--preset rl_100m --steps 300` runs the
by-the-book ~100M-parameter configuration (hours on CPU — default is the
CPU-friendly preset that demonstrates learning in minutes).

  PYTHONPATH=src python examples/rlvr_async_train.py [--steps 60]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import REGISTRY
from repro.data.dataset import ArithmeticTask, VOCAB
from repro.launch.pipeline import PipelineSettings, build_rlvr_pipeline
from repro.launch.train import PRESETS, build_model_cfg


def run_mode(alpha, steps, preset, seed=0, weight_sync="overlapped",
             replicas=1):
    model = build_model_cfg("qwen3-4b", preset)
    task = ArithmeticTask(max_operand=4, ops=("+",), seed=seed)
    settings = PipelineSettings(
        async_generation_ratio=alpha, pg_variant="tis",
        rollout_batch_size=16, num_return_sequences_in_group=8,
        num_slots=16, max_new_tokens=4, max_seq_len=16,
        weight_sync=weight_sync, learning_rate=5e-3, seed=seed,
        # --replicas N shards the 16 slots across N proxy/engine replicas
        # behind a ProxyRouter (queue scheduling + co-located groups);
        # N=1 is the plain single-proxy path.
        num_rollout_replicas=replicas)
    pipe = build_rlvr_pipeline(model, settings, task=task)
    t0 = time.time()
    stats = pipe.run(num_steps=steps, timeout=1800)
    wall = time.time() - t0
    rewards = [s.reward_mean for s in stats]
    return rewards, wall, max(s.staleness_max for s in stats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--weight-sync", default="overlapped",
                    choices=["overlapped", "blocking"])
    ap.add_argument("--replicas", type=int, default=1,
                    help="rollout fleet size (num_rollout_replicas)")
    args = ap.parse_args()

    for name, alpha in (("sync (alpha=0)", 0), ("async (alpha=2)", 2)):
        rewards, wall, stale = run_mode(alpha, args.steps, args.preset,
                                        weight_sync=args.weight_sync,
                                        replicas=args.replicas)
        k = max(2, len(rewards) // 5)
        print(f"{name:16s}: {wall:6.1f}s  reward {np.mean(rewards[:k]):.3f} "
              f"-> {np.mean(rewards[-k:]):.3f}  max_staleness={stale}")


if __name__ == "__main__":
    main()
