"""Agentic post-training on a simulated ALFWorld-style environment, with the
paper's §5.2 mechanisms: environment-level asynchronous rollout (EnvManager
pool sharing one LLMProxy) and redundant environment rollout
(num_env_groups x group_size > rollout_batch_size, fail-slow envs injected).

  PYTHONPATH=src python examples/agentic_alfworld_sim.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

from repro.configs import REGISTRY
from repro.envs.sim_envs import GridTargetEnv, LatencyEnv
from repro.launch.pipeline import PipelineSettings, build_agentic_pipeline

model = dataclasses.replace(
    REGISTRY["qwen3-4b"].smoke(),
    num_layers=2, d_model=128, num_heads=4, head_dim=32, num_kv_heads=2,
    d_ff=256, vocab_size=256)

settings = PipelineSettings(
    async_generation_ratio=1,
    pg_variant="topr",                 # T+/T- split suits sparse env rewards
    rollout_batch_size=8,
    num_slots=8,
    max_new_tokens=4,
    max_seq_len=64,
    learning_rate=1e-3,
)

# redundant env rollout: 5 groups x 3 envs = 15 > batch 8; some envs are
# fail-slow (5x latency) — the pool stops at 8 trajectories, stragglers
# never gate the step.
def make_env(eid):
    if eid % 5 == 0:
        return LatencyEnv(eid, mu=0.05, sigma=0.02, p_fail_slow=0.5,
                          fail_slow_factor=5.0, max_steps=3)
    return GridTargetEnv(eid, max_steps=6, latency=0.01)


pipe = build_agentic_pipeline(model, settings, make_env=make_env,
                              num_env_groups=5, group_size=3,
                              max_env_steps=6)
t0 = time.time()
stats = pipe.run(num_steps=4)
print(f"\n4 agentic steps in {time.time() - t0:.1f}s "
      f"({len(pipe.pool.managers)} concurrent envs, "
      f"{settings.num_slots} decode slots)")
for s in stats:
    print(f"step {s.step}: wait {s.wait_time:.2f}s train {s.train_time:.2f}s "
          f"stale_max {s.staleness_max} reward {s.reward_mean:.2f}")
print("env-level async: decode slots stayed busy while envs were stepping;")
print(f"proxy completed {pipe.proxy.requests_completed} requests over "
      f"{pipe.proxy.steps_executed} engine steps")
