"""Agentic post-training on a simulated ALFWorld-style environment, with the
paper's §5.2 mechanisms: environment-level asynchronous rollout (EnvManager
pool sharing one rollout service) and redundant environment rollout
(num_env_groups x group_size > rollout_batch_size, fail-slow envs injected).

Each EnvManager drives a first-class ``Session`` (the handle-based client
API): the session owns the conversation context, version-tags every turn,
and a turn interrupted by a weight sync transparently RESUMES — on the paged
engine the retained KV pages are re-attached, so trajectories survive syncs
with zero re-prefill instead of being thrown away.

  PYTHONPATH=src python examples/agentic_alfworld_sim.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

from repro.configs import REGISTRY
from repro.envs.sim_envs import GridTargetEnv, LatencyEnv
from repro.launch.pipeline import PipelineSettings, build_agentic_pipeline

model = dataclasses.replace(
    REGISTRY["qwen3-4b"].smoke(),
    num_layers=2, d_model=128, num_heads=4, head_dim=32, num_kv_heads=2,
    d_ff=256, vocab_size=256)

settings = PipelineSettings(
    async_generation_ratio=1,
    pg_variant="topr",                 # T+/T- split suits sparse env rewards
    rollout_batch_size=8,
    num_slots=8,
    max_new_tokens=4,
    max_seq_len=64,
    weight_sync="overlapped",          # rollout keeps stepping through syncs
    agentic_context="full",            # sessions resubmit the conversation;
                                       # the prefix cache makes each turn an
                                       # incremental prefill
    learning_rate=1e-3,
)

# redundant env rollout: 5 groups x 3 envs = 15 > batch 8; some envs are
# fail-slow (5x latency) — the pool stops at 8 trajectories, stragglers
# never gate the step.
def make_env(eid):
    if eid % 5 == 0:
        return LatencyEnv(eid, mu=0.05, sigma=0.02, p_fail_slow=0.5,
                          fail_slow_factor=5.0, max_steps=3)
    return GridTargetEnv(eid, max_steps=6, latency=0.01)


pipe = build_agentic_pipeline(model, settings, make_env=make_env,
                              num_env_groups=5, group_size=3,
                              max_env_steps=6)
t0 = time.time()
stats = pipe.run(num_steps=4)
print(f"\n4 agentic steps in {time.time() - t0:.1f}s "
      f"({len(pipe.pool.managers)} concurrent envs, "
      f"{settings.num_slots} decode slots)")
for s in stats:
    print(f"step {s.step}: wait {s.wait_time:.2f}s train {s.train_time:.2f}s "
          f"stale_max {s.staleness_max} reward {s.reward_mean:.2f}")
print("env-level async: decode slots stayed busy while envs were stepping;")
print(f"proxy completed {pipe.proxy.requests_completed} requests over "
      f"{pipe.proxy.steps_executed} engine steps "
      f"(suspends: {pipe.proxy.suspend_count} — overlapped sync)")
print(f"session turns rode the prefix cache: {pipe.proxy.cache_stats}")
print(f"in-flight turns resumed across weight syncs: "
      f"{pipe.client.resumes} page re-attaches, "
      f"{pipe.client.reprefills} re-prefills")
