"""Quickstart: the ROLL Flash public API in ~60 lines.

Part 1 drives the handle-based rollout client directly (submit ->
GenerationHandle -> result/stream); part 2 builds the asynchronous training
pipeline on a tiny model and runs two steps (overlapped weight sync: rollout
never stops while the trainer swaps params).

Kept CI-fast (<30 s on a laptop CPU): the tier-1 workflow smoke-runs this
file so the public API examples cannot rot.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import REGISTRY
from repro.core import LLMProxy, RolloutClient, RolloutTask
from repro.data.dataset import VOCAB
from repro.launch.pipeline import (PipelineSettings, build_rlvr_pipeline,
                                   make_rollout_engine)
from repro.models import get_api

# 1. a tiny architecture config (reduced variant for CPU)
model = dataclasses.replace(
    REGISTRY["qwen3-4b"].smoke(),
    num_layers=2, d_model=64, num_heads=4, head_dim=16, num_kv_heads=2,
    d_ff=128, vocab_size=VOCAB)

# ---------------------------------------------------------------- handles
# The rollout surface: a RolloutClient over an LLMProxy issues handles.
settings = PipelineSettings(num_slots=4, max_new_tokens=6, max_seq_len=32)
api = get_api(model)
import jax
engine = make_rollout_engine(api, api.init(jax.random.PRNGKey(0)), settings)
proxy = LLMProxy(engine).start()
client = RolloutClient(proxy)

task = RolloutTask(task_id=0, prompt_id=0, replica_idx=0,
                   prompt_tokens=np.asarray([3, 1, 4, 1, 5], np.int32),
                   max_new_tokens=6)
handle = client.submit(task, stream=True)      # -> GenerationHandle
chunks = [list(c) for c in handle.stream()]    # incremental tokens
result = handle.result(timeout=60)             # resolves exactly once
print(f"handle: tokens={list(result.tokens)} streamed_chunks={len(chunks)} "
      f"legs={result.legs}")
proxy.stop()

# --------------------------------------------------------------- pipeline
# 2. the async architecture, configured like the paper's appendix-A YAML
settings = PipelineSettings(
    async_generation_ratio=2,      # the asynchronous ratio alpha (0 = Sync)
    pg_variant="tis",              # off-policy corrector
    rollout_batch_size=8,          # samples per training step
    num_return_sequences_in_group=2,
    num_slots=4,                   # decode slots (the rollout "GPUs")
    max_new_tokens=4,
    max_seq_len=32,
    weight_sync="overlapped",      # staged swap: rollout never suspends
    learning_rate=3e-3,
)

# 3. build + run: engine -> LLMProxy -> RolloutClient -> SampleBuffer(alpha)
#    -> RolloutProducer (handle consumer) -> AsyncController (train)
pipe = build_rlvr_pipeline(model, settings)
stats = pipe.run(num_steps=2)

print(f"\n{'step':>4} {'wait_s':>7} {'train_s':>8} {'sync_s':>7} "
      f"{'stale_max':>9} {'reward':>7}")
for s in stats:
    print(f"{s.step:>4} {s.wait_time:>7.2f} {s.train_time:>8.2f} "
          f"{s.sync_time:>7.3f} {s.staleness_max:>9} {s.reward_mean:>7.2f}")
print(f"\nbuffer: produced={pipe.buffer.total_produced} "
      f"consumed={pipe.buffer.total_consumed} capacity={pipe.buffer.capacity}")
print("overlapped sync: proxy never suspended:",
      pipe.proxy.suspend_count == 0)
print("staleness never exceeded alpha:",
      all(s.staleness_max <= settings.async_generation_ratio for s in stats))
