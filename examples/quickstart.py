"""Quickstart: the ROLL Flash public API in ~60 lines.

Builds the asynchronous pipeline on a tiny model, runs a few steps, and
prints what the async architecture is doing (buffer occupancy, staleness,
weight-sync cadence).

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import REGISTRY, list_archs
from repro.data.dataset import VOCAB
from repro.launch.pipeline import PipelineSettings, build_rlvr_pipeline

print("assigned architectures:", ", ".join(list_archs()))

# 1. pick an architecture config (reduced variant for CPU)
model = dataclasses.replace(
    REGISTRY["qwen3-4b"].smoke(),
    num_layers=2, d_model=128, num_heads=4, head_dim=32, num_kv_heads=2,
    d_ff=256, vocab_size=VOCAB)

# 2. configure the pipeline exactly like the paper's appendix-A YAML
settings = PipelineSettings(
    async_generation_ratio=2,      # the asynchronous ratio alpha (0 = Sync)
    pg_variant="tis",              # off-policy corrector: ppo | decoupled_ppo
                                   #   | tis | cispo | topr | weighted_topr
    rollout_batch_size=16,         # samples per training step
    num_return_sequences_in_group=4,
    is_num_return_sequences_expand=True,   # prompt replication
    num_slots=16,                  # decode slots (the rollout "GPUs")
    max_new_tokens=6,
    learning_rate=3e-3,
)

# 3. build + run: DecodeEngine -> LLMProxy -> SampleBuffer(alpha)
#    -> RolloutProducer (continuous generation) -> AsyncController (train)
pipe = build_rlvr_pipeline(model, settings)
stats = pipe.run(num_steps=5)

print(f"\n{'step':>4} {'wait_s':>7} {'train_s':>8} {'sync_s':>7} "
      f"{'stale_max':>9} {'reward':>7}")
for s in stats:
    print(f"{s.step:>4} {s.wait_time:>7.2f} {s.train_time:>8.2f} "
          f"{s.sync_time:>7.3f} {s.staleness_max:>9} {s.reward_mean:>7.2f}")
print(f"\nbuffer: produced={pipe.buffer.total_produced} "
      f"consumed={pipe.buffer.total_consumed} capacity={pipe.buffer.capacity}")
print("staleness never exceeded alpha:",
      all(s.staleness_max <= settings.async_generation_ratio for s in stats))
