#!/usr/bin/env python
"""concheck — lock-discipline static analysis for the async fleet.

Usage:
    python tools/concheck.py [PATH ...] [--graph-out FILE] [--verbose]

Checks every ``.py`` file under the given paths (default: ``src/repro``)
with the rules in ``repro.analysis.static_check`` and exits non-zero if any
violation is found.  ``--graph-out`` writes the extracted static
lock-acquisition graph as JSON (uploaded as a CI artifact).

Waive a finding inline with a reasoned ``# concheck: disable=<rule>`` on the
offending line.  Rules: guarded-by, lock-order, blocking-under-lock,
cond-wait-loop, thread-join, busy-wait.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.static_check import RULES, check_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "paths", nargs="*",
        default=[os.path.join(os.path.dirname(_HERE), "src", "repro")],
        help="files/directories to check (default: src/repro)",
    )
    ap.add_argument("--graph-out", metavar="FILE", default=None,
                    help="write the static lock-order graph JSON here")
    ap.add_argument("--verbose", action="store_true",
                    help="also print the lock graph and per-rule counts")
    args = ap.parse_args(argv)

    result = check_paths(args.paths)

    if args.graph_out:
        with open(args.graph_out, "w", encoding="utf-8") as fh:
            json.dump(result.graph, fh, indent=2, sort_keys=True)
        print(f"concheck: lock graph ({len(result.graph['nodes'])} locks, "
              f"{len(result.graph['edges'])} edges) -> {args.graph_out}")

    if args.verbose:
        print("lock-order edges:")
        for e in result.graph["edges"]:
            print(f"  {e['from']} -> {e['to']}   ({e['at']})")
        counts = {r: 0 for r in RULES}
        for v in result.violations:
            counts[v.rule] = counts.get(v.rule, 0) + 1
        print("rule hits:", {k: v for k, v in counts.items() if v})

    for v in result.violations:
        print(str(v))

    if result.violations:
        print(f"concheck: {len(result.violations)} violation(s)")
        return 1
    print("concheck: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
