"""Paged-KV engine: slot-engine parity, chunked prefill, abort→resume.

The two load-bearing guarantees (ISSUE acceptance criteria):

* the paged engine matches the seed slot engine token-for-token under
  greedy decoding, at mixed prompt lengths with co-scheduled prefill;
* ABORT with retained pages → resume produces byte-identical samples to
  an uninterrupted run (no prefix re-prefill, logprobs bit-equal).
"""
import threading
import time

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.llm_proxy import LLMProxy
from repro.core.sample_buffer import SampleBuffer
from repro.core.scheduler import RolloutProducer
from repro.core.types import RolloutTask, next_uid
from repro.models import get_api
from repro.rollout.engine import DecodeEngine
from repro.rollout.paged_engine import PagedDecodeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _drain(eng, want, max_steps=500):
    results = {}
    for _ in range(max_steps):
        for rid, toks, lps in eng.step():
            results[rid] = (list(toks), list(lps))
        if len(results) >= want:
            return results
    raise AssertionError(f"engine stalled: {len(results)}/{want} finished")


def _solo_slot(api, params, prompt, budget, max_total_len=64):
    eng = DecodeEngine(api, params, num_slots=1, max_total_len=max_total_len,
                       eos_id=99, temperature=0.0, prefill_bucket=None)
    eng.add_request(0, prompt, budget)
    return _drain(eng, 1)[0]


def test_paged_matches_slot_engine_greedy_mixed_lengths(setup):
    """Mixed-length prompts admitted while others decode: every request's
    greedy output must equal the slot engine decoding it alone."""
    cfg, api, params = setup
    eng = PagedDecodeEngine(api, params, num_slots=3, max_total_len=64,
                            page_size=8, prefill_chunk=8, eos_id=99,
                            temperature=0.0)
    rng = np.random.default_rng(7)
    prompts = {rid: rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for rid, n in enumerate([3, 17, 9, 26, 5])}
    # admit the first wave; feed the rest as slots free up
    pending = list(prompts)[::-1]
    for _ in range(3):
        eng.add_request(pending[-1], prompts[pending[-1]], 6)
        pending.pop()
    results = {}
    for _ in range(500):
        for rid, toks, lps in eng.step():
            results[rid] = (list(toks), list(lps))
            if pending:
                eng.add_request(pending[-1], prompts[pending[-1]], 6)
                pending.pop()
        if len(results) == len(prompts):
            break
    assert len(results) == len(prompts)
    for rid, prompt in prompts.items():
        want_t, want_l = _solo_slot(api, params, prompt, 6)
        got_t, got_l = results[rid]
        assert got_t == want_t, f"request {rid} diverged from slot engine"
        np.testing.assert_allclose(got_l, want_l, rtol=1e-5, atol=1e-6)


def test_chunked_prefill_coschedules_with_decode(setup):
    """While a long prompt prefills chunk-by-chunk, an already-decoding
    request keeps producing tokens every step (no prefill stall)."""
    cfg, api, params = setup
    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=64,
                            page_size=8, prefill_chunk=8, eos_id=99,
                            temperature=0.0)
    eng.add_request(0, np.asarray([1, 2, 3], np.int32), 30)
    while eng.slots and eng.slots[list(eng.req_to_slot.values())[0]].phase != "decode":
        eng.step()
    # long prompt arrives: 4 chunks of prefill needed
    long_prompt = np.arange(1, 33, dtype=np.int32)
    eng.add_request(1, long_prompt, 4)
    tokens_before = len(eng.slots[eng.req_to_slot[0]].tokens)
    for _ in range(4):  # the 4 chunk steps
        eng.step()
    tokens_after = len(eng.slots[eng.req_to_slot[0]].tokens)
    assert tokens_after - tokens_before == 4, \
        "request 0 must decode one token per step during request 1's prefill"
    assert eng.total_prefill_chunks >= 4


def test_abort_resume_byte_identical(setup):
    """Retain pages on ABORT, resume later: final tokens AND logprobs are
    byte-identical to the uninterrupted run (prefix KV reused, not rebuilt)."""
    cfg, api, params = setup
    prompt = np.asarray([1, 5, 7, 9, 2, 4], np.int32)
    budget = 8

    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=64,
                            page_size=8, prefill_chunk=8, eos_id=99,
                            temperature=0.0)
    eng.add_request(0, prompt, budget)
    base_t, base_l = _drain(eng, 1)[0]

    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=64,
                            page_size=8, prefill_chunk=8, eos_id=99,
                            temperature=0.0)
    eng.add_request(0, prompt, budget)
    for _ in range(5):
        eng.step()
    partial = eng.abort(0, retain=True)
    assert partial.resumable and len(partial.tokens) > 0
    prefill_tokens_before_resume = eng.total_prefill_tokens
    # churn an unrelated request through the freed slot (page-pool reuse)
    eng.add_request(5, np.asarray([8, 8], np.int32), 3)
    _drain(eng, 1)
    eng.resume_request(0, 10, budget - len(partial.tokens))
    got = _drain(eng, 1)[10]
    # resume must NOT have re-prefilled the prefix
    assert eng.total_prefill_tokens == prefill_tokens_before_resume + 2, \
        "only request 5's 2-token prompt may have been prefilled after abort"
    full_t = list(partial.tokens) + got[0]
    full_l = list(partial.logprobs) + got[1]
    assert full_t == base_t
    np.testing.assert_array_equal(np.asarray(full_l, np.float32),
                                  np.asarray(base_l, np.float32))


def test_abort_resume_through_proxy_and_producer(setup):
    """The async path end-to-end: producer submits, ABORT_STALE(retain)
    interrupts, resume re-attaches pages; the published sample equals the
    uninterrupted greedy sequence."""
    cfg, api, params = setup
    prompt = np.asarray([2, 9, 4, 3], np.int32)
    budget = 40  # long enough that the abort below cannot race completion

    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=64,
                            page_size=8, prefill_chunk=8, eos_id=99,
                            temperature=0.0)
    eng.add_request(0, prompt, budget)
    base_t, _ = _drain(eng, 1)[0]

    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=64,
                            page_size=8, prefill_chunk=8, eos_id=99,
                            temperature=0.0)
    proxy = LLMProxy(eng).start()
    buf = SampleBuffer(batch_size=4, alpha=4)
    prompts = iter([(0, prompt)])
    producer = RolloutProducer(proxy, buf, prompts, group_size=1,
                               max_new_tokens=budget,
                               reward_fn=lambda s: 1.0)
    producer.start()
    # let generation get going, then abort everything with retained pages.
    # suspend() parks the loop so the ABORT command is guaranteed to be
    # processed before the request can run to completion.
    deadline = time.monotonic() + 30
    while eng.total_tokens_decoded < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert eng.total_tokens_decoded >= 2, "generation never started"
    proxy.suspend()
    proxy.abort_stale(min_version=10, retain=True)
    proxy.resume()
    while not buf._samples and time.monotonic() < deadline:
        time.sleep(0.01)
    producer.stop()
    proxy.stop()
    assert len(buf._samples) == 1
    sample = buf._samples[0]
    buf.close()
    assert proxy.requests_aborted >= 1
    assert list(sample.response_tokens) == base_t
    np.testing.assert_array_equal(sample.prompt_tokens, prompt)


def test_page_pool_accounting(setup):
    """Pages are exclusively owned, freed on finish/abort, admission is
    gated on pool headroom, and the refcount audit passes after every
    completion / abort / resume transition."""
    cfg, api, params = setup
    eng = PagedDecodeEngine(api, params, num_slots=4, max_total_len=32,
                            page_size=8, num_pages=9, prefill_chunk=8,
                            eos_id=99, temperature=0.0)
    total = eng.num_free_pages
    assert total == 8  # page 0 reserved as garbage
    assert eng.pages_shared == 0 and eng.pages_private == 0
    # 3 requests x (8 prompt + 8 budget) = 2 pages each
    for rid in range(3):
        assert eng.can_admit(8, 8)
        eng.add_request(rid, np.arange(1, 9, dtype=np.int32), 8)
    assert eng.num_free_pages == 2
    assert eng.pages_private == 6 and eng.pages_shared == 0
    assert eng.can_admit(8, 8) and not eng.can_admit(16, 16)
    eng.audit_pages()
    # retained pages stay allocated until release
    eng.step()
    partial = eng.abort(2, retain=True)
    assert partial.resumable
    assert eng.num_free_pages == 2
    eng.audit_pages()
    eng.release_retained(2)
    assert eng.num_free_pages == 4
    eng.audit_pages()
    # plain abort frees immediately
    eng.abort(1)
    assert eng.num_free_pages == 6
    eng.audit_pages()
    _drain(eng, 1)  # request 0 runs to completion
    assert eng.num_free_pages == total
    assert eng.pages_private == 0 and eng.pages_shared == 0
    assert eng.peak_pages_in_use == 6
    eng.audit_pages()
    assert not eng.slots and not eng.retained


def test_abort_resume_audit_cycle(setup):
    """Refcounts stay leak-free through a full abort->resume->finish cycle
    (the retained record holds the refs while parked)."""
    cfg, api, params = setup
    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=64,
                            page_size=8, prefill_chunk=8, eos_id=99,
                            temperature=0.0)
    eng.add_request(0, np.asarray([1, 5, 7, 9, 2, 4], np.int32), 8)
    for _ in range(5):
        eng.step()
    partial = eng.abort(0, retain=True)
    assert partial.resumable
    eng.audit_pages()
    eng.resume_request(0, 10, 8 - len(partial.tokens))
    eng.audit_pages()
    _drain(eng, 1)
    eng.audit_pages()
    assert eng.num_free_pages == eng.num_pages - 1


@pytest.mark.kernels
def test_engine_kernel_attention_matches_ref(setup):
    """The Pallas paged decode-attention path (interpret mode) plugged into
    the fused engine step must produce the ref path's greedy tokens."""
    cfg, api, params = setup
    outs = {}
    for impl in ("ref", "kernel_interpret"):
        eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=32,
                                page_size=8, prefill_chunk=8, eos_id=99,
                                temperature=0.0, attn_impl=impl)
        eng.add_request(0, np.asarray([1, 5, 7], np.int32), 6)
        outs[impl] = _drain(eng, 1)[0][0]
    assert outs["ref"] == outs["kernel_interpret"]


def test_paged_engine_rejects_recurrent_families(setup):
    cfg = tiny("rwkv6-3b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged"):
        PagedDecodeEngine(api, params, num_slots=1, max_total_len=16)


def test_resume_bypasses_page_starved_head_of_queue(setup):
    """Liveness: a page-starved plain request at the head of the pending
    queue must NOT block resume requests behind it — the resumes re-attach
    already-retained pages and are what frees the pool again."""
    cfg, api, params = setup
    # pool fits exactly two 2-page requests (page 0 is garbage)
    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=32,
                            page_size=8, num_pages=5, prefill_chunk=8,
                            eos_id=99, temperature=0.0)
    proxy = LLMProxy(eng)
    results = []
    for rid in (0, 1):
        task = RolloutTask(task_id=rid, prompt_id=rid, replica_idx=0,
                           prompt_tokens=np.asarray([1 + rid, 2, 3], np.int32),
                           max_new_tokens=8)
        proxy.generate(task, version=0, callback=results.append)
    proxy._process_commands()
    proxy._admit_pending()
    for _ in range(6):
        eng.step()
    # park both requests (all pages stay allocated)...
    proxy.abort_stale(min_version=5, retain=True)
    proxy._process_commands()
    assert eng.num_free_pages == 0 and len(eng.retained) == 2
    # ...then a page-hungry plain request jumps the queue ahead of resumes
    blocker = RolloutTask(task_id=99, prompt_id=99, replica_idx=0,
                          prompt_tokens=np.asarray([7] * 16, np.int32),
                          max_new_tokens=16)
    proxy.generate(blocker, version=5, callback=results.append)
    for i, r in enumerate(results[:2]):
        resumed = RolloutTask(task_id=10 + i, prompt_id=r.task.prompt_id,
                              replica_idx=0, prompt_tokens=r.task.prompt_tokens,
                              max_new_tokens=8 - len(r.tokens))
        proxy.generate_resumed(resumed, 5, results.append,
                               resume_from=r.request_id)
    proxy._process_commands()
    proxy._admit_pending()
    # the two resumes are running despite the blocked head
    assert sorted(eng.req_to_slot) == [10, 11]
    finished = set()
    for _ in range(200):
        for rid, _toks, _lps in eng.step():
            finished.add(rid)
        proxy._admit_pending()
        if finished >= {10, 11, 99}:
            break
    assert finished >= {10, 11, 99}, "blocker was never admitted"


def test_proxy_admits_paged_requests_beyond_pool(setup):
    """LLMProxy + can_admit: requests queue when the pool is full and are
    admitted as pages free up — no assertion crashes."""
    cfg, api, params = setup
    eng = PagedDecodeEngine(api, params, num_slots=2, max_total_len=32,
                            page_size=8, num_pages=5, prefill_chunk=8,
                            eos_id=99, temperature=0.0)
    proxy = LLMProxy(eng).start()
    results = []
    lock = threading.Lock()

    def cb(r):
        with lock:
            results.append(r)

    for i in range(4):
        task = RolloutTask(task_id=next_uid(), prompt_id=i, replica_idx=0,
                           prompt_tokens=np.asarray([1 + i, 2, 3], np.int32),
                           max_new_tokens=5)
        proxy.generate(task, version=0, callback=cb)
    deadline = time.monotonic() + 30
    while len(results) < 4 and time.monotonic() < deadline:
        time.sleep(0.01)
    proxy.stop()
    assert len(results) == 4
    assert all(not r.aborted and len(r.tokens) > 0 for r in results)
