"""Off-policy objective correctness + advantage estimators (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algos import (LossConfig, VARIANTS, gae, group_normalized_advantage,
                         kl_k3, policy_loss, rl_loss, token_logprobs)

B, S = 4, 8
KEY = jax.random.PRNGKey(0)


def _fields(key, scale=0.3):
    ks = jax.random.split(key, 6)
    lp = -jnp.abs(jax.random.normal(ks[0], (B, S)))
    old = lp + scale * jax.random.normal(ks[1], (B, S))
    prox = lp + scale * 0.5 * jax.random.normal(ks[2], (B, S))
    adv = jax.random.normal(ks[3], (B, S))
    mask = (jax.random.uniform(ks[4], (B, S)) > 0.3).astype(jnp.float32)
    mask = mask.at[:, 0].set(0.0)
    pos = (jax.random.uniform(ks[5], (B,)) > 0.5).astype(jnp.float32)
    return lp, old, prox, adv, mask, pos


@pytest.mark.parametrize("variant", VARIANTS)
def test_all_variants_finite_and_differentiable(variant):
    lp, old, prox, adv, mask, pos = _fields(KEY)
    cfg = LossConfig(pg_variant=variant)

    def f(lp_):
        return policy_loss(lp_, old, prox, adv, mask, pos, cfg)[0]

    loss, grad = jax.value_and_grad(f)(lp)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.isfinite(grad).all())
    # gradient only flows into masked (response) tokens
    assert float(jnp.abs(grad * (1 - mask)).max()) == 0.0


def test_decoupled_ppo_reduces_to_ppo_when_prox_is_old():
    lp, old, _, adv, mask, pos = _fields(KEY)
    l1, _ = policy_loss(lp, old, old, adv, mask, pos, LossConfig(pg_variant="ppo"))
    l2, _ = policy_loss(lp, old, old, adv, mask, pos,
                        LossConfig(pg_variant="decoupled_ppo"))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_tis_cispo_equal_reinforce_gradient_on_policy():
    """At ratio==1 (on-policy), TIS and CISPO weights are 1 -> gradient equals
    REINFORCE: -A * grad(logpi)."""
    lp, _, prox, adv, mask, pos = _fields(KEY)
    old = lp  # on-policy

    def seq_mean(x):
        return ((x * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1)).mean()

    for variant in ("tis", "cispo"):
        g = jax.grad(lambda l, v=variant: policy_loss(
            l, old, prox, adv, mask, pos, LossConfig(pg_variant=v))[0])(lp)
        g_reinforce = jax.grad(lambda l: -seq_mean(adv * l))(lp)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_reinforce),
                                   rtol=1e-5, atol=1e-6)


def test_topr_positive_untruncated_negative_truncated():
    lp, old, prox, adv, mask, _ = _fields(KEY, scale=2.0)  # big ratios
    cfg = LossConfig(pg_variant="topr", c=1.0)
    all_pos = jnp.ones((B,))
    all_neg = jnp.zeros((B,))
    g_pos = jax.grad(lambda l: policy_loss(l, old, prox, adv, mask, all_pos, cfg)[0])(lp)
    # positive trajectories: plain REINFORCE (no IS weight at all)
    def seq_mean(x):
        return ((x * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1)).mean()
    g_reinforce = jax.grad(lambda l: -seq_mean(adv * l))(lp)
    np.testing.assert_allclose(np.asarray(g_pos), np.asarray(g_reinforce),
                               rtol=1e-5, atol=1e-6)
    # negative trajectories: weights capped at c
    loss_neg, m = policy_loss(lp, old, prox, adv, mask, all_neg, cfg)
    assert bool(jnp.isfinite(loss_neg))


def test_ppo_clip_suppresses_gradient_outside_trust_region():
    """Tokens with ratio far outside [1-eps,1+eps] and favorable advantage
    contribute zero gradient."""
    lp = jnp.zeros((1, 4))
    old = jnp.full((1, 4), -2.0)  # ratio = e^2 >> 1+eps
    adv = jnp.ones((1, 4))
    mask = jnp.ones((1, 4))
    pos = jnp.ones((1,))
    g = jax.grad(lambda l: policy_loss(
        l, old, old * 0, adv, mask, pos, LossConfig(pg_variant="ppo"))[0])(lp)
    assert float(jnp.abs(g).max()) == 0.0


@given(st.integers(2, 16), st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_grpo_group_stats(g, n_groups):
    rewards = jnp.asarray(
        np.random.default_rng(g * 100 + n_groups).normal(size=g * n_groups),
        jnp.float32)
    adv = group_normalized_advantage(rewards, g)
    a = np.asarray(adv).reshape(n_groups, g)
    np.testing.assert_allclose(a.mean(1), 0.0, atol=1e-5)
    stds = np.asarray(rewards).reshape(n_groups, g).std(1)
    nz = stds > 1e-4
    np.testing.assert_allclose(a.std(1)[nz], 1.0, atol=1e-2)


def test_grpo_zero_variance_group_gives_zero_advantage():
    rewards = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    adv = group_normalized_advantage(rewards, 4)
    np.testing.assert_allclose(np.asarray(adv), 0.0, atol=1e-4)


def test_gae_matches_manual():
    rewards = jnp.asarray([[0.0, 0.0, 1.0]])
    values = jnp.asarray([[0.5, 0.5, 0.5]])
    mask = jnp.ones((1, 3))
    adv, ret = gae(rewards, values, mask, gamma=1.0, lam=1.0)
    # terminal: delta_2 = 1 - 0.5 = .5; delta_1 = 0 + .5 - .5 = 0 -> adv_1 = .5
    np.testing.assert_allclose(np.asarray(adv[0]), [0.5, 0.5, 0.5], atol=1e-6)


def test_kl_k3_nonnegative_and_zero_at_equal():
    lp, old, *_ = _fields(KEY)
    mask = jnp.ones((B, S))
    assert float(kl_k3(lp, lp, mask)) == pytest.approx(0.0, abs=1e-6)
    assert float(kl_k3(lp, old, mask)) >= 0.0


def test_token_logprobs_is_log_softmax_gather():
    logits = jax.random.normal(KEY, (2, 5, 11))
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 5), 0, 11)
    lp = token_logprobs(logits, toks)
    expected = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                   toks[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(lp), np.asarray(expected),
                               rtol=1e-5, atol=1e-5)


def test_engine_mismatch_cap_applies():
    lp, old, prox, adv, mask, pos = _fields(KEY, scale=3.0)
    batch = dict(old_logprobs=old, prox_logprobs=prox, ref_logprobs=lp,
                 advantages=adv, mask=mask, is_positive=pos)
    l1, _ = rl_loss(lp, batch, LossConfig(pg_variant="tis", engine_mismatch_cap=1e9))
    l2, _ = rl_loss(lp, batch, LossConfig(pg_variant="tis", engine_mismatch_cap=1.0))
    assert float(l1) != float(l2)


def test_critic_ppo_train_step():
    """Actor-critic PPO path: finite losses, value head learns the reward."""
    import sys
    sys.path.insert(0, "tests")
    from conftest import tiny
    from repro.models import get_api
    from repro.train.critic import make_critic_train_state, make_critic_train_step
    from repro.train.optimizer import OptConfig

    cfg = tiny("qwen3-4b")
    api = get_api(cfg)
    state = make_critic_train_state(api, jax.random.PRNGKey(0))
    step = jax.jit(make_critic_train_step(
        api, LossConfig(pg_variant="ppo"),
        OptConfig(learning_rate=1e-2, warmup_steps=1)))

    b, s = 4, 16
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    mask = jnp.zeros((b, s)).at[:, s // 2:].set(1.0)
    lp = -jnp.abs(jax.random.normal(key, (b, s)))
    batch = dict(tokens=tokens, mask=mask, rewards=jnp.asarray([1., 0., 1., 0.]),
                 advantages=mask * 0.0, old_logprobs=lp, prox_logprobs=lp,
                 ref_logprobs=lp, is_positive=jnp.asarray([1., 0., 1., 0.]))
    vlosses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        vlosses.append(float(metrics["value_loss"]))
    assert vlosses[-1] < vlosses[0]  # critic fits the terminal rewards
