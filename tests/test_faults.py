"""Fault tolerance & elasticity: crash failover, exactly-once handle
resolution, mid-run replica addition, autoscaling hysteresis, and the
seeded chaos sweeps behind the CI ``faults`` tier.

Acceptance-criteria coverage:

* killing a replica loses ZERO completed samples: every in-flight handle
  fails over through the abort→resume path and resolves exactly once;
* a crash during prefill, decode, or a staged weight sync never wedges
  the fleet (the sync ack of a dead replica is waived);
* ``add_replica`` places a warmed replica into rotation mid-run;
* the autoscaler scales up under queue pressure and drains/retires idle
  replicas, with patience + cooldown hysteresis (no flapping);
* after any of the above, ``fleet_audit`` is clean (rid→replica map
  empty at quiescence, engines audit clean).
"""
import threading
import time

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.async_controller import AsyncController
from repro.core.faults import (FaultInjector, FaultyProxy, ReplicaDeadError,
                               wrap_fleet)
from repro.core.llm_proxy import LLMProxy
from repro.core.rollout_client import RolloutClient
from repro.core.router import AutoscalePolicy, ProxyRouter
from repro.core.sample_buffer import SampleBuffer
from repro.core.scheduler import RolloutProducer
from repro.core.slo import SLOConfig
from repro.core.types import PRIORITY_HIGH, PRIORITY_LOW
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine
from test_router import FakeEngine, _task
from test_slo import _ptask


def _faulty_fleet(n=2, router_kw=None, **kw):
    engines = [FakeEngine(**kw) for _ in range(n)]
    proxies = wrap_fleet([LLMProxy(e, name=f"p{i}")
                          for i, e in enumerate(engines)])
    return engines, proxies, ProxyRouter(proxies, **(router_kw or {}))


def _wait_for(cond, timeout=10.0, tick=0.002):
    deadline = time.monotonic() + timeout
    while not cond() and time.monotonic() < deadline:
        time.sleep(tick)
    assert cond(), "condition not reached in time"


# ------------------------------------------------------------ FaultyProxy
def test_faulty_proxy_crash_semantics():
    """A killed replica behaves like a crashed process: unhealthy, raises
    on commands, suppresses in-flight callbacks, snapshots lost decode
    progress, and stop() is a no-op."""
    eng = FakeEngine(slots=2)
    p = FaultyProxy(LLMProxy(eng, name="victim"))
    fired = []
    rid = p.generate(_task(50, prompt=[1, 2]), 0, fired.append)
    assert p.healthy()
    p.start()
    _wait_for(lambda: eng.active.get(rid, {"toks": []})["toks"])
    p.kill()
    assert not p.healthy()
    assert p.kills == 1
    assert p.decoded_counts().get(rid, 0) > 0, "lost progress snapshotted"
    with pytest.raises(ReplicaDeadError):
        p.generate(_task(3), 0, fired.append)
    with pytest.raises(ReplicaDeadError):
        p.abort(rid)
    p.kill()                                  # idempotent
    p.stop()                                  # no-op post-mortem
    assert not fired, "callbacks of a crashed replica never fire"
    assert p.steps_executed >= 0, "metric reads survive the crash"


def test_faulty_proxy_kill_after_steps_watchdog():
    eng = FakeEngine(slots=2)
    p = FaultyProxy(LLMProxy(eng), kill_after_steps=3).start()
    p.generate(_task(1000, prompt=[1]), 0, lambda r: None)
    _wait_for(lambda: not p.healthy())
    assert p.inner.steps_executed >= 3


# --------------------------------------------------------------- failover
def test_failover_mid_decode_resolves_all_handles():
    """Tentpole acceptance: kill a replica mid-decode.  Every in-flight
    handle on it fails over to the survivor and resolves exactly once with
    the full budget; the fleet stays audit-clean; counters account the
    lost decode progress."""
    engines, proxies, router = _faulty_fleet(n=2, slots=4)
    router.start()
    client = RolloutClient(router)
    handles = [client.submit(_task(60, prompt=[1, 2, 3])) for _ in range(4)]
    fired = {id(h): [] for h in handles}
    for h in handles:
        h.add_done_callback(fired[id(h)].append)
    _wait_for(lambda: all(len(e.active) == 2 for e in engines))
    _wait_for(lambda: all(st["toks"]
                          for e in engines for st in e.active.values()))
    victim = 0
    proxies[victim].kill()
    assert router.probe_health() == [victim]
    for h in handles:
        res = h.result(30)
        assert not res.aborted and len(res.tokens) == 60
        assert sum(n for _, n in res.legs) == 60
    time.sleep(0.05)
    router.stop()
    assert all(len(v) == 1 for v in fired.values()), "exactly-once"
    assert router.replica_state(victim) == "dead"
    assert router.replicas_alive == 1
    assert router.failovers == 2, "both in-flight handles failed over"
    assert router.lost_tokens > 0, "mid-decode progress was lost"
    assert client.reprefills == 2, "failover re-admits the full prefix"
    router.fleet_audit()
    # the survivor did all the failed-over work: its own 2 plus the 2
    # re-admitted failover prefixes
    assert len(engines[1 - victim].added) == 4


def test_failover_during_prefill_zero_lost_tokens():
    """A crash before any decode step loses nothing: the failed-over
    requests re-admit from their original prompts, lost_tokens stays 0."""
    engines, proxies, router = _faulty_fleet(n=2, slots=4)
    client = RolloutClient(router)
    # un-started fleet: requests sit admitted pre-decode (prefill phase)
    handles = [client.submit(_task(10, prompt=[1] * 5)) for _ in range(4)]
    victim = router._home[handles[0].task.task_id].idx
    proxies[victim].kill()
    router.probe_health()
    router.start()
    for h in handles:
        res = h.result(30)
        assert not res.aborted and len(res.tokens) == 10
    time.sleep(0.05)
    router.stop()
    assert router.lost_tokens == 0
    assert router.failovers == 2
    router.fleet_audit()


def test_dispatch_detects_unprobed_death():
    """Without any health probe, submitting to a dead replica raises
    ReplicaDeadError at dispatch — the router marks it dead and retries
    placement on a survivor transparently."""
    engines, proxies, router = _faulty_fleet(n=2, slots=4)
    proxies[0].kill()                       # router not told
    client = RolloutClient(router)
    router.start()
    res = client.submit(_task(5, prompt=[1, 2])).result(10)
    router.stop()
    assert not res.aborted and len(res.tokens) == 5
    assert router.replica_state(0) == "dead"
    assert engines[1].added, "retried onto the survivor"


def test_retained_pages_dead_replica_reprefills_elsewhere():
    """An abort-with-retain victim whose home replica dies before the
    resume must NOT resume into vanished pages: the continuation falls
    back to re-prefilling the concatenated prefix on a survivor."""
    engines, proxies, router = _faulty_fleet(n=2, slots=2)
    router.start()
    versions = [0]
    client = RolloutClient(router, version_fn=lambda: versions[0])
    h = client.submit(_task(40, prompt=[1, 2, 3]), version=0)
    _wait_for(lambda: any(e.active for e in engines))
    home = 0 if engines[0].active else 1
    versions[0] = 1
    router.abort_stale(min_version=1, retain=True)
    proxies[home].kill()
    router.probe_health()
    res = h.result(30)
    time.sleep(0.05)
    router.stop()
    assert not res.aborted and sum(n for _, n in res.legs) == 40
    assert engines[1 - home].added, "continuation landed on the survivor"
    router.fleet_audit()


def test_crash_during_staged_weight_sync_waives_dead_ack():
    """A replica dying mid-staged-sync must not deadlock the trainer: the
    fleet sync event is set once every LIVE replica acked (the dead one's
    ack is waived by the in-wait health probe)."""
    engines, proxies, router = _faulty_fleet(n=3, slots=2)
    router.start()
    proxies[2].suspend()                    # wedge replica 2's command loop
    _wait_for(lambda: proxies[2].inner.suspend_count == 1)
    ev = router.update_weights_async("w1")
    assert not ev.wait(0.05), "suspended replica has not acked"
    proxies[2].kill()                       # dies mid-sync
    assert ev.wait(10), "dead replica's ack is waived"
    router.stop()
    assert engines[0].update_count == 1 and engines[1].update_count == 1
    assert router.replica_state(2) == "dead"


# -------------------------------------------------------------- elasticity
def test_add_replica_mid_run_warm_placement():
    """add_replica grows the fleet mid-run: the newcomer is warmed with
    the last-synced weights BEFORE taking traffic, and queue scheduling
    immediately places new work on it."""
    engines, proxies, router = _faulty_fleet(n=1, slots=4)
    router.start()
    assert router.update_weights_async("w7").wait(10)   # remembered for warm-starts
    new_eng = FakeEngine(slots=4)
    idx = router.add_replica(LLMProxy(new_eng, name="p_new"))
    assert idx == 1 and router.num_replicas == 2
    assert router.replicas_added == 1
    assert new_eng.update_count == 1, "warmed with the last weights"
    # load replica 0, then submit: least-loaded routing picks the newcomer
    client = RolloutClient(router)
    ballast = client.submit(_task(500, prompt=[1] * 4))
    h = client.submit(_task(5, prompt=[1, 2]))
    assert h.result(10).tokens is not None
    ballast.abort()
    ballast.result(10)
    router.stop()
    assert h.task.task_id in new_eng.added, "new replica took the work"
    router.fleet_audit()


def test_add_replica_requires_factory_or_proxy():
    _, _, router = _faulty_fleet(n=1)
    with pytest.raises(RuntimeError, match="replica_factory"):
        router.add_replica()


def test_autoscale_up_down_hysteresis():
    """Queue pressure past up_patience ticks grows the fleet; an idle
    fleet drains and RETIRES a replica after down_patience ticks; cooldown
    blocks immediate re-action; min/max bounds are honored."""
    made = []

    def factory():
        e = FakeEngine(slots=1)
        made.append(e)
        return LLMProxy(e, name=f"p_auto_{len(made)}")

    pol = AutoscalePolicy(min_replicas=1, max_replicas=2, queue_high=2.0,
                          active_low=0.5, up_patience=2, down_patience=2,
                          cooldown=1)
    eng = FakeEngine(slots=1, step_sleep=0.002)
    router = ProxyRouter([LLMProxy(eng, name="p0")],
                         replica_factory=factory, autoscale=pol)
    router.start()
    client = RolloutClient(router)
    # slots=1: one admits, the rest stack up as queue depth > 2.0 * 1
    handles = [client.submit(_task(10, prompt=[1])) for _ in range(6)]
    _wait_for(lambda: router.queue_depth >= 3)
    assert router.autoscale_tick() is None, "patience: one tick is noise"
    assert router.autoscale_tick() == "up"
    assert router.num_replicas == 2 and router.scale_ups == 1
    assert router.autoscale_tick() is None, "cooldown blocks re-action"
    for h in handles:
        assert h.result(10).tokens is not None
    _wait_for(lambda: router.load() == 0)
    # idle now: queue 0, utilization 0 < 0.5
    assert router.autoscale_tick() is None, "down patience tick 1"
    assert router.autoscale_tick() == "down"
    victim = next(i for i in range(2)
                  if router.replica_state(i) == "draining")
    assert router.autoscale_tick() is None, "cooldown"
    _wait_for(lambda: router.autoscale_tick() is None
              and router.replica_state(victim) == "retired")
    assert router.scale_downs == 1
    assert router.replicas_alive == 1
    # min_replicas floor: the last replica never drains
    for _ in range(10):
        router.autoscale_tick()
    assert router.replicas_alive == 1
    router.stop()


def test_controller_stats_expose_fleet_health():
    """StepStats carries replicas_alive / failovers / lost_tokens when the
    controller drives a router-fronted fleet — including a crash mid-run."""
    engines, proxies, router = _faulty_fleet(n=2, slots=8)
    router.start()
    buf = SampleBuffer(batch_size=4, alpha=1)

    def prompts():
        i = 0
        while True:
            yield i, np.asarray([1, 2], np.int32)
            i += 1

    prod = RolloutProducer(router, buf, prompts(), group_size=1,
                           max_new_tokens=3, reward_fn=lambda s: 1.0)
    prod.start()
    ctrl = AsyncController(buf, proxies, lambda batch: {"loss": 0.0},
                           lambda: "w", alpha=1, router=router)
    try:
        stats = ctrl.train(2, timeout=60)
        proxies[1].kill()
        router.probe_health()
        stats = ctrl.train(1, timeout=60)
    finally:
        prod.stop()
        buf.close()
        router.stop()
    assert stats[0].replicas_alive == 2
    assert stats[-1].replicas_alive == 1
    assert all(len(s.active_per_replica) == s.replicas_alive for s in stats)


# ------------------------------------------------------- real paged fleet
@pytest.fixture(scope="module")
def paged_setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


@pytest.mark.timeout(240)
def test_paged_crash_failover_greedy_parity(paged_setup):
    """Acceptance on the REAL engine: kill one of two paged replicas
    mid-decode.  Every handle resolves with output byte-identical to an
    uninterrupted single-engine run (failover re-prefill preserves greedy
    semantics), and the survivor audits clean."""
    cfg, api, params = paged_setup
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 30, n).astype(np.int32) for n in (5, 7, 4, 9)]
    budget = 24

    ref_eng = PagedDecodeEngine(api, params, num_slots=4, max_total_len=64,
                                page_size=8, prefill_chunk=8, eos_id=99,
                                temperature=0.0)
    ref_proxy = LLMProxy(ref_eng).start()
    ref = [list(RolloutClient(ref_proxy).submit(_task(budget, p))
                .result(120).tokens) for p in prompts]
    ref_proxy.stop()

    engines = [PagedDecodeEngine(api, params, num_slots=2, max_total_len=64,
                                 page_size=8, prefill_chunk=8, eos_id=99,
                                 temperature=0.0) for _ in range(2)]
    proxies = wrap_fleet([LLMProxy(e, name=f"paged_{i}")
                          for i, e in enumerate(engines)])
    router = ProxyRouter(proxies).start()
    client = RolloutClient(router)
    handles = [client.submit(_task(budget, p)) for p in prompts]
    fired = []
    for h in handles:
        h.add_done_callback(fired.append)
    deadline = time.monotonic() + 60
    while (min(e.total_tokens_decoded for e in engines) < 3
           and time.monotonic() < deadline):
        time.sleep(0.01)
    proxies[0].kill()
    router.probe_health()
    out = [list(h.result(120).tokens) for h in handles]
    time.sleep(0.1)
    router.stop()
    assert out == ref, "failover must preserve greedy outputs"
    assert len(fired) == len(handles), "every handle resolved exactly once"
    assert router.failovers >= 1 and router.replicas_alive == 1
    router.fleet_audit()


# ------------------------------------------------- SLO x fault interaction
def test_preempted_then_killed_resolves_exactly_once():
    """Preemption composing with crash failover: a low-priority request is
    preempted (pages parked on its home replica), then the replica is
    killed before the resume completes.  Every handle — the preempted one,
    the preemptor, and bystanders — still resolves exactly once with its
    full budget; the fleet audits clean."""
    slo = SLOConfig()
    engines = [FakeEngine(slots=1, step_sleep=0.002) for _ in range(2)]
    proxies = wrap_fleet([LLMProxy(e, name=f"p{i}", slo=slo)
                          for i, e in enumerate(engines)])
    router = ProxyRouter(proxies)
    router.start()
    client = RolloutClient(router)
    # least-loaded placement: low0 -> p0, low1 -> p1, high -> p0
    h_low0 = client.submit(_ptask(20, priority=PRIORITY_LOW))
    h_low1 = client.submit(_ptask(30, priority=PRIORITY_LOW))
    _wait_for(lambda: engines[0].active and engines[1].active)
    h_high = client.submit(_ptask(2, priority=PRIORITY_HIGH))
    _wait_for(lambda: proxies[0].preemptions == 1)
    proxies[0].kill()
    router.probe_health()
    fired = []
    for h in (h_low0, h_low1, h_high):
        h.add_done_callback(fired.append)
    for h in (h_low0, h_low1, h_high):
        res = h.result(60)
        assert not res.aborted, "chaos must never surface an aborted handle"
        assert sum(n for _, n in res.legs) == h.task.max_new_tokens
    time.sleep(0.1)
    router.stop()
    assert len(fired) == 3, "exactly-once, zero duplicates"
    # >= 1: the failed-over high-priority request may legitimately preempt
    # the survivor's low-priority decode too
    assert router.preemptions >= 1, "counters survive the crash"
    router.fleet_audit()


def test_stalled_replica_detected_and_failed_over():
    """A hung replica still answers healthy(); only the router's
    steps-frozen probe (slo.replica_stall_s) catches it.  Its in-flight
    work fails over to the survivor like a crash."""
    slo = SLOConfig(replica_stall_s=0.2)
    engines = [FakeEngine(slots=2, step_sleep=0.002) for _ in range(2)]
    proxies = wrap_fleet([LLMProxy(e, name=f"p{i}")
                          for i, e in enumerate(engines)])
    router = ProxyRouter(proxies, slo=slo)
    router.start()
    router.start_health_monitor(0.02)
    client = RolloutClient(router)
    handles = [client.submit(_task(40, prompt=[1, 2])) for _ in range(2)]
    _wait_for(lambda: engines[0].active and engines[1].active)
    proxies[0].stall()
    assert proxies[0].healthy(), "a hung replica still answers healthy()"
    _wait_for(lambda: router.replica_state(0) == "dead", timeout=15)
    for h in handles:
        res = h.result(60)
        assert not res.aborted and sum(n for _, n in res.legs) == 40
    time.sleep(0.1)
    router.stop()        # unblocks the stalled loop; no late delivery
    assert proxies[0].stalls == 1
    assert router.replicas_alive == 1
    router.fleet_audit()


def test_background_threads_joined_on_shutdown():
    """Regression (thread-leak fix): health monitor, FaultyProxy
    self-destruct watchdogs, and the FaultInjector are all joined by their
    owners' stop() — a full start/stop cycle leaves no new live thread."""
    before = set(threading.enumerate())
    engines = [FakeEngine(slots=2, step_sleep=0.002) for _ in range(2)]
    # arm a never-firing self-destruct so each watchdog thread exists
    proxies = wrap_fleet([LLMProxy(e, name=f"p{i}")
                          for i, e in enumerate(engines)],
                         kill_after_steps=10 ** 9)
    router = ProxyRouter(proxies)
    router.start()
    router.start_health_monitor(0.01)
    injector = FaultInjector(proxies, seed=0, max_kills=1, min_alive=2,
                             on_kill=lambda i: router.probe_health())
    injector.start()
    client = RolloutClient(router)
    assert client.submit(_task(5, prompt=[1, 2])).result(30).tokens is not None
    injector.stop()                      # sets halt AND joins
    assert not injector.is_alive()
    router.stop()                        # joins monitor + proxy watchdogs
    deadline = time.monotonic() + 10
    leaked = [t for t in threading.enumerate()
              if t not in before and t.is_alive()]
    while leaked and time.monotonic() < deadline:
        time.sleep(0.01)
        leaked = [t for t in threading.enumerate()
                  if t not in before and t.is_alive()]
    assert not leaked, f"threads leaked past shutdown: {leaked}"


# ------------------------------------------------------------ chaos sweeps
@pytest.mark.faults
def test_chaos_sweep_fake_fleet_seeded():
    """Seeded chaos over a 4-replica fleet: the injector kills up to 2
    random replicas while 32 requests run.  Invariants (never timing):
    every handle resolves exactly once with its full budget, no duplicate
    resolutions, survivors audit clean, counters consistent."""
    engines, proxies, router = _faulty_fleet(n=4, slots=4, step_sleep=0.002)
    router.start()
    client = RolloutClient(router)
    injector = FaultInjector(proxies, seed=1234, min_delay=0.01,
                             max_delay=0.06, max_kills=2, min_alive=2,
                             on_kill=lambda i: router.probe_health())
    injector.start()
    rng = np.random.default_rng(5)
    handles = []
    resolved = []
    for _ in range(32):
        n = int(rng.integers(8, 40))
        h = client.submit(_task(n, prompt=[1] * int(rng.integers(2, 6))))
        h.add_done_callback(resolved.append)
        handles.append(h)
        time.sleep(0.002)
    for h in handles:
        res = h.result(60)
        assert not res.aborted, "chaos must never surface an aborted handle"
        assert len(res.tokens) == h.task.max_new_tokens
        assert sum(n for _, n in res.legs) == len(res.tokens)
    injector.stop()
    injector.join(timeout=5)
    time.sleep(0.1)
    router.stop()
    assert len(resolved) == len(handles), "exactly-once, zero duplicates"
    assert router.replicas_alive == 4 - len(injector.killed)
    assert router.failovers >= 0 and router.replicas_failed == len(injector.killed)
    router.fleet_audit()


@pytest.mark.faults
@pytest.mark.timeout(300)
def test_chaos_sweep_with_weight_syncs_and_aborts():
    """Chaos + the full control plane: staged fleet syncs, stale aborts
    with retain, and a mid-sweep add_replica, while the injector kills a
    replica.  All handles resolve, stitched budgets add up, audit clean."""
    engines, proxies, router = _faulty_fleet(n=3, slots=3, step_sleep=0.002)
    router.start()
    versions = [0]
    client = RolloutClient(router, version_fn=lambda: versions[0])
    injector = FaultInjector(proxies, seed=99, min_delay=0.02,
                             max_delay=0.08, max_kills=1, min_alive=2,
                             on_kill=lambda i: router.probe_health())
    injector.start()
    rng = np.random.default_rng(7)
    handles = []
    for wave in range(4):
        for _ in range(6):
            h = client.submit(_task(int(rng.integers(6, 24)),
                                    prompt=[1] * int(rng.integers(2, 5))),
                              version=versions[0])
            handles.append(h)
        time.sleep(0.03)
        ev = router.update_weights_async(f"w{wave}")
        assert ev.wait(30), "fleet sync completes even with a dead replica"
        versions[0] += 1
        router.abort_stale(min_version=versions[0], retain=True)
        if wave == 2:
            router.add_replica(FaultyProxy(
                LLMProxy(FakeEngine(slots=3, step_sleep=0.002), name="p_new")))
    for h in handles:
        res = h.result(60)
        assert not res.aborted
        assert sum(n for _, n in res.legs) == len(res.tokens)
    injector.stop()
    injector.join(timeout=5)
    time.sleep(0.15)
    router.stop()
    assert router.replicas_added == 1
    router.fleet_audit()


@pytest.mark.faults
@pytest.mark.timeout(300)
def test_chaos_sweep_hang_modes():
    """Chaos beyond crashes: the injector fires kill, stall, AND slow
    faults while 24 requests run.  Stalls are invisible to healthy() — the
    router's steps-frozen probe must rescue their work; slowdowns must
    never break correctness.  Invariants: every handle resolves exactly
    once with its full budget, survivors audit clean."""
    slo = SLOConfig(replica_stall_s=0.3)
    engines = [FakeEngine(slots=4, step_sleep=0.002) for _ in range(4)]
    proxies = wrap_fleet([LLMProxy(e, name=f"p{i}")
                          for i, e in enumerate(engines)])
    router = ProxyRouter(proxies, slo=slo)
    router.start()
    router.start_health_monitor(0.02)
    client = RolloutClient(router)
    injector = FaultInjector(proxies, seed=31337, min_delay=0.01,
                             max_delay=0.05, max_kills=3, min_alive=2,
                             modes=("kill", "stall", "slow"),
                             on_kill=lambda i: router.probe_health())
    injector.start()
    rng = np.random.default_rng(13)
    handles, resolved = [], []
    for _ in range(24):
        h = client.submit(_task(int(rng.integers(8, 32)),
                                prompt=[1] * int(rng.integers(2, 6))))
        h.add_done_callback(resolved.append)
        handles.append(h)
        time.sleep(0.003)
    for h in handles:
        res = h.result(90)
        assert not res.aborted, "chaos must never surface an aborted handle"
        assert len(res.tokens) == h.task.max_new_tokens
        assert sum(n for _, n in res.legs) == len(res.tokens)
    injector.stop()
    assert not injector.is_alive()
    time.sleep(0.15)
    router.stop()
    assert len(resolved) == len(handles), "exactly-once, zero duplicates"
    fired = (len(injector.killed) + len(injector.stalled)
             + len(injector.slowed))
    assert fired <= 3
    router.fleet_audit()
