"""Checkpoint round-trips (bf16 + fp32 + int trees)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny
from repro.checkpoint import latest_checkpoint, load_tree, save_checkpoint
from repro.models import get_api
from repro.train.trainer import make_train_state


def test_roundtrip_train_state(tmp_path, rng_key):
    cfg = tiny("qwen3-4b")
    api = get_api(cfg)
    state = make_train_state(api, rng_key)
    path = save_checkpoint(str(tmp_path), 7, state, arch=cfg.arch_id)
    assert latest_checkpoint(str(tmp_path)) == path

    like = jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, a.dtype), state)
    restored = load_tree(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored), strict=True):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_checkpoint_ordering(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(3)})
    save_checkpoint(str(tmp_path), 12, {"x": jnp.ones(3)})
    save_checkpoint(str(tmp_path), 3, {"x": jnp.ones(3)})
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000012")
