"""COW prefix sharing for GRPO groups (submit_group + PagePool).

Load-bearing guarantees:

* ``submit_group(G)`` is token-for-token (and logprob-bit) identical to G
  independent submits under greedy decoding — sharing is an optimization,
  never a semantic change;
* the prompt is prefilled exactly once per group;
* refcounted pages survive any mix of finish / abort / retain / resume
  across the group (``audit_pages`` after every transition);
* aborting the not-yet-forked leader promotes a follower with zero
  repeated prefill.
"""
import threading
import time

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.llm_proxy import LLMProxy
from repro.core.scheduler import collect_rollout
from repro.models import get_api
from repro.models.paged import PagePool
from repro.rollout.engine import DecodeEngine
from repro.rollout.paged_engine import PagedDecodeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _drain(eng, want, max_steps=800):
    results = {}
    for _ in range(max_steps):
        for rid, toks, lps in eng.step():
            results[rid] = (list(toks), list(lps))
        if len(results) >= want:
            return results
    raise AssertionError(f"engine stalled: {len(results)}/{want} finished")


def _engine(api, params, **kw):
    base = dict(num_slots=4, max_total_len=64, page_size=8, prefill_chunk=8,
                eos_id=99, temperature=0.0)
    base.update(kw)
    return PagedDecodeEngine(api, params, **base)


# --------------------------------------------------------------- page pool
def test_page_pool_refcounts():
    pool = PagePool(6, page_size=4)
    a = pool.alloc(3)
    assert pool.pages_free == 2 and pool.pages_private == 3
    pool.share(a[:2])
    assert pool.pages_shared == 2 and pool.pages_private == 1
    pool.release(a[:2])               # drop the second refs
    assert pool.pages_shared == 0 and pool.pages_private == 3
    pool.release(a)
    assert pool.pages_free == 5 and pool.pages_in_use == 0
    with pytest.raises(AssertionError, match="double release"):
        pool.release([a[0]])


def test_page_pool_fork_prefix_boundary():
    pool = PagePool(10, page_size=4)
    pages = pool.alloc(4)
    shared, tail = pool.fork_prefix(pages, 8)     # aligned: 2 full, no tail
    assert shared == pages[:2] and tail is None
    shared2, tail2 = pool.fork_prefix(pages, 9)   # partial: tail = page idx 2
    assert shared2 == pages[:2] and tail2 == pages[2]
    assert all(pool.refcount(p) == 3 for p in pages[:2])
    assert pool.peak_pages_in_use == 4


# ------------------------------------------------------- greedy parity
@pytest.mark.parametrize("plen", [8, 11])  # page-aligned and partial tail
def test_group_parity_with_independent(setup, plen):
    cfg, api, params = setup
    rng = np.random.default_rng(plen)
    prompt = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
    g, budget = 3, 7

    eng = _engine(api, params)
    for rid in range(g):
        eng.add_request(rid, prompt, budget)
    indep = _drain(eng, g)
    prefill_independent = eng.total_prefill_tokens

    eng = _engine(api, params)
    eng.submit_group(list(range(g)), prompt, budget)
    grouped = _drain(eng, g)
    assert eng.total_prefill_tokens == plen, "prompt must prefill exactly once"
    assert prefill_independent == g * plen
    eng.audit_pages()
    assert eng.pages_free == eng.num_pages - 1, "leaked pages after finish"
    for rid in range(g):
        assert grouped[rid][0] == indep[rid][0], f"lane {rid} diverged"
        np.testing.assert_array_equal(
            np.asarray(grouped[rid][1], np.float32),
            np.asarray(indep[rid][1], np.float32))


def test_fork_shares_prefix_pages(setup):
    """After the fork the fully-filled prompt pages are aliased (refcount G)
    and only tail+decode pages are per-lane."""
    cfg, api, params = setup
    prompt = np.arange(1, 18, dtype=np.int32)      # 17 tokens: 2 full pages + tail
    eng = _engine(api, params)
    eng.submit_group([0, 1, 2], prompt, 6)
    while eng.total_groups_forked == 0:
        eng.step()
    assert eng.pages_shared == 2                    # the full prompt pages
    leader_row = eng._slot_pages[eng.req_to_slot[0]]
    for rid in (1, 2):
        row = eng._slot_pages[eng.req_to_slot[rid]]
        assert row[:2] == leader_row[:2], "followers must alias prefix pages"
        assert row[2] != leader_row[2], "tail page must be private"
    eng.audit_pages()
    _drain(eng, 3)
    eng.audit_pages()
    assert eng.pages_free == eng.num_pages - 1 and eng.pages_shared == 0


def test_forked_lane_abort_resume_while_siblings_decode(setup):
    """Abort one forked lane mid-decode with retained pages; siblings keep
    decoding; the resumed lane is byte-identical to the uninterrupted run."""
    cfg, api, params = setup
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5], np.int32)
    g, budget = 3, 10

    eng = _engine(api, params)
    eng.submit_group([0, 1, 2], prompt, budget)
    base = _drain(eng, g)

    eng = _engine(api, params)
    eng.submit_group([0, 1, 2], prompt, budget)
    for _ in range(5):
        eng.step()
    partial = eng.abort(1, retain=True)
    assert partial.resumable and len(partial.tokens) > 0
    eng.audit_pages()
    assert eng.pages_shared > 0, "retained lane must keep its shared refs"
    # siblings run to completion while lane 1 is parked
    rest = _drain(eng, 2)
    eng.audit_pages()
    for rid in (0, 2):
        assert rest[rid][0] == base[rid][0]
    prefill_before = eng.total_prefill_tokens
    eng.resume_request(1, 11, budget - len(partial.tokens))
    got = _drain(eng, 1)[11]
    assert eng.total_prefill_tokens == prefill_before, \
        "resume must re-attach pages, not re-prefill"
    assert list(partial.tokens) + got[0] == base[1][0]
    np.testing.assert_array_equal(
        np.asarray(list(partial.logprobs) + got[1], np.float32),
        np.asarray(base[1][1], np.float32))
    eng.audit_pages()
    assert eng.pages_free == eng.num_pages - 1 and not eng.retained


def test_pre_fork_leader_abort_promotes_follower(setup):
    """Aborting the group's prefill leader before the fork hands its pages
    (prefilled content intact) to a follower — no prompt work repeats, and
    retain degrades to a plain abort (nothing decoded yet)."""
    cfg, api, params = setup
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5], np.int32)
    g, budget = 3, 10

    eng = _engine(api, params)
    eng.submit_group([0, 1, 2], prompt, budget)
    base = _drain(eng, g)

    eng = _engine(api, params, prefill_chunk=4)
    eng.submit_group([0, 1, 2], prompt, budget)
    eng.step()                                     # one 4-token chunk in
    r = eng.abort(0, retain=True)
    assert not r.resumable and len(r.tokens) == 0
    eng.audit_pages()
    rest = _drain(eng, 2)
    assert eng.total_prefill_tokens == len(prompt), "prefill must not restart"
    for rid in (1, 2):
        assert rest[rid][0] == base[rid][0]
    eng.audit_pages()
    assert eng.pages_free == eng.num_pages - 1


def test_pre_fork_follower_abort_releases_reserved_pages(setup):
    cfg, api, params = setup
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5], np.int32)
    eng = _engine(api, params, prefill_chunk=4)
    eng.submit_group([0, 1, 2], prompt, 10)
    eng.step()
    free_before = eng.pages_free
    r = eng.abort(2, retain=True)
    assert not r.resumable
    assert eng.pages_free > free_before
    eng.audit_pages()
    rest = _drain(eng, 2)
    assert sorted(rest) == [0, 1]
    eng.audit_pages()
    assert eng.pages_free == eng.num_pages - 1


def test_group_admission_gating(setup):
    """can_admit_group accounts for sharing: a group fits where independent
    lanes would not."""
    cfg, api, params = setup
    # 16-token prompt (2 full pages) + 8 budget -> 3 pages/lane independent
    # (4 lanes = 12 pages, over the 7-page pool), but grouped COW needs only
    # 2 shared + 4x1 private = 6.
    eng = _engine(api, params, num_slots=4, max_total_len=32, num_pages=8)
    assert 4 * eng._pages_needed(16 + 8) > eng.pages_free
    assert eng.can_admit_group(16, 4, 8)
    eng.submit_group([0, 1, 2, 3], np.arange(1, 17, dtype=np.int32), 8)
    _drain(eng, 4)
    eng.audit_pages()
    assert eng.pages_free == eng.num_pages - 1


# ------------------------------------------------------------ proxy path
def test_proxy_group_submit_degrades_on_slot_engine(setup):
    """generate_group works against engines without supports_group: the
    proxy expands the group into independent requests."""
    cfg, api, params = setup
    eng = DecodeEngine(api, params, num_slots=2, max_total_len=32,
                       eos_id=99, temperature=0.0)
    proxy = LLMProxy(eng).start()
    results = []
    lock = threading.Lock()

    def cb(r):
        with lock:
            results.append(r)

    from repro.core.scheduler import expand_tasks
    tasks = expand_tasks(0, np.asarray([1, 2, 3], np.int32), 3, 5,
                         replicate=True)
    proxy.generate_group(tasks, version=0, callback=cb)
    deadline = time.monotonic() + 30
    while len(results) < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    proxy.stop()
    assert len(results) == 3
    assert all(not r.aborted and len(r.tokens) == 5 for r in results)


def test_collect_rollout_group_submission_paged(setup):
    """collect_rollout emits group submissions: one prefill per prompt,
    complete groups collected."""
    cfg, api, params = setup
    eng = _engine(api, params, num_slots=8, max_total_len=32)
    proxy = LLMProxy(eng).start()
    rng = np.random.default_rng(3)
    import itertools

    def prompts():
        for pid in itertools.count():
            yield pid, rng.integers(1, 30, 6).astype(np.int32)

    out = collect_rollout(proxy, prompts(), num_groups=2, group_size=4,
                          max_new_tokens=5,
                          reward_fn=lambda s: float(s.response_tokens[0] % 2),
                          timeout=120)
    proxy.stop()
    assert len(out) == 8
    assert eng.total_groups_forked >= 2
    assert eng.total_prefill_tokens == 6 * (eng.total_groups_forked)
    eng.audit_pages()


def test_never_fitting_group_expands_to_singles(setup):
    """A group whose COW page plan exceeds the WHOLE pool must not block the
    queue forever: the proxy expands it into singles that fit one at a time."""
    cfg, api, params = setup
    # 16-token prompt, page 8: full=2, priv=1 -> group of 4 needs 6 pages,
    # but the pool only has 5 usable; each single (3 pages) fits alone.
    eng = _engine(api, params, num_slots=4, max_total_len=32, num_pages=6)
    assert not eng.group_fits_pool(16, 4, 8)
    proxy = LLMProxy(eng).start()
    results = []
    lock = threading.Lock()

    def cb(r):
        with lock:
            results.append(r)

    from repro.core.scheduler import expand_tasks
    tasks = expand_tasks(0, np.arange(1, 17, dtype=np.int32), 4, 8,
                         replicate=True)
    proxy.generate_group(tasks, version=0, callback=cb)
    deadline = time.monotonic() + 60
    while len(results) < 4 and time.monotonic() < deadline:
        time.sleep(0.01)
    proxy.stop()
    assert len(results) == 4
    assert all(not r.aborted for r in results)
    eng.audit_pages()


def test_producer_groups_stay_prompt_aligned_after_partial_flush():
    """A capacity pinch mid-group must not de-align grouping for the rest of
    the run: the boundary-crossing pull is held back to seed the next group."""
    from repro.core.sample_buffer import SampleBuffer
    from repro.core.scheduler import RolloutProducer

    class RecordingProxy:
        def __init__(self):
            self.groups, self.singles = [], []

        def generate_group(self, tasks, version, cb):
            self.groups.append([t.prompt_id for t in tasks])

        def generate(self, task, version, cb):
            self.singles.append(task.prompt_id)

    p = np.asarray([1, 2], np.int32)
    stream = iter([(0, p)] * 4 + [(1, p)] * 4)
    buf = SampleBuffer(batch_size=3, alpha=0)      # capacity 3 < group_size
    proxy = RecordingProxy()
    prod = RolloutProducer(proxy, buf, stream, group_size=4, max_new_tokens=4,
                           reward_fn=lambda s: 1.0)
    prod._produce_group()                           # pinch: 3 of 4 A-replicas
    assert proxy.groups == [[0, 0, 0]]
    buf.reclaim(3)
    prod._produce_group()   # last A, then B crosses the boundary -> held
    assert proxy.singles == [0] and prod._groups.held is not None
    buf.reclaim(1)
    prod._produce_group()                           # held B seeds the group
    assert proxy.groups[-1] == [1, 1, 1]
    assert all(len(set(g)) == 1 for g in proxy.groups), \
        "every group must be single-prompt"


@pytest.mark.kernels
def test_group_fork_with_pallas_kernel_matches_ref(setup):
    """Forked lanes read shared pages through the unchanged Pallas paged
    decode-attention kernel (interpret mode): greedy outputs match ref."""
    cfg, api, params = setup
    prompt = np.asarray([1, 5, 7, 9, 2], np.int32)
    outs = {}
    for impl in ("ref", "kernel_interpret"):
        eng = _engine(api, params, num_slots=2, max_total_len=32,
                      attn_impl=impl)
        eng.submit_group([0, 1], prompt, 4)
        outs[impl] = {rid: t for rid, (t, _) in _drain(eng, 2).items()}
        eng.audit_pages()
    assert outs["ref"] == outs["kernel_interpret"]


# ----------------------------------------------------------- slow sweeps
@pytest.mark.slow
@pytest.mark.timeout(600)
@pytest.mark.parametrize("g", [2, 4, 8])
def test_group_parity_sweep(setup, g):
    """Greedy parity across group sizes and prompt lengths crossing page
    boundaries, with stochastic admission order."""
    cfg, api, params = setup
    rng = np.random.default_rng(g)
    for plen in (5, 8, 13, 24):
        prompt = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        eng = _engine(api, params, num_slots=g, max_total_len=64)
        for rid in range(g):
            eng.add_request(rid, prompt, 6)
        indep = _drain(eng, g)
        eng = _engine(api, params, num_slots=g, max_total_len=64)
        eng.submit_group(list(range(g)), prompt, 6)
        grouped = _drain(eng, g)
        assert eng.total_prefill_tokens == plen
        eng.audit_pages()
        for rid in range(g):
            assert grouped[rid][0] == indep[rid][0], (g, plen, rid)


@pytest.mark.slow
@pytest.mark.timeout(600)
def test_bench_prefix_sharing_ratios(setup):
    """The ISSUE acceptance ratios at G=8, reduced workload: grouped COW
    computes >= 4x fewer prefill tokens and holds >= 2x fewer peak pages
    than independent submission, byte-identical greedy outputs."""
    cfg, api, params = setup
    g, budget = 8, 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (32, 41, 48)]

    def run(grouped):
        eng = _engine(api, params, num_slots=g * len(prompts),
                      max_total_len=64)
        rid = 0
        for p in prompts:
            rids = list(range(rid, rid + g))
            rid += g
            if grouped:
                eng.submit_group(rids, p, budget)
            else:
                for r in rids:
                    eng.add_request(r, p, budget)
        outs = _drain(eng, g * len(prompts))
        eng.audit_pages()
        return eng.total_prefill_tokens, eng.peak_pages_in_use, outs

    pre_i, peak_i, outs_i = run(False)
    pre_g, peak_g, outs_g = run(True)
    assert pre_i >= 4 * pre_g, (pre_i, pre_g)
    assert peak_i >= 2 * peak_g, (peak_i, peak_g)
    assert all(outs_i[r][0] == outs_g[r][0] for r in outs_i)
