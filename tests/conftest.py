import os
import sys

# tests must see ONE device (the dry-run sets its own flags in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # real hypothesis (installed by the [test] extra in CI)
    import hypothesis  # noqa: F401
except ImportError:  # bare env: degrade @given to a deterministic sweep
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    _hypothesis_fallback.install(sys.modules)

import dataclasses  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.configs import REGISTRY  # noqa: E402


def tiny(arch: str, **overrides):
    """Extra-small variant of an assigned arch for fast CPU tests."""
    cfg = REGISTRY[arch].smoke()
    base = dict(num_layers=2, d_model=64, num_heads=4, head_dim=16,
                num_kv_heads=2, d_ff=128, vocab_size=64)
    if cfg.family == "ssm":
        base.update(num_heads=2, num_kv_heads=2, rwkv_head_size=32)
    if cfg.family == "hybrid":
        base.update(num_layers=3, lru_width=64, sliding_window=16,
                    num_kv_heads=1)
    if cfg.is_moe:
        base.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64)
    if cfg.family == "vlm":
        base.update(num_image_tokens=8, num_kv_heads=1)
    if cfg.family == "audio":
        base.update(num_encoder_layers=2, encoder_frames=16,
                    num_kv_heads=4)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
