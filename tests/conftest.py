import os
import sys

# tests must see ONE device (the dry-run sets its own flags in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # real hypothesis (installed by the [test] extra in CI)
    import hypothesis  # noqa: F401
except ImportError:  # bare env: degrade @given to a deterministic sweep
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    _hypothesis_fallback.install(sys.modules)

import dataclasses  # noqa: E402
import threading  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.configs import REGISTRY  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="run under the runtime lock sanitizer (tracked locks, dynamic "
             "lock-order graph, per-test inversion check); equivalent to "
             "REPRO_SANITIZE=1")


def pytest_configure(config):
    sanitize = (config.getoption("--sanitize")
                or os.environ.get("REPRO_SANITIZE", "") not in ("", "0"))
    if sanitize:
        from repro.analysis import sanitizer
        sanitizer.enable(True)
    config._repro_sanitize = sanitize


@pytest.fixture(autouse=True)
def _zero_lock_inversions(request):
    """Sanitizer mode: every test must leave the dynamic lock-order graph
    free of NEW inversions.  The graph itself accumulates across the whole
    session, so an order conflict between two different tests is caught
    too — whichever test closes the cycle fails."""
    if not request.config._repro_sanitize:
        yield
        return
    from repro.analysis import sanitizer
    before = len(sanitizer.report()["inversions"])
    yield
    new = sanitizer.report()["inversions"][before:]
    assert not new, (
        f"lock-order inversions during {request.node.nodeid}: {new}")


# Worker threads a test starts must be stopped/joined before it returns —
# a leaked thread keeps mutating shared state under LATER tests, turning
# their failures into unreproducible noise.  Grace window covers stop()
# paths that signal first and join asynchronously.
_THREAD_SETTLE_S = 2.0


@pytest.fixture(autouse=True)
def _no_leaked_threads(request):
    if request.node.get_closest_marker("thread_leaks_ok"):
        yield
        return
    before = {t.ident for t in threading.enumerate()}
    yield

    def leaked():
        return [t for t in threading.enumerate()
                if t.is_alive() and t.ident not in before
                and t is not threading.current_thread()]
    deadline = time.monotonic() + _THREAD_SETTLE_S
    remain = leaked()
    while remain and time.monotonic() < deadline:
        time.sleep(0.02)
        remain = leaked()
    assert not remain, (
        f"test leaked alive threads: {sorted(t.name for t in remain)} — "
        "stop/join them, or mark the test @pytest.mark.thread_leaks_ok "
        "(deliberately stalled workers only)")


def tiny(arch: str, **overrides):
    """Extra-small variant of an assigned arch for fast CPU tests."""
    cfg = REGISTRY[arch].smoke()
    base = dict(num_layers=2, d_model=64, num_heads=4, head_dim=16,
                num_kv_heads=2, d_ff=128, vocab_size=64)
    if cfg.family == "ssm":
        base.update(num_heads=2, num_kv_heads=2, rwkv_head_size=32)
    if cfg.family == "hybrid":
        base.update(num_layers=3, lru_width=64, sliding_window=16,
                    num_kv_heads=1)
    if cfg.is_moe:
        base.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64)
    if cfg.family == "vlm":
        base.update(num_image_tokens=8, num_kv_heads=1)
    if cfg.family == "audio":
        base.update(num_encoder_layers=2, encoder_frames=16,
                    num_kv_heads=4)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
