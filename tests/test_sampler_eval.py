"""Sampler (temperature / top-k / top-p) + Pass@k evaluation harness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.eval import evaluate_passk, pass_at_k_estimator
from repro.models import get_api
from repro.rollout.sampler import sample_tokens

KEY = jax.random.PRNGKey(0)


def test_greedy_is_argmax():
    logits = jax.random.normal(KEY, (4, 16))
    toks, lp = sample_tokens(KEY, logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))
    assert float(lp.max()) <= 0.0


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]])
    hits = set()
    for i in range(64):
        t, _ = sample_tokens(jax.random.fold_in(KEY, i), logits, top_k=2)
        hits.add(int(t[0]))
    assert hits <= {2, 3}


def test_top_p_restricts_support():
    # p(3)=0.64, p(2)=0.24 -> top_p=0.7 keeps exactly {3, 2}
    logits = jnp.log(jnp.asarray([[0.04, 0.08, 0.24, 0.64]]))
    hits = set()
    for i in range(128):
        t, _ = sample_tokens(jax.random.fold_in(KEY, i), logits, top_p=0.7)
        hits.add(int(t[0]))
    assert hits == {2, 3}


def test_top_p_one_is_full_distribution():
    logits = jax.random.normal(KEY, (2, 8))
    t1, lp1 = sample_tokens(KEY, logits, top_p=1.0)
    t2, lp2 = sample_tokens(KEY, logits)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(lp2), rtol=1e-6)


@pytest.mark.parametrize("n,c,k,expected", [
    (8, 0, 4, 0.0), (8, 8, 1, 1.0), (2, 1, 1, 0.5), (4, 2, 2, 5.0 / 6.0),
])
def test_pass_at_k_estimator(n, c, k, expected):
    assert pass_at_k_estimator(n, c, k) == pytest.approx(expected)


def test_evaluate_passk_monotone_in_k():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(KEY)
    res = evaluate_passk(api, params, num_prompts=6, n_per_prompt=4,
                         ks=(1, 2, 4), max_new_tokens=4)
    vals = [res.pass_at_k[k] for k in (1, 2, 4)]
    assert vals == sorted(vals)
    assert res.pass_at_1 == pytest.approx(res.pass_at_k[1])
