"""Self-tests for the concurrency toolkit: each static rule must flag its
known-bad fixture (and pass the corrected twin), the runtime sanitizer must
detect seeded inversions, and the schedule perturber must reproduce the
historical SampleBuffer version race against a deliberately buggy copy."""
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.analysis.schedules import SchedulePerturber
from repro.analysis.sanitizer import REGISTRY, TrackedLock, TrackedRLock
from repro.analysis.static_check import check_paths, check_source
from repro.core.sample_buffer import SampleBuffer, StaleSampleError
from repro.core.types import Sample


def _rules(src):
    res = check_source(textwrap.dedent(src), "fixture.py")
    return [v.rule for v in res.violations]


# ---------------------------------------------------------------------------
# static rules: one failing fixture + one clean twin per rule
# ---------------------------------------------------------------------------

def test_guarded_by_flags_unlocked_access():
    bad = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self._count = 0  # guarded-by: _lock

        def bump(self):
            self._count += 1
    """
    assert "guarded-by" in _rules(bad)


def test_guarded_by_accepts_locked_access_and_holds_marker():
    good = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self._count = 0  # guarded-by: _lock

        def bump(self):
            with self._lock:
                self._count += 1

        def _bump_locked(self):  # holds: _lock
            self._count += 1
    """
    assert _rules(good) == []


def test_guarded_by_waiver_suppresses():
    waived = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self._count = 0  # guarded-by: _lock

        def peek(self):
            # racy-read tolerated: monitoring only
            # concheck: disable=guarded-by
            return self._count
    """
    assert _rules(waived) == []


def test_lock_order_cycle_detected():
    bad = """
    import threading

    class A:
        def __init__(self):
            self._x = threading.Lock()

    class B:
        def __init__(self):
            self._y = threading.Lock()

    # lock-order: A._x -> B._y
    # lock-order: B._y -> A._x
    """
    assert "lock-order" in _rules(bad)


def test_lock_order_nested_with_builds_edges():
    src = """
    import threading

    class C:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

        def both(self):
            with self._a:
                with self._b:
                    pass
    """
    res = check_source(textwrap.dedent(src), "fixture.py")
    assert res.violations == []
    edges = {(e["from"], e["to"]) for e in res.graph["edges"]}
    assert ("C._a", "C._b") in edges


def test_blocking_call_under_lock_flagged():
    bad = """
    import threading
    import time

    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def slow(self):
            with self._lock:
                time.sleep(1.0)
    """
    assert "blocking-under-lock" in _rules(bad)


def test_cond_wait_without_predicate_loop_flagged():
    bad = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self._cond = threading.Condition(self._lock)

        def wait_once(self):
            with self._cond:
                self._cond.wait()
    """
    assert "cond-wait-loop" in _rules(bad)
    good = bad.replace(
        "self._cond.wait()",
        "while not self.ready():\n                    self._cond.wait()")
    assert "cond-wait-loop" not in _rules(good)


def test_thread_started_without_join_flagged():
    bad = """
    import threading

    class C:
        def start(self):
            self._worker = threading.Thread(target=self._run)
            self._worker.start()
    """
    assert "thread-join" in _rules(bad)
    good = bad + """
        def stop(self):
            self._worker.join(timeout=5)
    """
    assert "thread-join" not in _rules(good)


def test_busy_wait_poll_loop_flagged():
    bad = """
    import time

    class C:
        def wait_done(self):
            while not self.done:
                time.sleep(0.001)
    """
    assert "busy-wait" in _rules(bad)


def test_busy_wait_timed_event_repoll_flagged():
    bad = """
    class C:
        def wait_done(self):
            while not self._stop.is_set():
                self._event.wait(timeout=0.01)
    """
    assert "busy-wait" in _rules(bad)


# ---------------------------------------------------------------------------
# the repo gate: the shipped tree must be clean
# ---------------------------------------------------------------------------

def test_repo_tree_passes_concheck():
    res = check_paths(["src/repro"])
    assert res.violations == [], \
        [f"{v.path}:{v.line} {v.rule}: {v.msg}" for v in res.violations]


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------

@pytest.fixture
def clean_registry():
    """Isolate deliberate violations from the session-wide inversion check
    (and from other tests): snapshot-reset around the test body."""
    REGISTRY.reset()
    saved_threshold = REGISTRY.hold_threshold_s
    yield REGISTRY
    REGISTRY.hold_threshold_s = saved_threshold
    sanitizer.install_perturber(None)
    REGISTRY.reset()


def test_sanitizer_records_edges_and_detects_inversion(clean_registry):
    a = TrackedLock("T.a")
    b = TrackedLock("T.b")
    with a:
        with b:
            pass
    assert sanitizer.report()["inversions"] == []

    def reversed_order():
        with b:
            with a:
                pass
    t = threading.Thread(target=reversed_order)
    t.start()
    t.join()
    inv = sanitizer.report()["inversions"]
    assert inv and inv[0]["held"] == "T.b" and inv[0]["acquiring"] == "T.a"
    with pytest.raises(AssertionError):
        sanitizer.assert_no_inversions("self-test")


def test_sanitizer_same_class_different_instance_is_inversion(clean_registry):
    a1 = TrackedLock("Replica._lock")
    a2 = TrackedLock("Replica._lock")
    with a1:
        with a2:
            pass
    assert sanitizer.report()["inversions"], \
        "nesting two instances of one lock class is a self-deadlock risk"


def test_sanitizer_reentrant_rlock_is_not_inversion(clean_registry):
    r = TrackedRLock("T.r")
    with r:
        with r:
            pass
    assert sanitizer.report()["inversions"] == []


def test_sanitizer_condition_wait_pops_held_stack(clean_registry):
    r = TrackedRLock("T.cond_lock")
    cond = threading.Condition(r)
    other = TrackedLock("T.other")

    def waiter():
        with cond:
            cond.wait(timeout=5)
            # post-wait nesting must record cond_lock -> other, and the
            # wait itself must have released the tracked entry (otherwise
            # the notifier's acquisition below would report an inversion).
            with other:
                pass

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cond:
        cond.notify_all()
    t.join()
    rep = sanitizer.report()
    assert rep["inversions"] == []
    assert "T.cond_lock -> T.other" in rep["edges"]


def test_sanitizer_long_hold_reported(clean_registry):
    clean_registry.hold_threshold_s = 0.01
    lock = TrackedLock("T.slowpoke")
    with lock:
        time.sleep(0.05)
    holds = sanitizer.report()["long_holds"]
    assert holds and holds[0]["lock"] == "T.slowpoke"
    assert sanitizer.report()["inversions"] == []  # report-only


def test_graph_json_shape(clean_registry):
    a = TrackedLock("G.a")
    b = TrackedLock("G.b")
    with a:
        with b:
            pass
    g = sanitizer.graph_json()
    assert g["source"] == "runtime"
    assert {"from": "G.a", "to": "G.b", "count": 1} in g["edges"]
    assert set(g["nodes"]) == {"G.a", "G.b"}


# ---------------------------------------------------------------------------
# schedule perturbation: reproduce the historical buffer version race
# ---------------------------------------------------------------------------

class _VersionedQueue:
    """Minimal twin of the SampleBuffer consume path.  ``buggy=True``
    re-creates the staleness race this repo fixed in its early history: the
    strict re-check reads ``self._version`` AFTER the consume critical
    section instead of capturing it inside, so a concurrent
    ``advance_version`` fails a batch that was admissible at the moment it
    was consumed.  ``buggy=False`` captures inside — the shipped fix."""

    def __init__(self, *, buggy):
        self.buggy = buggy
        self._lock = TrackedLock("BuggyQueue._lock")
        self._version = 0

    def advance_version(self):
        with self._lock:
            self._version += 1

    def consume_one(self):
        """Produce-and-consume one sample at the current version.  Both
        happen in ONE critical section, so the sample is admissible at
        consume time BY CONSTRUCTION — any staleness failure is spurious."""
        with self._lock:
            version_started = self._version
            version_at_consume = self._version
        if self.buggy:
            # BUG: second acquisition re-reads the version post-consume
            with self._lock:
                version_at_consume = self._version
        if version_at_consume - version_started > 0:   # alpha = 0
            raise StaleSampleError(
                f"v{version_started} checked at v{version_at_consume}")


def _race_sweep(*, buggy, seed, iters=100):
    """One adversarial schedule: a trainer thread advancing the version at
    full tilt against a consumer; returns True if a spurious staleness
    failure was observed."""
    sanitizer.install_perturber(SchedulePerturber(
        seed=seed, p_yield=1.0, max_sleep_s=0.003,
        only_locks={"BuggyQueue._lock"}))
    q = _VersionedQueue(buggy=buggy)
    stop = threading.Event()

    def trainer():
        while not stop.is_set():
            q.advance_version()
            time.sleep(0.0002)

    t = threading.Thread(target=trainer)
    t.start()
    raced = False
    try:
        for _ in range(iters):
            try:
                q.consume_one()
            except StaleSampleError:
                raced = True
                break
    finally:
        stop.set()
        t.join()
        sanitizer.install_perturber(None)
    return raced


def test_perturber_reproduces_version_race_on_buggy_queue(clean_registry):
    """Under seeded schedule perturbation the buggy twin's post-release
    version read races with advance_version and fails spuriously."""
    assert any(_race_sweep(buggy=True, seed=s) for s in (1234, 99, 7)), \
        ("perturbed schedule never hit the version race — widen the sweep "
         "before trusting the fuzzer")


def test_fixed_queue_immune_to_version_race(clean_registry):
    """The shipped fix (capture version_at_consume INSIDE the critical
    section): the same adversarial schedules can never fail spuriously."""
    for s in (1234, 99, 7):
        assert not _race_sweep(buggy=False, seed=s)


def _sample(sid, version):
    z = np.zeros((1,), np.int32)
    return Sample(sample_id=sid, prompt_id=0, replica_idx=0,
                  prompt_tokens=z, response_tokens=z,
                  logprobs=np.zeros((1,), np.float32),
                  version_started=version)


def test_sample_buffer_under_perturbation(clean_registry):
    """Race-fuzz the real SampleBuffer's producer/consumer condition
    machinery: two producer threads vs a consuming main thread, every lock
    acquisition perturbed.  Every sample is either consumed exactly once or
    evicted as stale by advance_version — none lost, none duplicated — with
    zero lock-order inversions."""
    was = sanitizer.enabled()
    sanitizer.enable(True)         # buffer's factory locks become tracked
    try:
        buf = SampleBuffer(batch_size=2, alpha=1.0, strict=False)
    finally:
        sanitizer.enable(was)
    sanitizer.install_perturber(SchedulePerturber(
        seed=42, p_yield=0.5, max_sleep_s=0.001))
    per_producer = 10
    total = 2 * per_producer

    def producer(base):
        for k in range(per_producer):
            v = buf.begin_generation(timeout=10)
            assert v is not None
            buf.put(_sample(base + k, v))

    threads = [threading.Thread(target=producer, args=(b,))
               for b in (0, 1000)]
    for t in threads:
        t.start()
    got = []
    deadline = time.monotonic() + 30
    try:
        # advance_version evicts completed samples that staled past alpha, so
        # the exit condition is consumed + evicted == total, not consumed ==
        # total; single-sample gets avoid stranding an odd remainder.
        while len(got) + buf.total_evicted < total:
            assert time.monotonic() < deadline, "sweep made no progress"
            try:
                got.extend(buf.get_batch(1, timeout=0.5))
            except TimeoutError:
                continue
            if len(got) % 2 == 0:
                buf.advance_version()
    finally:
        for t in threads:
            t.join()
    ids = [s.sample_id for s in got]
    assert len(ids) + buf.total_evicted == total
    assert len(set(ids)) == len(ids)    # nothing lost, nothing duplicated
    sanitizer.assert_no_inversions("SampleBuffer perturbation sweep")


@pytest.mark.slow
def test_sanitized_router_sweep_no_inversions(clean_registry):
    """Race-fuzz the fleet path end to end: tracked locks + perturbation on
    every core lock class, concurrent submit / weight-sync / kill traffic.
    Any lock-order inversion anywhere in buffer, client, router or proxy
    fails the sweep."""
    from test_router import FakeEngine, _task

    from repro.core.llm_proxy import LLMProxy
    from repro.core.rollout_client import RolloutClient
    from repro.core.router import ProxyRouter

    was = sanitizer.enabled()
    sanitizer.enable(True)
    try:
        proxies = [LLMProxy(FakeEngine(slots=4, step_sleep=0.0005),
                            name=f"r{i}") for i in range(2)]
        router = ProxyRouter(proxies)
    finally:
        sanitizer.enable(was)
    sanitizer.install_perturber(SchedulePerturber(
        seed=7, p_yield=0.3, max_sleep_s=0.001))
    router.start()
    client = RolloutClient(router)
    try:
        handles = [client.submit(_task(4)) for _ in range(16)]
        sync = router.update_weights_async({"w": 1})
        router.mark_dead(1)
        assert sync.wait(timeout=10)
        for h in handles:
            res = h.result(timeout=30)
            assert res is not None
    finally:
        router.stop()
    sanitizer.assert_no_inversions("router sweep")
    rep = sanitizer.report()
    assert rep["acquisitions"] > 0
