"""Automatic cross-prompt prefix caching (RadixCache) + rollout bugfixes.

Load-bearing guarantees:

* caching is an optimization, never a semantic change: greedy outputs are
  byte-identical with the cache on vs off;
* a preamble shared across distinct prompts prefills exactly ONCE — for
  sequential AND for concurrent admission (mid-prefill extension);
* the refcount invariant (``audit_pages``) holds across every interaction
  of the cache with abort/retain/resume/group forks;
* LRU eviction keeps the cache from ever causing admission failure;
* regression coverage for the rollout-path bugfixes: per-epoch group uids,
  budget-exhausted abort→resume, and graceful prompt-stream exhaustion in
  ``collect_rollout``.
"""
import time

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.llm_proxy import LLMProxy
from repro.core.sample_buffer import SampleBuffer
from repro.core.scheduler import RolloutProducer, collect_rollout
from repro.core.types import GenerationResult, RolloutTask, next_uid
from repro.models import get_api
from repro.models.paged import PagePool, RadixCache
from repro.rollout.paged_engine import PagedDecodeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _drain(eng, want, max_steps=2000):
    results = {}
    for _ in range(max_steps):
        for rid, toks, lps in eng.step():
            results[rid] = (list(toks), list(lps))
        if len(results) >= want:
            return results
    raise AssertionError(f"engine stalled: {len(results)}/{want} finished")


def _engine(api, params, **kw):
    base = dict(num_slots=4, max_total_len=64, page_size=8, prefill_chunk=8,
                eos_id=99, temperature=0.0, prefix_cache=True)
    base.update(kw)
    return PagedDecodeEngine(api, params, **base)


def _preamble_prompts(n=8, pre_len=24, sfx_len=8, seed=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(1, 30, pre_len).astype(np.int32)
    return [np.concatenate([pre, rng.integers(1, 30, sfx_len).astype(np.int32)])
            for _ in range(n)]


# ------------------------------------------------------------- radix unit
def test_radix_match_insert_refcounts():
    pool = PagePool(10, page_size=4)
    cache = RadixCache(pool)
    toks = np.arange(1, 13, dtype=np.int32)          # 3 full pages
    pages = pool.alloc(3)
    assert cache.insert(toks, pages) == 3
    assert all(pool.refcount(p) == 2 for p in pages)  # owner + cache
    pool.release(pages)                               # owner done
    assert all(pool.refcount(p) == 1 for p in pages)
    assert cache.held_pages() and pool.pages_free == 10 - 1 - 3

    # full match (shares), partial match, miss
    m = cache.match(toks)
    assert m == pages and all(pool.refcount(p) == 2 for p in pages)
    pool.release(m)
    partial = np.concatenate([toks[:8], np.asarray([99, 98, 97, 96], np.int32)])
    m2 = cache.match(partial)
    assert m2 == pages[:2]
    pool.release(m2)
    assert cache.match(np.asarray([7, 7, 7, 7], np.int32)) == []
    # sub-page prompts can never match
    assert cache.match(toks[:3]) == []
    assert cache.hit_tokens == 12 + 8

    # dedupe: same content from a different physical copy is not re-inserted
    dup = pool.alloc(3)
    assert cache.insert(toks, dup) == 0
    pool.release(dup)
    assert pool.pages_free == 10 - 1 - 3


def test_radix_match_from_page_extension():
    pool = PagePool(10, page_size=4)
    cache = RadixCache(pool)
    toks = np.arange(1, 13, dtype=np.int32)
    pages = pool.alloc(3)
    cache.insert(toks, pages)
    pool.release(pages)
    ext = cache.match(toks, from_page=1)              # skip already-written page
    assert ext == pages[1:]
    pool.release(ext)
    assert cache.match(toks, from_page=3) == []


def test_radix_lru_eviction_order():
    pool = PagePool(12, page_size=4)
    cache = RadixCache(pool)
    a = np.asarray([1, 1, 1, 1], np.int32)
    b = np.asarray([2, 2, 2, 2], np.int32)
    pa, pb = pool.alloc(1), pool.alloc(1)
    cache.insert(a, pa)
    cache.insert(b, pb)
    pool.release(pa + pb)
    pool.release(cache.match(a))                      # refresh A: B is now LRU
    assert cache.evict(1) == 1
    assert cache.match(b) == [] and cache.match(a) == pa  # B evicted, A kept
    pool.release(pa)
    # pinned pages (refcount > 1) are not evictable
    held = cache.match(a)
    assert cache.evictable_pages == 0 and cache.evict(1) == 0
    pool.release(held)
    assert cache.evictable_pages == 1


# --------------------------------------------------- cross-prompt sharing
def test_shared_preamble_prefills_once_sequential(setup):
    """8 distinct prompts sharing a 24-token preamble, run back-to-back:
    the preamble's pages are computed once and aliased 7 times."""
    cfg, api, params = setup
    prompts = _preamble_prompts()
    eng = _engine(api, params)
    outs = {}
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 6)
        outs.update(_drain(eng, 1))
        eng.audit_pages()
    assert eng.total_prefill_tokens == 32 + 7 * 8, "preamble must prefill once"
    assert eng.cache_hit_tokens == 7 * 24
    assert eng.cache_hits == 7 and eng.cache_lookups >= 8

    off = _engine(api, params, num_slots=8, prefix_cache=False)
    for i, p in enumerate(prompts):
        off.add_request(i, p, 6)
    outs_off = _drain(off, 8)
    assert off.total_prefill_tokens == 8 * 32
    for i in range(8):
        assert outs[i][0] == outs_off[i][0], f"request {i} diverged"
        np.testing.assert_array_equal(
            np.asarray(outs[i][1], np.float32),
            np.asarray(outs_off[i][1], np.float32))


def test_shared_preamble_prefills_once_concurrent(setup):
    """All 8 admitted together (no completions yet): mid-prefill extension
    still collapses the shared preamble to a single prefill."""
    cfg, api, params = setup
    prompts = _preamble_prompts()
    eng = _engine(api, params, num_slots=8)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 6)
    outs = _drain(eng, 8)
    eng.audit_pages()
    assert eng.total_prefill_tokens == 32 + 7 * 8
    off = _engine(api, params, num_slots=8, prefix_cache=False)
    for i, p in enumerate(prompts):
        off.add_request(i, p, 6)
    outs_off = _drain(off, 8)
    for i in range(8):
        assert outs[i][0] == outs_off[i][0], f"request {i} diverged"


def test_partial_page_boundary_match(setup):
    """A 20-token shared preamble (2.5 pages) only matches its 2 full pages;
    an exact-duplicate prompt matches all but the final token's page."""
    cfg, api, params = setup
    rng = np.random.default_rng(7)
    pre = rng.integers(1, 30, 20).astype(np.int32)
    p1 = np.concatenate([pre, rng.integers(1, 30, 12).astype(np.int32)])
    p2 = np.concatenate([pre, rng.integers(1, 30, 12).astype(np.int32)])
    eng = _engine(api, params)
    eng.add_request(0, p1, 4)
    _drain(eng, 1)
    eng.add_request(1, p2, 4)
    _drain(eng, 1)
    assert eng.cache_hit_tokens == 16          # 2 full pages, not 20 tokens
    eng.add_request(2, p1.copy(), 4)           # identical prompt (32 tokens)
    _drain(eng, 1)
    # matches 3 of 4 pages: the page holding the final token must prefill
    assert eng.cache_hit_tokens == 16 + 24
    eng.audit_pages()


def test_cache_survives_group_fork_abort_resume(setup):
    """COW group forks + abort-with-retain + resume compose with the cache:
    outputs stay byte-identical and the refcount audit holds throughout."""
    cfg, api, params = setup
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5], np.int32)
    g, budget = 3, 10

    ref = _engine(api, params, prefix_cache=False)
    ref.submit_group([0, 1, 2], prompt, budget)
    base = _drain(ref, g)

    eng = _engine(api, params)
    eng.submit_group([0, 1, 2], prompt, budget)
    for _ in range(5):
        eng.step()
    partial = eng.abort(1, retain=True)
    assert partial.resumable
    eng.audit_pages()
    rest = _drain(eng, 2)
    eng.audit_pages()
    for rid in (0, 2):
        assert rest[rid][0] == base[rid][0]
    eng.resume_request(1, 11, budget - len(partial.tokens))
    got = _drain(eng, 1)[11]
    assert list(partial.tokens) + got[0] == base[1][0]
    eng.audit_pages()
    # a second group of the same prompt now rides the cache
    before = eng.total_prefill_tokens
    eng.submit_group([20, 21, 22], prompt, budget)
    again = _drain(eng, 3)
    assert eng.total_prefill_tokens - before == 3, \
        "cached group must prefill only the final partial page"
    for i, rid in enumerate((20, 21, 22)):
        assert again[rid][0] == base[i][0]
    eng.audit_pages()


def test_release_retained_feeds_cache(setup):
    cfg, api, params = setup
    prompt = np.arange(1, 18, dtype=np.int32)
    eng = _engine(api, params)
    eng.add_request(0, prompt, 10)
    for _ in range(12):                  # 3 prefill chunks + 9 decode steps
        eng.step()
    r = eng.abort(0, retain=True)
    assert r.resumable and len(r.tokens) >= 8
    held = eng.cache_pages_held
    eng.release_retained(0)
    eng.audit_pages()
    assert eng.cache_pages_held > held, \
        "retained decode-region pages must enter the cache"
    assert not eng.retained
    # the decoded prefix is now a hit for a prompt that extends it (the
    # agentic pattern: next turn's prompt = conversation + previous action)
    ext = np.concatenate([prompt, np.asarray(r.tokens[:8], np.int32)])
    eng.add_request(1, ext, 4)
    assert eng.slots[eng.req_to_slot[1]].prefill_done == 24
    _drain(eng, 1)
    eng.audit_pages()


def test_lru_eviction_prevents_admission_failure(setup):
    """A pool sized for 2 in-flight requests accumulates cache holds; the
    4th admission must evict LRU leaves rather than fail."""
    cfg, api, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 30, 16).astype(np.int32) for _ in range(4)]
    eng = _engine(api, params, num_slots=2, max_total_len=32, num_pages=8)
    for i, p in enumerate(prompts):               # 3 pages each, 7 usable
        assert eng.can_admit(16, 8), f"admission {i} must not fail"
        eng.add_request(i, p, 8)
        _drain(eng, 1)
        eng.audit_pages()
    assert eng.cache_evicted_pages > 0, "pressure must trigger LRU eviction"
    assert eng.pool.pages_free + eng.cache_pages_held == eng.num_pages - 1


def test_radix_interior_pin_not_promised_to_admission():
    """A mid-prefill extender shares only the continuation pages, pinning a
    descendant while the refcount-1 ancestors stay interior — leaf-first
    eviction cannot reach them, so evictable_pages must not count them
    (or can_admit would over-promise and pool.alloc would assert)."""
    pool = PagePool(10, page_size=4)
    cache = RadixCache(pool)
    toks = np.arange(1, 13, dtype=np.int32)           # path A -> B -> C
    pages = pool.alloc(3)
    cache.insert(toks, pages)
    pool.release(pages)
    assert cache.evictable_pages == 3
    held = cache.match(toks, from_page=2, extend=True)  # pin C only
    assert held == pages[2:]
    assert cache.evictable_pages == 0, \
        "pinned leaf blocks its whole ancestor path from cascading eviction"
    assert cache.evict(3) == 0
    pool.release(held)
    assert cache.evictable_pages == 3 and cache.evict(3) == 3


def test_concurrent_extension_counts_ext_hits(setup):
    cfg, api, params = setup
    prompts = _preamble_prompts(n=4)
    eng = _engine(api, params, num_slots=4)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 4)
    _drain(eng, 4)
    # admitted together: nothing cached at admission time, so the sharing
    # happened via mid-prefill extension — recorded separately from hits
    assert eng.cache_hits == 0 and eng.cache_ext_hits >= 3
    assert eng.cache_hit_tokens == 3 * 24


def test_stale_pages_not_republished_after_weight_update(setup):
    """Abort/finish/release of a request whose KV predates the last weight
    sync must NOT repopulate the flushed cache with old-policy pages (the
    async controller aborts stale requests right after update_weights)."""
    cfg, api, params = setup
    prompt = np.arange(1, 25, dtype=np.int32)
    eng = _engine(api, params)
    # in-flight under old weights: partially prefilled + retained records
    eng.add_request(0, prompt, 6)
    eng.add_request(1, prompt, 6)
    for _ in range(4):
        eng.step()
    r1 = eng.abort(1, retain=True)
    assert r1.resumable
    eng.update_weights(params)                 # flush + epoch bump
    assert eng.cache_pages_held == 0
    eng.step()                                 # request 0 keeps prefilling
    assert eng.cache_pages_held == 0, \
        "old-epoch slot must not publish mid-prefill pages"
    _drain(eng, 1)                             # request 0 finishes
    assert eng.cache_pages_held == 0, \
        "old-epoch finish must not re-insert stale KV"
    eng.release_retained(1)
    assert eng.cache_pages_held == 0, \
        "old-epoch retained release must not re-insert stale KV"
    eng.audit_pages()
    assert eng.pool.pages_free == eng.num_pages - 1
    # a fresh post-sync request publishes again
    eng.add_request(2, prompt, 6)
    _drain(eng, 1)
    assert eng.cache_pages_held > 0
    eng.audit_pages()


def test_can_resume_uses_evictable_pages(setup):
    """A resume needing extra pages must count cache-evictable pages as
    available — gating on raw pages_free would park the resume forever
    while the cache sits on every free page."""
    cfg, api, params = setup
    eng = _engine(api, params, num_slots=2, max_total_len=64, num_pages=10)
    rng = np.random.default_rng(9)
    eng.add_request(0, rng.integers(1, 30, 32).astype(np.int32), 8)
    _drain(eng, 1)                              # cache now holds 4 pages
    eng.add_request(1, rng.integers(1, 30, 8).astype(np.int32), 8)
    for _ in range(3):
        eng.step()
    r = eng.abort(1, retain=True)
    assert r.resumable
    ret = eng.retained[1]
    extra = eng._resume_pages_needed(ret, 40) - len(ret.pages)
    assert extra > eng.pool.pages_free, "test needs genuine page pressure"
    assert eng.can_resume(1, 40), "evictable cache pages must count"
    eng.resume_request(1, 11, 40)
    assert eng.cache_evicted_pages > 0
    eng.audit_pages()
    _drain(eng, 1)
    eng.audit_pages()


def test_weight_update_flushes_cache(setup):
    cfg, api, params = setup
    prompt = np.arange(1, 25, dtype=np.int32)
    eng = _engine(api, params)
    eng.add_request(0, prompt, 4)
    _drain(eng, 1)
    assert eng.cache_pages_held > 0
    eng.update_weights(params)
    assert eng.cache_pages_held == 0
    assert eng.pool.pages_free == eng.num_pages - 1
    eng.audit_pages()
    eng.add_request(1, prompt, 4)
    assert eng.slots[eng.req_to_slot[1]].prefill_done == 0, \
        "post-update admission must not alias stale KV"
    _drain(eng, 1)


def test_proxy_cache_stats(setup):
    cfg, api, params = setup
    eng = _engine(api, params)
    proxy = LLMProxy(eng)
    s = proxy.cache_stats
    assert s == {"lookups": 0, "hits": 0, "misses": 0, "extension_hits": 0,
                 "hit_tokens": 0, "evicted_pages": 0, "pages_held": 0}
    prompts = _preamble_prompts(n=2)
    eng.add_request(0, prompts[0], 4)
    _drain(eng, 1)
    eng.add_request(1, prompts[1], 4)
    _drain(eng, 1)
    s = proxy.cache_stats
    assert s["hits"] == 1 and s["hit_tokens"] == 24
    assert s["lookups"] == 2, \
        "one lookup per admission; extension probes must not inflate stats"
    assert s["misses"] == 1
    assert proxy.cache_hit_tokens == 24


def test_pipeline_prefix_cache_setting(setup):
    from repro.launch.pipeline import PipelineSettings, make_rollout_engine
    from repro.rollout.engine import DecodeEngine
    cfg, api, params = setup
    eng = make_rollout_engine(api, params, PipelineSettings())
    assert eng.prefix_cache is not None            # auto -> on for paged
    eng = make_rollout_engine(api, params, PipelineSettings(prefix_cache="off"))
    assert eng.prefix_cache is None
    # slot engine: the setting passes through as a no-op
    eng = make_rollout_engine(api, params, PipelineSettings(
        rollout_engine="slot", prefix_cache="on"))
    assert isinstance(eng, DecodeEngine)
    with pytest.raises(ValueError, match="prefix_cache"):
        make_rollout_engine(api, params,
                            PipelineSettings(prefix_cache="bogus"))


def test_multi_turn_incremental_prefill(setup):
    """The agentic pattern: each turn resubmits the growing conversation.
    With the cache, turn t only prefills the new suffix."""
    cfg, api, params = setup
    rng = np.random.default_rng(11)
    eng = _engine(api, params, num_slots=2, max_total_len=64)
    convo = rng.integers(1, 30, 9).astype(np.int32)
    total_uncached = 0
    for turn in range(3):
        eng.add_request(turn, convo, 4)
        out = _drain(eng, 1)[turn]
        eng.audit_pages()
        total_uncached += len(convo)
        obs = rng.integers(1, 30, 5).astype(np.int32)
        convo = np.concatenate([convo, np.asarray(out[0], np.int32), obs])
    assert eng.total_prefill_tokens < total_uncached, \
        "each turn must re-prefill only the uncached tail"
    assert eng.cache_hit_tokens >= 16


# ----------------------------------------------- rollout-path regressions
class _RecordingProxy:
    """Quacks like LLMProxy; captures callbacks so tests can inject abort
    legs into the client-layer continuation."""

    def __init__(self):
        self.groups, self.singles, self.resumed, self.released = [], [], [], []
        self.callbacks = {}

    def generate_group(self, tasks, version, cb, **kw):
        self.groups.append(list(tasks))
        for t in tasks:
            self.callbacks[t.task_id] = cb
        return [t.task_id for t in tasks]

    def generate(self, task, version, cb, **kw):
        self.singles.append(task)
        self.callbacks[task.task_id] = cb
        return task.task_id

    def generate_resumed(self, task, version, cb, resume_from, **kw):
        self.resumed.append((task, resume_from))
        self.callbacks[task.task_id] = cb
        return task.task_id

    def release_retained(self, request_id):
        self.released.append(request_id)

    def abort(self, request_id, retain=False):
        pass


def test_producer_fresh_group_uid_per_epoch():
    """A prompt repeated across epochs must get a FRESH group uid — with
    group_id=pid the second epoch's group collides with the first."""
    p = np.asarray([1, 2], np.int32)
    stream = iter([(0, p)] * 4 + [(1, p)] * 4 + [(0, p)] * 4)  # epoch 2 of pid 0
    buf = SampleBuffer(batch_size=32, alpha=0)
    proxy = _RecordingProxy()
    prod = RolloutProducer(proxy, buf, stream, group_size=4, max_new_tokens=4,
                           reward_fn=lambda s: 1.0)
    for _ in range(3):
        prod._produce_group()
    gids = [[t.group_id for t in g] for g in proxy.groups]
    assert len(gids) == 3
    assert all(len(set(g)) == 1 for g in gids), "one uid per group"
    assert len({g[0] for g in gids}) == 3, \
        "repeated prompt must not reuse its earlier group uid"
    assert all(g[0] != t.prompt_id for g, grp in zip(gids, proxy.groups,
                                                     strict=True)
               for t in grp), "group uid must not be the prompt id"


def test_producer_partial_flush_keeps_one_uid():
    """A capacity pinch splits a group across submissions; both halves must
    carry the SAME uid so downstream assembly reunites them."""
    p = np.asarray([1, 2], np.int32)
    stream = iter([(0, p)] * 4 + [(1, p)] * 4)
    buf = SampleBuffer(batch_size=3, alpha=0)       # capacity 3 < group_size
    proxy = _RecordingProxy()
    prod = RolloutProducer(proxy, buf, stream, group_size=4, max_new_tokens=4,
                           reward_fn=lambda s: 1.0)
    prod._produce_group()                           # pinch: 3 of 4 replicas
    buf.reclaim(3)
    prod._produce_group()                           # 4th replica, B held back
    buf.reclaim(1)
    prod._produce_group()                           # held B seeds new group
    gid_a = proxy.groups[0][0].group_id
    assert proxy.singles[0].group_id == gid_a, \
        "partial-flush remainder must keep the group uid"
    assert proxy.groups[1][0].group_id != gid_a


def _abort_result(task, tokens, resumable=True):
    return GenerationResult(
        request_id=task.task_id, task=task,
        tokens=np.asarray(tokens, np.int32),
        logprobs=np.zeros((len(tokens),), np.float32),
        version_started=0, aborted=True, partial=True, resumable=resumable)


def test_budget_exhausted_abort_finishes_instead_of_resuming():
    """An abort arriving with the generation budget fully spent must publish
    the sample (clamped) and release the retained pages — resuming would
    decode >= 1 extra token per cycle.  The continuation lives in the
    CLIENT layer now: the producer only sees the final handle result."""
    buf = SampleBuffer(batch_size=4, alpha=0)
    proxy = _RecordingProxy()
    prod = RolloutProducer(proxy, buf, iter([]), group_size=1,
                           max_new_tokens=4, reward_fn=lambda s: 1.0)
    buf.begin_generation()
    task = RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=4, group_id=7)
    prod._submit([task], version=0)
    proxy.callbacks[task.task_id](_abort_result(task, [5, 6, 7, 8]))
    assert not proxy.resumed and len(proxy.singles) == 1, "must not resume"
    assert proxy.released == [task.task_id], "retained pages must be freed"
    batch = buf.get_batch(1, block=False)
    assert list(batch[0].response_tokens) == [5, 6, 7, 8]
    assert len(batch[0].logprobs) == 4


def test_budget_exhausted_multi_leg_resume_clamps():
    """Second leg: 3 tokens from leg one + 2 more decoded overruns the
    4-token budget — finish and clamp to exactly max_new_tokens.  The
    stitched state lives in the handle, not in task meta."""
    buf = SampleBuffer(batch_size=4, alpha=0)
    proxy = _RecordingProxy()
    prod = RolloutProducer(proxy, buf, iter([]), group_size=1,
                           max_new_tokens=4, reward_fn=lambda s: 1.0)
    buf.begin_generation()
    task = RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=4, group_id=7)
    prod._submit([task], version=0)
    proxy.callbacks[task.task_id](_abort_result(task, [5, 6, 7]))
    (leg2, resume_from), = proxy.resumed       # transparent resume, leg 2
    assert resume_from == task.task_id and leg2.max_new_tokens == 1
    assert "resumed_tokens" not in leg2.meta, \
        "no abort->resume meta threading outside the client layer"
    proxy.callbacks[leg2.task_id](_abort_result(leg2, [8, 9]))
    assert len(proxy.resumed) == 1, "budget spent: must not resume again"
    assert leg2.task_id in proxy.released
    batch = buf.get_batch(1, block=False)
    assert list(batch[0].response_tokens) == [5, 6, 7, 8]
    assert len(batch[0].logprobs) == 4
    assert batch[0].meta["legs"] == [(0, 3), (0, 1)], \
        "per-leg tags are budget-clamped: they exactly segment the arrays"


def test_partial_budget_abort_still_resumes_with_exact_remainder():
    buf = SampleBuffer(batch_size=4, alpha=0)
    proxy = _RecordingProxy()
    prod = RolloutProducer(proxy, buf, iter([]), group_size=1,
                           max_new_tokens=6, reward_fn=lambda s: 1.0)
    buf.begin_generation()
    task = RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=6, group_id=7)
    prod._submit([task], version=0)
    proxy.callbacks[task.task_id](_abort_result(task, [5, 6]))
    (resumed, resume_from), = proxy.resumed
    assert resume_from == task.task_id
    assert resumed.max_new_tokens == 4, "remainder, never max(1, ...) padding"
    # retained-page resume keeps the ORIGINAL prompt
    np.testing.assert_array_equal(resumed.prompt_tokens, [1, 2, 3])


def test_non_resumable_abort_reprefills_concatenated_prefix():
    """Slot-engine fallback: no retained pages, so the continuation
    re-prefills original prompt + decoded prefix as the new prompt."""
    buf = SampleBuffer(batch_size=4, alpha=0)
    proxy = _RecordingProxy()
    prod = RolloutProducer(proxy, buf, iter([]), group_size=1,
                           max_new_tokens=6, reward_fn=lambda s: 1.0)
    buf.begin_generation()
    task = RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=6, group_id=7)
    prod._submit([task], version=0)
    proxy.callbacks[task.task_id](_abort_result(task, [5, 6], resumable=False))
    assert not proxy.resumed and len(proxy.singles) == 2
    leg2 = proxy.singles[-1]
    assert list(leg2.prompt_tokens) == [1, 2, 3, 5, 6]
    assert leg2.max_new_tokens == 4
    proxy.callbacks[leg2.task_id](GenerationResult(
        request_id=leg2.task_id, task=leg2,
        tokens=np.asarray([7, 8], np.int32),
        logprobs=np.zeros((2,), np.float32), version_started=2))
    batch = buf.get_batch(1, block=False)
    assert list(batch[0].response_tokens) == [5, 6, 7, 8]
    assert list(batch[0].prompt_tokens) == [1, 2, 3], "original prompt only"
    assert batch[0].version_started == 2, "tagged with the final leg version"


def test_collect_rollout_stream_exhaustion_returns_partial(setup):
    """All groups filtered + stream exhausted: collect_rollout returns the
    partial result promptly instead of raising StopIteration or spinning
    until the timeout."""
    cfg, api, params = setup
    eng = _engine(api, params, num_slots=8, max_total_len=32)
    proxy = LLMProxy(eng).start()
    rng = np.random.default_rng(5)
    stream = iter([(i, rng.integers(1, 30, 6).astype(np.int32))
                   for i in range(3)])
    t0 = time.monotonic()
    out = collect_rollout(proxy, stream, num_groups=2, group_size=2,
                          max_new_tokens=4, reward_fn=lambda s: 1.0,
                          filter_fn=lambda g: False, timeout=120)
    elapsed = time.monotonic() - t0
    proxy.stop()
    assert out == []
    assert elapsed < 60, "exhaustion must break out, not run to timeout"


def test_collect_rollout_aborts_only_running_tasks(setup):
    """The cleanup loop must not ABORT task ids that already completed."""
    cfg, api, params = setup
    eng = _engine(api, params, num_slots=8, max_total_len=32)
    proxy = LLMProxy(eng).start()
    aborted_ids = []
    real_abort = proxy.abort

    def spy_abort(request_id, retain=False):
        aborted_ids.append(request_id)
        return real_abort(request_id, retain=retain)

    proxy.abort = spy_abort
    rng = np.random.default_rng(6)
    stream = iter([(i, rng.integers(1, 30, 6).astype(np.int32))
                   for i in range(8)])
    out = collect_rollout(proxy, stream, num_groups=2, group_size=2,
                          max_new_tokens=4,
                          reward_fn=lambda s: float(s.response_tokens[0] % 2),
                          timeout=120)
    proxy.stop()
    assert len(out) == 4
    # with no extra running prompts and no filtering, nothing is running at
    # the end — the old code aborted every submitted (completed) id.
    assert aborted_ids == []


def test_env_manager_full_context_mode(setup):
    """context_mode='full' resubmits the growing conversation; the prefix
    cache turns the repeated history into cache hits."""
    from repro.core.env_manager import EnvManagerPool
    from repro.envs.base import BaseEnv

    class ScriptedEnv(BaseEnv):
        def __init__(self, env_id):
            self.t = 0

        def reset(self):
            self.t = 0
            return np.asarray([11, 12, 13, 14, 15, 16, 17, 18], np.int32)

        def step(self, action):
            self.t += 1
            done = self.t >= 2
            return (np.asarray([20 + self.t] * 8, np.int32),
                    1.0 if done else 0.0, done, {})

    cfg, api, params = setup
    eng = _engine(api, params, num_slots=4, max_total_len=64)
    proxy = LLMProxy(eng).start()
    buf = SampleBuffer(batch_size=2, alpha=0)
    pool = EnvManagerPool(ScriptedEnv, proxy, buf, num_env_groups=1,
                          group_size=1, max_steps=4, max_new_tokens=4,
                          target_trajectories=1, context_mode="full",
                          max_context_tokens=60)
    pool.start()
    batch = buf.get_batch(1, timeout=90)
    pool.stop()
    proxy.stop()
    assert len(batch) == 1
    assert pool.managers[0].context_mode == "full"
    assert eng.cache_hit_tokens > 0, \
        "turn 2's resubmitted history must hit the cache"


def test_env_manager_rejects_bad_context_mode(setup):
    from repro.core.env_manager import EnvManager
    with pytest.raises(ValueError, match="context_mode"):
        EnvManager(env=None, proxy=None, pool=None, env_id=0, group_id=0,
                   max_steps=1, max_new_tokens=1, context_mode="bogus")
    with pytest.raises(ValueError, match="max_context_tokens"):
        # uncapped growing conversations would overrun the engine budget
        EnvManager(env=None, proxy=None, pool=None, env_id=0, group_id=0,
                   max_steps=1, max_new_tokens=1, context_mode="full")


# ----------------------------------------------------------- slow sweeps
@pytest.mark.slow
@pytest.mark.timeout(600)
def test_cache_on_off_parity_sweep(setup):
    """Greedy parity across prompt lengths crossing page boundaries, with
    prompts sharing prefixes of various depths."""
    cfg, api, params = setup
    rng = np.random.default_rng(0)
    pre = rng.integers(1, 30, 19).astype(np.int32)
    lengths = [5, 8, 13, 21, 32]
    prompts = [np.concatenate([pre[:n % 20], rng.integers(1, 30, n).astype(np.int32)])
               for n in lengths]
    outs = {}
    for pc in (False, True):
        eng = _engine(api, params, num_slots=8, prefix_cache=pc)
        for i, p in enumerate(prompts):
            eng.add_request(i, p, 6)
        res = _drain(eng, len(prompts))
        eng.audit_pages()
        outs[pc] = res
    for i in range(len(prompts)):
        assert outs[True][i][0] == outs[False][i][0], f"prompt {i} diverged"


@pytest.mark.slow
@pytest.mark.timeout(600)
def test_cache_churn_audit_sweep(setup):
    """Randomized add/abort/retain/resume/finish churn with the cache on:
    the refcount audit must hold after every transition and the pool must
    fully drain (minus cache holds) at the end."""
    cfg, api, params = setup
    rng = np.random.default_rng(42)
    eng = _engine(api, params, num_slots=4, max_total_len=32, num_pages=24)
    next_rid = [0]
    retained = []

    def admit():
        plen = int(rng.integers(4, 17))
        p = rng.integers(1, 30, plen).astype(np.int32)
        rid = next_rid[0]
        next_rid[0] += 1
        if eng.can_admit(plen, 6):
            eng.add_request(rid, p, 6)

    for _step in range(200):
        op = rng.random()
        if op < 0.25 and eng.num_free_slots > 0:
            admit()
        elif op < 0.35 and eng.active_request_ids:
            rid = int(rng.choice(eng.active_request_ids))
            keep = bool(rng.random() < 0.5)
            r = eng.abort(rid, retain=keep)
            if r.resumable:
                retained.append((rid, len(r.tokens)))
        elif op < 0.45 and retained:
            rid, ntok = retained.pop()
            new_rid = 10000 + rid
            if eng.can_resume(rid, 6):
                eng.resume_request(rid, new_rid, max(1, 6 - ntok))
            else:
                eng.release_retained(rid)
        else:
            eng.step()
        eng.audit_pages()
    for rid in list(eng.active_request_ids):
        eng.abort(rid)
    for rid, _ in retained:
        eng.release_retained(rid)
    eng.audit_pages()
    assert eng.pool.pages_free + eng.cache_pages_held == eng.num_pages - 1
