"""Data pipeline + verifier correctness (property-based)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.types import Sample
from repro.data.dataset import (ArithmeticProblem, ArithmeticTask,
                                decode_number, encode_number, pad_and_stack)
from repro.rewards.verifier import ArithmeticVerifier


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_number_roundtrip(n):
    assert decode_number(encode_number(n) + [2]) == n


@given(st.integers(0, 99), st.integers(0, 99),
       st.sampled_from(["+", "*", "-"]))
@settings(max_examples=50, deadline=None)
def test_prompt_roundtrip_and_verifier(a, b, op):
    if op == "-" and b > a:
        a, b = b, a
    prob = ArithmeticProblem(a, b, op)
    task = ArithmeticTask(ops=("+", "*", "-"))
    parsed = task.problem_from_prompt(prob.prompt_tokens())
    assert parsed == prob

    verifier = ArithmeticVerifier(task)
    good = Sample(sample_id=0, prompt_id=0, replica_idx=0,
                  prompt_tokens=prob.prompt_tokens(),
                  response_tokens=prob.answer_tokens(),
                  logprobs=np.zeros(1))
    bad = Sample(sample_id=1, prompt_id=0, replica_idx=0,
                 prompt_tokens=prob.prompt_tokens(),
                 response_tokens=ArithmeticProblem(a + 1, b, op).answer_tokens(),
                 logprobs=np.zeros(1))
    assert verifier(good) == 1.0
    if ArithmeticProblem(a + 1, b, op).answer != prob.answer:
        # wrong but well-formed numeric answer gets only the format credit
        assert verifier(bad) == verifier.format_credit < 1.0


def test_prompt_stream_groups():
    task = ArithmeticTask(seed=1)
    stream = task.prompt_stream(group_size=3)
    items = [next(stream) for _ in range(9)]
    pids = [p for p, _ in items]
    assert pids == [0, 0, 0, 1, 1, 1, 2, 2, 2]
    toks = {p: t.tobytes() for p, t in items}
    assert len(toks) == 3


def test_pad_and_stack():
    out = pad_and_stack([np.asarray([1, 2]), np.asarray([3])], 4, align="left")
    np.testing.assert_array_equal(out, [[1, 2, 0, 0], [3, 0, 0, 0]])
