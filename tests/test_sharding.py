"""Sharding rules + single-device lower/compile of the sharded step functions.

The full 512-device dry-run runs via `python -m repro.launch.dryrun` (it must
own XLA_FLAGS before jax init); these tests validate the same plumbing on the
1-device mesh so pytest exercises build_combo end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from conftest import tiny
from repro.models import get_api, sharding as shd


def fake_mesh(data=4, model=4):
    """Abstract mesh for spec computation only (no devices needed)."""
    devs = np.empty((data, model), dtype=object)
    for i in range(data):
        for j in range(model):
            devs[i, j] = jax.devices()[0]
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_param_rules_hit_expected_axes():
    cfg = tiny("qwen3-8b", d_model=128, num_heads=8, head_dim=16,
               num_kv_heads=4, d_ff=256, vocab_size=256)
    api = get_api(cfg)
    params = jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = shd.param_specs(params, mesh)
    flat = {shd._path_str(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(specs)[0]}
    assert flat["blocks/attn/wq"] == P(None, "data", "model")
    assert flat["blocks/attn/wo"] == P(None, "model", "data")
    assert flat["blocks/mlp/wi_gate"] == P(None, "data", "model")
    assert flat["embed"] == P("model", "data")
    assert flat["lm_head"] == P("data", "model")
    assert flat["final_norm/scale"] == P()


def test_param_rules_moe_expert_parallel():
    cfg = tiny("qwen3-moe-235b-a22b", d_model=128, num_heads=8, head_dim=16,
               num_kv_heads=4, num_experts=4, moe_d_ff=64, vocab_size=256)
    api = get_api(cfg)
    params = jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = shd.param_specs(params, mesh)
    flat = {shd._path_str(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(specs)[0]}
    assert flat["blocks/moe/w_gate"] == P(None, "model", "data", None)
    assert flat["blocks/moe/w_down"] == P(None, "model", "data", None)


def test_divisibility_fallback_replicates():
    """head_dim 120 (danube) etc: dims not divisible by the mesh axis size
    must silently fall back to replication, not crash."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sizes = {"data": 16, "model": 16}
    spec = shd._spec_for("blocks/attn/wq", (3, 120), sizes)
    assert spec == P(None, None)  # 3 % 16 != 0, 120 % 16 != 0


def _abstract_mesh(data=16, model=16):
    from jax.sharding import AbstractMesh
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh((data, model), ("data", "model"))
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh((("data", data), ("model", model)))


def test_cache_specs_batch_and_feature_sharded():
    cfg = tiny("qwen3-8b", num_kv_heads=2, head_dim=16)
    api = get_api(cfg)
    mesh = _abstract_mesh()
    cache = jax.eval_shape(lambda: api.init_cache(16, 64))
    specs = shd.cache_specs(cache, mesh)
    k_spec = specs.k
    assert k_spec[1] in ("data", ("data",))  # batch axis
    # largest remaining axis (the sequence axis) gets the model TP shard
    # (§Perf iter 2: head_dim sharding forced GQA-reshape resharding)
    assert k_spec[2] == "model"
    assert specs.pos[1] in ("data", ("data",))
    assert specs.pos[-1] is None   # int32 positions never TP-sharded


def test_cache_specs_long_context_fallback():
    """B=1: batch unshardable -> sequence axis sharded over data."""
    cfg = tiny("h2o-danube-3-4b", sliding_window=None, num_kv_heads=2, head_dim=16)
    api = get_api(cfg)
    mesh = _abstract_mesh()
    cache = jax.eval_shape(lambda: api.init_cache(1, 512))
    specs = shd.cache_specs(cache, mesh)
    assert specs.k[1] is None
    assert specs.k[2] == "data"  # context-parallel over data
    assert "model" in specs.k    # plus a TP axis elsewhere


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-4b", "train_4k"),
    ("qwen3-moe-235b-a22b", "decode_32k"),
    ("rwkv6-3b", "long_500k"),
    ("seamless-m4t-medium", "prefill_32k"),
])
def test_build_combo_lowers_on_unit_mesh(arch, shape, monkeypatch):
    """build_combo must lower+compile on the degenerate 1x1 mesh with tiny
    shape overrides (full-size validation is the dryrun launcher's job)."""
    import dataclasses

    from repro.configs import SHAPES
    from repro.launch import dryrun

    cfg = tiny(arch)
    sh = dataclasses.replace(SHAPES[shape], seq_len=64, global_batch=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn, args, in_shard, out_shard, donate = dryrun.build_combo(cfg, sh, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard,
                           donate_argnums=donate).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # jax 0.4.x returns a per-device list
        ca = ca[0]
    assert ca.get("flops", 0) > 0


def test_activation_sharding_hook_noop_without_spec():
    x = jnp.ones((2, 4, 8))
    shd.set_activation_sharding(None)
    assert shd.constrain_activation(x) is x
