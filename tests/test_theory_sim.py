"""Propositions 1 & 2 vs the discrete-event simulator (property-based)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import simulator as S
from repro.core import theory as T


@given(n=st.integers(1, 200), k=st.integers(1, 64), seed=st.integers(0, 999))
@settings(max_examples=50, deadline=None)
def test_prop1_queue_completion_bound(n, k, seed):
    rng = np.random.default_rng(seed)
    durs = rng.lognormal(0.0, 1.2, size=n)
    t = S.simulate_queue_completion(durs, k)
    bound = T.prop1_completion_bound(n, k, float(durs.mean()), float(durs.max()))
    assert t <= bound + 1e-9


@given(n=st.integers(2, 100), k=st.integers(1, 32), seed=st.integers(0, 999))
@settings(max_examples=50, deadline=None)
def test_queue_within_graham_bound_of_static(n, k, seed):
    """Greedy list scheduling is NOT pointwise <= a lucky static partition
    (hypothesis found the counterexample), but Graham's bound guarantees
    queue <= (2 - 1/k) * OPT <= (2 - 1/k) * static."""
    rng = np.random.default_rng(seed)
    durs = rng.lognormal(0.0, 1.5, size=n)
    q = S.simulate_queue_completion(durs, k)
    s = S.simulate_static_completion(durs, k)
    assert q <= (2.0 - 1.0 / k) * s + 1e-9


def test_queue_beats_static_on_average():
    rng = np.random.default_rng(0)
    wins = 0
    for _ in range(200):
        durs = rng.lognormal(0.0, 1.5, size=64)
        q = S.simulate_queue_completion(durs, 8)
        s = S.simulate_static_completion(durs, 8)
        wins += q <= s + 1e-9
    assert wins >= 175  # queue scheduling dominates under long tails


@given(seed=st.integers(0, 200), alpha=st.sampled_from([0.0, 1.0, 2.0, 4.0]))
@settings(max_examples=25, deadline=None)
def test_async_pipeline_staleness_bounded(seed, alpha):
    cfg = S.PipelineConfig(rollout_batch_size=16, gpus=8, train_gpus=4,
                           infer_gpus=4, slots_per_gpu=4, per_token_time=0.001,
                           mu_train_per_sample=0.01, train_overhead=0.5,
                           alpha=alpha, mode="async")
    res = S.simulate_pipeline(np.random.default_rng(seed), cfg, 10,
                              S.lognormal_lengths(500, 1.0))
    assert max(res.staleness) <= alpha
    assert min(res.staleness) >= 0


@given(seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_async_beats_sync_naive_under_long_tail(seed):
    """Theory: async >= sync throughput whenever tails are heavy (Prop 2)."""
    base = dict(rollout_batch_size=64, group_size=8, gpus=16, slots_per_gpu=8,
                per_token_time=0.001, mu_train_per_sample=0.05,
                train_overhead=2.0)
    sampler = S.lognormal_lengths(2000, 1.2)
    naive = S.simulate_pipeline(np.random.default_rng(seed),
                                S.PipelineConfig(**base, mode="sync_naive"), 8,
                                sampler)
    asy = S.simulate_pipeline(np.random.default_rng(seed),
                              S.PipelineConfig(**base, mode="async",
                                               train_gpus=8, infer_gpus=8,
                                               alpha=2), 8, sampler)
    assert asy.throughput > naive.throughput


def test_prop2_optimal_beta_balances_pipelines():
    n, k, mu_g, l_g, mu_t, e, alpha = 256, 64, 5.0, 100.0, 1.0, 1.0, 2.0
    beta = T.prop2_optimal_beta(n, k, mu_g, l_g, mu_t, e, alpha)
    assert 0.0 < beta < 1.0
    gen = n / ((1 - beta) * k) * mu_g + l_g / ((alpha + 1) * (1 - beta))
    train = e * n / (beta * k) * mu_t
    np.testing.assert_allclose(gen, train, rtol=1e-9)
    # eq (11): the balanced bound
    bound = T.prop2_async_bound(n, k, mu_g, l_g, mu_t, e, alpha, beta)
    np.testing.assert_allclose(
        bound, T.prop2_async_bound_at_optimum(n, k, mu_g, l_g, mu_t, e, alpha),
        rtol=1e-6)


@given(alpha=st.floats(0.5, 16.0))
@settings(max_examples=20, deadline=None)
def test_prop2_async_tighter_than_sync(alpha):
    """Eq 11 <= eq 8 strictly for alpha > 0."""
    n, k, mu_g, l_g, mu_t, e = 256, 64, 5.0, 100.0, 1.0, 1.0
    sync = T.prop2_sync_bound(n, k, mu_g, l_g, mu_t, e)
    asyb = T.prop2_async_bound_at_optimum(n, k, mu_g, l_g, mu_t, e, alpha)
    assert asyb < sync


def test_prop1_max_speedup_formula():
    assert T.prop1_max_speedup(mu_gen=10.0, l_gen=90.0) == pytest.approx(10.0)


def test_env_async_speedup_grows_with_variance():
    """Fig 9 left: higher sigma -> bigger env-level-async speedup."""
    speedups = []
    for sigma in (1.0, 10.0):
        cfg = S.AgenticConfig(rollout_batch_size=128, num_env_groups=16,
                              group_size=8, k_slots=32, turns=5,
                              env_latency_mu=10.0, env_latency_sigma=sigma,
                              env_async=False)
        t_sync = S.simulate_agentic_step(np.random.default_rng(0), cfg)
        cfg_async = S.AgenticConfig(**{**cfg.__dict__, "env_async": True})
        t_async = S.simulate_agentic_step(np.random.default_rng(0), cfg_async)
        speedups.append(t_sync / t_async)
    assert speedups[1] > speedups[0] >= 1.0


def test_redundant_env_rollout_tolerates_fail_stop():
    cfg = S.AgenticConfig(rollout_batch_size=64, num_env_groups=10,
                          group_size=8, k_slots=32, turns=3,
                          env_latency_mu=5.0, env_latency_sigma=2.0,
                          env_async=True, p_fail_stop=0.1)
    t = S.simulate_agentic_step(np.random.default_rng(0), cfg)
    assert np.isfinite(t)
    # without redundancy the same failure rate cannot fill the batch
    cfg_exact = S.AgenticConfig(**{**cfg.__dict__, "num_env_groups": 8,
                                   "p_fail_stop": 0.4})
    with pytest.raises(RuntimeError):
        S.simulate_agentic_step(np.random.default_rng(1), cfg_exact)


def test_filtered_rollout_queue_faster_than_batch():
    kw = dict(batch_groups=8, group_size=8, k_slots=64,
              length_sampler=S.lognormal_lengths(1000, 1.0),
              per_token_time=0.001, p_filter=0.4)
    b = S.simulate_filtered_rollout(np.random.default_rng(0), mode="batch", **kw)
    q = S.simulate_filtered_rollout(np.random.default_rng(0), mode="queue",
                                    extra_prompts=16, **kw)
    assert q.gen_time < b.gen_time
