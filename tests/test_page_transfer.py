"""Cross-replica KV page transfer + fleet-global cache-aware routing.

Covers the transfer primitive (``export_pages``/``import_pages`` round
trips, scales carried under ``kv_quant=int8``), the engine-level retained
export/import (zero-re-prefill migrated resume, byte-identical greedy
output), the prefix pull path, the router-owned ``FleetRadixIndex``
(consistency with every replica's local tree across insert/evict/flush,
verified by ``fleet_audit``), two-tier cache-aware placement, and a churn
sweep with kill/drain under cache-aware routing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import tiny

from repro.core.llm_proxy import LLMProxy
from repro.core.rollout_client import RolloutClient
from repro.core.router import FleetRadixIndex, ProxyRouter
from repro.core.types import RolloutTask, next_uid
from repro.models import get_api
from repro.models import paged
from repro.rollout.paged_engine import PagedDecodeEngine


@pytest.fixture(scope="module")
def paged_setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _paged(api, params, **kw):
    base = dict(num_slots=4, max_total_len=64, page_size=8, prefill_chunk=8,
                eos_id=99, temperature=0.0)
    base.update(kw)
    return PagedDecodeEngine(api, params, **base)


def _fleet(api, params, n, **kw):
    engines = [_paged(api, params, **kw) for _ in range(n)]
    proxies = [LLMProxy(e, name=f"pt_proxy_{i}")
               for i, e in enumerate(engines)]
    return engines, proxies


def _task(budget, prompt, **meta):
    return RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray(prompt, np.int32),
                       max_new_tokens=budget, meta=dict(meta))


def _drain(engine):
    out = {}
    while engine.req_to_slot:
        for rid, toks, _ in engine.step():
            out[rid] = list(toks)
    return out


def _pump(proxies, router=None, max_steps=3000):
    """Lockstep drive until the fleet quiesces."""
    for _ in range(max_steps):
        if not any(p.step_once() for p in proxies):
            if all(p.num_active == 0 and p.num_pending == 0
                   for p in proxies):
                return
    raise AssertionError("fleet did not quiesce")


# ------------------------------------------------------ transfer primitive
@pytest.mark.parametrize("kv_quant", ["off", "int8"])
def test_export_import_pages_roundtrip(paged_setup, kv_quant):
    """export_pages → import_pages into fresh physical slots must preserve
    page contents bit-for-bit, scales included under int8."""
    cfg, api, params = paged_setup
    key = jax.random.PRNGKey(1)
    cache = paged.init_paged_cache(cfg, num_pages=8, page_size=4,
                                   kv_quant=kv_quant)
    fill = jax.random.normal(key, cache.k_pages.shape).astype(
        cache.k_pages.dtype)
    fill2 = jax.random.normal(jax.random.PRNGKey(2),
                              cache.v_pages.shape).astype(cache.v_pages.dtype)
    cache = cache._replace(k_pages=fill, v_pages=fill2)
    if kv_quant == "int8":
        ks = jax.random.uniform(key, cache.k_scales.shape, jnp.float32)
        vs = jax.random.uniform(key, cache.v_scales.shape, jnp.float32)
        cache = cache._replace(k_scales=ks, v_scales=vs)
    src, dst = [1, 3, 5], [2, 4, 6]
    t = paged.export_pages(cache, src)
    assert t.num_pages == 3 and t.nbytes > 0
    assert (t.k_scales is not None) == (kv_quant == "int8")
    out = paged.import_pages(cache, dst, t)
    np.testing.assert_array_equal(np.asarray(out.k_pages[:, dst]), t.k)
    np.testing.assert_array_equal(np.asarray(out.v_pages[:, dst]), t.v)
    if kv_quant == "int8":
        np.testing.assert_array_equal(np.asarray(out.k_scales[:, dst]),
                                      t.k_scales)
        np.testing.assert_array_equal(np.asarray(out.v_scales[:, dst]),
                                      t.v_scales)
    # untouched pages stay untouched
    np.testing.assert_array_equal(np.asarray(out.k_pages[:, src]),
                                  np.asarray(cache.k_pages[:, src]))


def test_import_pages_validates(paged_setup):
    cfg, api, params = paged_setup
    cache = paged.init_paged_cache(cfg, num_pages=4, page_size=2)
    t = paged.export_pages(cache, [1, 2])
    with pytest.raises(ValueError):
        paged.import_pages(cache, [1], t)          # count mismatch
    qcache = paged.init_paged_cache(cfg, num_pages=4, page_size=2,
                                    kv_quant="int8")
    with pytest.raises(ValueError):
        paged.import_pages(qcache, [1, 2], t)      # quant-mode mismatch


# -------------------------------------------- engine-level retained moves
@pytest.mark.parametrize("kv_quant", ["off", "int8"])
def test_migrate_then_decode_byte_identical(paged_setup, kv_quant):
    """The satellite bugfix contract: pages moved mid-decode carry their
    k/v scales, so migrate-then-decode is byte-identical to the
    uninterrupted run — under quantized KV too."""
    cfg, api, params = paged_setup
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5], np.int32)
    budget = 24

    ref = _paged(api, params, kv_quant=kv_quant)
    ref.add_request(0, prompt, budget)
    base = _drain(ref)[0]

    a = _paged(api, params, kv_quant=kv_quant)
    b = _paged(api, params, kv_quant=kv_quant)
    a.add_request(1, prompt, budget)
    for _ in range(7):
        a.step()
    res = a.abort(1, retain=True)
    assert res.resumable
    record = a.export_retained(1)
    assert record is not None and record["kv_quant"] == kv_quant
    assert b.import_retained(1, record)
    a.release_retained(1)
    done = list(res.tokens)
    b.resume_request(1, 2, budget - len(done))
    got = done + _drain(b)[2]
    assert got == base, "migrated decode diverged from uninterrupted run"
    assert b.total_prefill_tokens == 0, "transfer must re-prefill nothing"
    assert a.pages_transferred_out == b.pages_transferred_in > 0
    assert a.transfer_bytes_out == b.transfer_bytes_in > 0
    # one batched device op per export/import — no per-page dispatch
    assert a.transfer_device_ops == 1 and b.transfer_device_ops == 1
    a.audit_pages()
    b.audit_pages()


def test_import_retained_rejects_mismatch_and_pressure(paged_setup):
    cfg, api, params = paged_setup
    prompt = np.arange(1, 10, dtype=np.int32)
    a = _paged(api, params)
    a.add_request(1, prompt, 16)
    for _ in range(4):
        a.step()
    a.abort(1, retain=True)
    record = a.export_retained(1)
    # quant-mode mismatch
    q = _paged(api, params, kv_quant="int8")
    assert not q.import_retained(1, record)
    # rid collision
    b = _paged(api, params)
    assert b.import_retained(1, record)
    assert not b.import_retained(1, record)
    # pool pressure: a tiny pool that cannot cover the pages
    small = _paged(api, params, num_pages=3)
    assert not small.import_retained(2, record)
    a.release_retained(1)
    b.release_retained(1)
    a.audit_pages()
    b.audit_pages()
    small.audit_pages()


def test_prefix_export_import_pull(paged_setup):
    """A pulled prefix lands in the target's radix cache and the next
    admission of the same prompt prefills only the uncached tail —
    byte-identical output to a cold engine."""
    cfg, api, params = paged_setup
    prompt = np.arange(1, 21, dtype=np.int32)   # 20 tokens, page_size 8
    a = _paged(api, params, prefix_cache=True)
    b = _paged(api, params, prefix_cache=True)
    a.add_request(1, prompt, 8)
    _drain(a)
    rec = a.export_prefix(prompt)
    assert rec is not None
    # match cap: 19 matchable tokens → 2 full pages of 8
    assert rec["transfer"].num_pages == 2
    pulled = b.import_prefix(rec)
    assert pulled == 2
    # re-import dedups against what is already cached
    assert b.import_prefix(rec) == 0
    b.add_request(5, prompt, 8)
    out_warm = _drain(b)[5]
    cold = _paged(api, params, prefix_cache=True)
    cold.add_request(9, prompt, 8)
    assert out_warm == _drain(cold)[9]
    assert b.total_prefill_tokens == len(prompt) - 16, \
        "pulled pages must shrink prefill to the uncached tail"
    a.audit_pages()
    b.audit_pages()


def test_import_prefix_skips_cross_epoch(paged_setup):
    cfg, api, params = paged_setup
    prompt = np.arange(1, 21, dtype=np.int32)
    a = _paged(api, params, prefix_cache=True)
    b = _paged(api, params, prefix_cache=True)
    a.add_request(1, prompt, 8)
    _drain(a)
    rec = a.export_prefix(prompt)
    b.update_weights(params)        # b now one epoch ahead of the record
    assert b.import_prefix(rec) == 0
    b.audit_pages()


# ------------------------------------------------------- fleet radix index
def test_fleet_index_tracks_insert_evict_clear(paged_setup):
    cfg, api, params = paged_setup
    engines, proxies = _fleet(api, params, 2, prefix_cache=True)
    router = ProxyRouter(proxies, cache_aware=True)
    idx = router.fleet_index
    assert idx is not None and idx.page_size == 8
    prompt = np.arange(1, 21, dtype=np.int32)
    router.generate(_task(6, prompt), 0, lambda r: None)
    _pump(proxies)
    assert idx.inserts > 0
    router.fleet_audit()            # index == local trees
    # weight sync flushes every cache; the index must follow
    # (async staging applies inline on un-started lockstep proxies)
    assert router.update_weights_async(params).wait(30)
    assert all(not e.prefix_cache.paths() for e in engines)
    assert all(not idx.paths_for(i) for i in range(2))
    router.fleet_audit()
    # repopulate, then evict under pressure on the owning replica
    router.generate(_task(6, prompt), 1, lambda r: None)
    _pump(proxies)
    router.fleet_audit()
    for e in engines:
        if e.prefix_cache.paths():
            e.prefix_cache.evict(10 ** 6)
    router.fleet_audit()


def test_fleet_index_best_prefix_and_drop():
    idx = FleetRadixIndex()
    idx.page_size = 2
    idx.on_insert(0, ((1, 2),))
    idx.on_insert(0, ((1, 2), (3, 4)))
    idx.on_insert(1, ((1, 2),))
    best = idx.best_prefix([1, 2, 3, 4, 5])
    assert best == {0: 4, 1: 2}
    idx.on_evict(0, ((1, 2), (3, 4)))
    assert idx.best_prefix([1, 2, 3, 4]) == {0: 2, 1: 2}
    idx.drop_replica(0)
    assert idx.best_prefix([1, 2, 3, 4]) == {1: 2}
    assert idx.paths_for(0) == set()
    idx.on_clear(1)
    assert idx.best_prefix([1, 2]) == {}


# --------------------------------------------------- cache-aware placement
def test_cache_affinity_routes_to_prefix_holder(paged_setup):
    """Within the slack band the replica holding the longest cached prefix
    wins placement even when it is not least-loaded."""
    cfg, api, params = paged_setup
    engines, proxies = _fleet(api, params, 2, prefix_cache=True)
    router = ProxyRouter(proxies, cache_aware=True,
                         cache_affinity_slack=10 ** 6)
    shared = np.arange(1, 21, dtype=np.int32)
    router.generate(_task(6, shared), 0, lambda r: None)
    _pump(proxies)
    holder = next(i for i, e in enumerate(engines)
                  if e.prefix_cache.paths())
    # the same preamble again: must land on the holder despite its load
    hits_before = engines[holder].prefix_cache.hits
    router.generate(_task(6, shared), 0, lambda r: None)
    _pump(proxies)
    assert router.cache_routed >= 1
    assert engines[holder].prefix_cache.hits > hits_before
    router.fleet_audit()


def test_zero_slack_pulls_prefix_to_least_loaded(paged_setup):
    """Outside the band (slack=0 and the holder loaded) placement goes
    least-loaded and the prefix pages are pulled across first."""
    cfg, api, params = paged_setup
    engines, proxies = _fleet(api, params, 2, prefix_cache=True)
    router = ProxyRouter(proxies, cache_aware=True, cache_affinity_slack=0)
    shared = np.arange(1, 21, dtype=np.int32)
    router.generate(_task(6, shared), 0, lambda r: None)
    _pump(proxies)
    holder = next(i for i, e in enumerate(engines)
                  if e.prefix_cache.paths())
    other = 1 - holder
    # load the holder so the band test fails for it
    busy = _task(20, np.asarray([9, 8, 7], np.int32))
    router.generate(busy, 0, lambda r: None)
    router.generate(_task(6, shared), 0, lambda r: None)
    _pump(proxies)
    assert router.cache_pulls >= 1
    assert engines[other].pages_transferred_in > 0
    assert router.pages_transferred > 0 and router.transfer_bytes > 0
    # the pull shrank the second admission's prefill on the target
    assert engines[other].cache_hit_tokens > 0
    router.fleet_audit()


def test_cache_aware_off_is_least_loaded(paged_setup):
    cfg, api, params = paged_setup
    engines, proxies = _fleet(api, params, 2, prefix_cache=True)
    router = ProxyRouter(proxies)          # cache_aware defaults off
    assert router.fleet_index is None
    shared = np.arange(1, 21, dtype=np.int32)
    for _ in range(3):
        router.generate(_task(6, shared), 0, lambda r: None)
        _pump(proxies)
    assert router.cache_routed == 0 and router.cache_pulls == 0
    router.fleet_audit()


# ------------------------------------------------- churn under cache-aware
@pytest.mark.slow
@pytest.mark.timeout(300)
def test_churn_kill_drain_under_cache_aware(paged_setup):
    """Kill + drain churn with cache-aware routing on: every handle
    resolves, no page leaks, and the fleet index never drifts from the
    local trees (dead replicas dropped, flushes propagated)."""
    from repro.core.faults import wrap_fleet
    cfg, api, params = paged_setup
    engines = [_paged(api, params, prefix_cache=True, num_slots=2)
               for _ in range(3)]
    proxies = wrap_fleet([LLMProxy(e, name=f"churn_{i}")
                          for i, e in enumerate(engines)])
    router = ProxyRouter(proxies, cache_aware=True, cache_affinity_slack=64)
    client = RolloutClient(router, version_fn=lambda: 0)
    shared = np.arange(1, 17, dtype=np.int32)
    handles = []

    def submit(n):
        for k in range(n):
            suffix = np.asarray([22 + (k % 7)], np.int32)
            handles.append(client.submit(
                _task(6, np.concatenate([shared, suffix])), version=0))

    submit(6)
    for _ in range(40):
        any(p.step_once() for p in proxies)
    router.drain(0)
    submit(4)
    for _ in range(40):
        any(p.step_once() for p in proxies)
    proxies[2].kill()
    router.probe_health()
    submit(4)
    for _ in range(4000):
        # step BEFORE checking: freshly submitted work sits in command
        # queues where num_active/num_pending cannot see it yet
        stepped = any(p.step_once() for p in proxies
                      if not p._dead.is_set())
        if not stepped and not router.num_active and not router.num_pending:
            break
    else:
        raise AssertionError("churned fleet did not quiesce")
    for h in handles:
        h.result(timeout=30)
    for _ in range(20):
        any(p.step_once() for p in proxies if not p._dead.is_set())
    router.fleet_audit()
