"""End-to-end system behaviour: the full async architecture wired together
(engine + proxy + buffer + producer + controller + trainer)."""
import time

import numpy as np
import pytest

from conftest import tiny
from repro.envs.sim_envs import GridTargetEnv
from repro.launch.pipeline import (PipelineSettings, build_agentic_pipeline,
                                   build_rlvr_pipeline)

pytestmark = [pytest.mark.slow, pytest.mark.timeout(300)]  # integration tier

MODEL = tiny("qwen3-4b", vocab_size=32)


def settings(**kw):
    base = dict(async_generation_ratio=1, rollout_batch_size=8,
                num_return_sequences_in_group=4, num_slots=8,
                max_new_tokens=6, max_seq_len=32, learning_rate=1e-3)
    base.update(kw)
    return PipelineSettings(**base)


@pytest.mark.parametrize("alpha", [0, 1, 2])
def test_rlvr_pipeline_staleness_bounded(alpha):
    pipe = build_rlvr_pipeline(MODEL, settings(async_generation_ratio=alpha))
    stats = pipe.run(num_steps=3, timeout=240)
    assert len(stats) == 3
    assert all(s.staleness_max <= alpha for s in stats)
    assert pipe.buffer.total_consumed == 3 * 8


def test_rlvr_pipeline_with_slot_engine_forced():
    """The seed slot engine stays selectable via settings and the full
    training loop behaves identically (paged is merely the default)."""
    from repro.rollout.engine import DecodeEngine

    pipe = build_rlvr_pipeline(MODEL, settings(rollout_engine="slot"))
    assert isinstance(pipe.engine, DecodeEngine)
    stats = pipe.run(num_steps=2, timeout=240)
    assert len(stats) == 2
    assert all(s.staleness_max <= 1 for s in stats)


def test_rlvr_sync_mode_never_stale():
    pipe = build_rlvr_pipeline(MODEL, settings(async_generation_ratio=0))
    stats = pipe.run(num_steps=2, timeout=240)
    assert all(s.staleness_max == 0 for s in stats)
    # sync mode suspends generation during training: nothing was produced
    # under in-between weights
    assert pipe.controller.sync_mode


def test_rlvr_all_variants_run():
    for variant in ("tis", "topr", "decoupled_ppo"):
        pipe = build_rlvr_pipeline(
            MODEL, settings(pg_variant=variant, rollout_batch_size=4,
                            num_return_sequences_in_group=2))
        stats = pipe.run(num_steps=2, timeout=240)
        assert len(stats) == 2


def test_samples_have_behaviour_logprobs_and_rewards():
    collected = []
    pipe = build_rlvr_pipeline(MODEL, settings())
    orig = pipe.trainer.train_on_samples

    def spy(samples):
        collected.extend(samples)
        return orig(samples)

    pipe.controller.train_fn = spy
    pipe.run(num_steps=2, timeout=240)
    assert collected
    for s in collected:
        assert s.reward is not None
        assert len(np.asarray(s.logprobs)) == len(np.asarray(s.response_tokens))
        assert np.all(np.asarray(s.logprobs) <= 0.0)


def test_agentic_pipeline_end_to_end():
    cfg = tiny("qwen3-4b", vocab_size=256)
    s = settings(rollout_batch_size=6, max_new_tokens=3, max_seq_len=64,
                 async_generation_ratio=1)
    pipe = build_agentic_pipeline(cfg, s, make_env=lambda i: GridTargetEnv(i),
                                  num_env_groups=4, group_size=3,
                                  max_env_steps=6)
    stats = pipe.run(num_steps=2, timeout=240)
    assert len(stats) == 2
    assert all(s_.staleness_max <= 1 for s_ in stats)


def test_weight_sync_propagates_to_engine():
    pipe = build_rlvr_pipeline(MODEL, settings(rollout_batch_size=4,
                                               num_return_sequences_in_group=2,
                                               learning_rate=5e-3))
    w0 = jax_leaves(pipe.engine.params)
    pipe.run(num_steps=2, timeout=240)
    # after weight sync the engine holds EXACTLY the trainer's current
    # params (same buffers), not the initial ones
    w1 = jax_leaves(pipe.engine.params)
    trainer_now = jax_leaves(pipe.trainer.get_weights())
    assert all(a is b for a, b in zip(w1, trainer_now, strict=True))
    assert not all(a is b for a, b in zip(w0, w1, strict=True))


def jax_leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


def test_abort_resume_preserves_partial_response():
    """ABORT -> resume: the partial response survives and the published
    sample stitches tokens+logprobs back together (no waste).  The
    continuation is owned by the RolloutClient — the producer is a thin
    handle consumer and never sees the intermediate legs."""
    import numpy as np

    from repro.core.llm_proxy import LLMProxy
    from repro.core.sample_buffer import SampleBuffer
    from repro.core.scheduler import RolloutProducer
    from test_proxy_engine import FakeEngine

    eng = FakeEngine(slots=1)
    proxy = LLMProxy(eng).start()
    buffer = SampleBuffer(batch_size=1, alpha=3)
    prompt = np.asarray([7, 8], np.int32)
    producer = RolloutProducer(
        proxy, buffer, iter([(0, prompt)]), group_size=1, max_new_tokens=40,
        reward_fn=lambda s: 1.0)
    producer.start()
    import time
    time.sleep(0.012)             # let a few (not all 40) tokens decode
    proxy.abort_stale(min_version=99)  # force ABORT of the in-flight request
    batch = buffer.get_batch(1, timeout=10)
    producer.stop()
    proxy.stop()
    if proxy.requests_aborted == 0:
        import pytest
        pytest.skip("scheduler raced: request completed before the abort")
    s = batch[0]
    # FakeEngine emits 0,1,2,...: a resumed request restarts its counter, so
    # a successful resume shows the stitched prefix then a fresh 0,1,2,...
    toks = list(np.asarray(s.response_tokens))
    assert len(toks) == len(np.asarray(s.logprobs)) == 40
    assert toks[0] == 0 and 0 in toks[1:], "expected stitched partial + resume"
    assert list(np.asarray(s.prompt_tokens)) == [7, 8]  # original prompt only
    assert len(s.meta["legs"]) >= 2, "per-leg version tags on the sample"


def test_multi_proxy_fleet():
    """Two engines + two LLMProxies sharing one SampleBuffer: the controller
    weight-syncs the whole fleet and freshness holds across both."""
    import jax
    import numpy as np

    from repro.core.async_controller import AsyncController
    from repro.core.llm_proxy import LLMProxy
    from repro.core.sample_buffer import SampleBuffer
    from repro.core.scheduler import RolloutProducer
    from repro.algos import LossConfig
    from repro.data.dataset import ArithmeticTask, EOS
    from repro.models import get_api
    from repro.rewards.verifier import ArithmeticVerifier
    from repro.rollout.engine import DecodeEngine
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import HostTrainer, TrainerConfig

    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    task = ArithmeticTask(seed=0)
    trainer = HostTrainer(api, jax.random.PRNGKey(0), LossConfig("tis"),
                          OptConfig(learning_rate=1e-3, warmup_steps=2),
                          TrainerConfig(max_seq_len=32, group_size=2))
    buffer = SampleBuffer(batch_size=8, alpha=1)
    proxies, producers = [], []
    for i in range(2):
        eng = DecodeEngine(api, trainer.get_weights(), num_slots=4,
                           max_total_len=32, eos_id=EOS, seed=i)
        proxy = LLMProxy(eng, name=f"proxy{i}").start()
        producer = RolloutProducer(
            proxy, buffer, task.prompt_stream(group_size=2), group_size=2,
            max_new_tokens=6, reward_fn=ArithmeticVerifier(task))
        producer.start()
        proxies.append(proxy)
        producers.append(producer)

    controller = AsyncController(buffer, proxies, trainer.train_on_samples,
                                 trainer.get_weights, alpha=1)
    try:
        stats = controller.train(3, timeout=240)
    finally:
        for pr in producers:
            pr.stop()
        buffer.close()
        for p in proxies:
            p.stop()
    assert len(stats) == 3
    assert all(s.staleness_max <= 1 for s in stats)
    # the fleet produced the batches (which proxy wins the race is
    # load-dependent) and BOTH received every weight update
    assert sum(p.requests_completed for p in proxies) >= 3 * 8
    w = jax_leaves(trainer.get_weights())
    for p in proxies:
        assert all(a is b for a, b in zip(jax_leaves(p.engine.params), w,
                                      strict=True))
