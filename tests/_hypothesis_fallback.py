"""Minimal stand-in for `hypothesis` when the real package is absent.

CI installs the genuine library via the ``[test]`` extra and this module is
never imported.  In bare environments (no network / no extra), conftest
registers this as ``hypothesis`` so the property-based test modules still
collect and run: ``@given`` degrades to a deterministic pseudo-random sweep
of ``max_examples`` draws per strategy — far weaker than real shrinking
Hypothesis, but it executes the same properties.

Only the surface these tests use is implemented: ``given``, ``settings``,
``strategies.integers/floats/sampled_from``.
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


class _DataObject:
    """Interactive draws (`data.draw(strategy)`) share the test's stream."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.example(self._rng)


def data() -> _Strategy:
    return _Strategy(lambda rng: _DataObject(rng))


def randoms(use_true_random: bool = True) -> _Strategy:
    return _Strategy(lambda rng: random.Random(rng.getrandbits(64)))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.data = data
strategies.randoms = randoms

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Records max_examples on the function for `given` to pick up, whether
    applied above or below it in the decorator stack."""
    def deco(fn):
        if getattr(fn, "_fallback_given", False):
            fn._max_examples = max_examples
        else:
            fn._pending_max_examples = max_examples
        return fn
    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        # positional @given args fill the RIGHTMOST parameters (as in real
        # hypothesis); bind them by NAME so pytest-passed fixture kwargs
        # can't collide with them.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        pos_names = [p.name for p in params[len(params) - len(arg_strats):]] \
            if arg_strats else []

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_pending_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            # deterministic per-test stream so failures reproduce
            rng = random.Random(fn.__name__)
            for _ in range(n):
                drawn_kw = {name: s.example(rng)
                            for name, s in zip(pos_names, arg_strats,
                                               strict=True)}
                drawn_kw.update((k, s.example(rng))
                                for k, s in kw_strats.items())
                fn(*args, **kwargs, **drawn_kw)
        wrapper._fallback_given = True
        # hide strategy-filled parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        remaining = [p for p in params
                     if p.name not in kw_strats and p.name not in pos_names]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper
    return deco


def install(sys_modules) -> None:
    """Register this module as `hypothesis` (+ `.strategies`)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__is_fallback__ = True
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = strategies
