"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

pytestmark = [pytest.mark.kernels, pytest.mark.timeout(300)]

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 128, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_sweep(b, h, kv, s, d, dtype, window):
    q = jax.random.normal(KEY, (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, kv, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_flash_attention_blocks_dont_matter():
    b, h, kv, s, d = 1, 2, 2, 256, 64
    q = jax.random.normal(KEY, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, kv, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, kv, s, d))
    o1 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    o2 = flash_attention(q, k, v, block_q=128, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,kv,s,d", [
    (2, 8, 2, 512, 64),
    (3, 4, 4, 256, 128),
    (1, 8, 1, 1024, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, kv, s, d, dtype):
    q = jax.random.normal(KEY, (b, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, d), dtype)
    lengths = jnp.asarray(np.random.default_rng(0).integers(1, s + 1, b), jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=128, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def _random_block_tables(rng, b, pages_per_seq, num_pages, page_size):
    """Random non-overlapping page assignments (page 0 = garbage, unused)."""
    bt = np.full((b, pages_per_seq), -1, np.int32)
    perm = rng.permutation(np.arange(1, num_pages))
    i, lengths = 0, []
    for bi in range(b):
        n = int(rng.integers(1, pages_per_seq + 1))
        bt[bi, :n] = perm[i:i + n]
        i += n
        lengths.append(int(rng.integers(1, n * page_size + 1)))
    return jnp.asarray(bt), jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("b,h,kv,d,page_size,pages_per_seq", [
    (2, 8, 2, 64, 16, 4),    # GQA
    (3, 4, 4, 64, 32, 2),    # MHA
    (1, 8, 1, 128, 16, 6),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(b, h, kv, d, page_size, pages_per_seq,
                                      dtype):
    num_pages = 1 + b * pages_per_seq
    q = jax.random.normal(KEY, (b, h, d), dtype)
    kp = jax.random.normal(jax.random.fold_in(KEY, 1),
                           (num_pages, page_size, kv, d), dtype)
    vp = jax.random.normal(jax.random.fold_in(KEY, 2),
                           (num_pages, page_size, kv, d), dtype)
    bt, lengths = _random_block_tables(np.random.default_rng(0), b,
                                       pages_per_seq, num_pages, page_size)
    out = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    expected = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_paged_decode_attention_matches_dense_decode():
    """A contiguous identity block table must reproduce the dense decode
    oracle: paging is pure bookkeeping, not different math."""
    b, h, kv, d, page_size, pages_per_seq = 2, 4, 2, 64, 16, 4
    s = page_size * pages_per_seq
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, d))
    q = jax.random.normal(KEY, (b, h, d))
    lengths = jnp.asarray([s, 37], jnp.int32)
    # identity layout: request bi's page p is physical page 1 + bi*P + p
    bt = jnp.arange(1, 1 + b * pages_per_seq, dtype=jnp.int32).reshape(b, -1)
    kp = jnp.concatenate([jnp.zeros((1, page_size, kv, d)),
                          k.reshape(b * pages_per_seq, page_size, kv, d)])
    vp = jnp.concatenate([jnp.zeros((1, page_size, kv, d)),
                          v.reshape(b * pages_per_seq, page_size, kv, d)])
    out = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_softcap():
    b, h, kv, d, page_size, pages_per_seq = 2, 4, 2, 64, 16, 3
    num_pages = 1 + b * pages_per_seq
    q = jax.random.normal(KEY, (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(KEY, 1),
                           (num_pages, page_size, kv, d))
    vp = jax.random.normal(jax.random.fold_in(KEY, 2),
                           (num_pages, page_size, kv, d))
    bt, lengths = _random_block_tables(np.random.default_rng(1), b,
                                       pages_per_seq, num_pages, page_size)
    out = paged_decode_attention(q, kp, vp, bt, lengths, softcap=30.0,
                                 interpret=True)
    expected = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths,
                                              softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


def _quantize_pages(x):
    """Per-(token, kv-head) symmetric int8 pages + fp32 scales, the same
    scheme ``paged.quantize_kv`` writes (scales laid out (N, page, kv))."""
    xf = np.asarray(x, np.float32)
    amax = np.abs(xf).max(-1)
    scale = np.maximum(amax, 1e-12) / 127.0
    codes = np.clip(np.round(xf / scale[..., None]), -127, 127).astype(np.int8)
    return jnp.asarray(codes), jnp.asarray(scale, jnp.float32)


@pytest.mark.slow
@pytest.mark.parametrize("b,h,kv,d,page_size,pages_per_seq", [
    (2, 8, 2, 64, 16, 4),    # GQA
    (3, 4, 4, 64, 32, 2),    # MHA
    (1, 8, 1, 128, 16, 6),   # MQA
])
def test_paged_decode_attention_int8_sweep(b, h, kv, d, page_size,
                                           pages_per_seq):
    """Quantized kernel (dequant-in-kernel) vs the quantized jnp oracle."""
    num_pages = 1 + b * pages_per_seq
    q = jax.random.normal(KEY, (b, h, d))
    kp_f = jax.random.normal(jax.random.fold_in(KEY, 1),
                             (num_pages, page_size, kv, d))
    vp_f = jax.random.normal(jax.random.fold_in(KEY, 2),
                             (num_pages, page_size, kv, d))
    kp, ks = _quantize_pages(kp_f)
    vp, vs = _quantize_pages(vp_f)
    bt, lengths = _random_block_tables(np.random.default_rng(0), b,
                                       pages_per_seq, num_pages, page_size)
    out = paged_decode_attention(q, kp, vp, bt, lengths,
                                 k_scales=ks, v_scales=vs, interpret=True)
    expected = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths,
                                              k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)
    # and the whole quantized path must track the fp oracle within int8 error
    fp = ref.paged_decode_attention_ref(q, kp_f, vp_f, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fp),
                               rtol=0.05, atol=0.05)


@pytest.mark.slow
def test_paged_decode_attention_int8_softcap():
    b, h, kv, d, page_size, pages_per_seq = 2, 4, 2, 64, 16, 3
    num_pages = 1 + b * pages_per_seq
    q = jax.random.normal(KEY, (b, h, d))
    kp, ks = _quantize_pages(jax.random.normal(
        jax.random.fold_in(KEY, 1), (num_pages, page_size, kv, d)))
    vp, vs = _quantize_pages(jax.random.normal(
        jax.random.fold_in(KEY, 2), (num_pages, page_size, kv, d)))
    bt, lengths = _random_block_tables(np.random.default_rng(1), b,
                                       pages_per_seq, num_pages, page_size)
    out = paged_decode_attention(q, kp, vp, bt, lengths, k_scales=ks,
                                 v_scales=vs, softcap=30.0, interpret=True)
    expected = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths,
                                              k_scales=ks, v_scales=vs,
                                              softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_window():
    b, h, kv, s, d = 2, 4, 2, 512, 64
    q = jax.random.normal(KEY, (b, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, d))
    lengths = jnp.asarray([512, 300], jnp.int32)
    out = decode_attention(q, k, v, lengths, window=128, block_k=128, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, lengths, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,t,h,d", [(2, 64, 3, 32), (1, 128, 2, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_sweep(b, t, h, d, dtype):
    mk = lambda i, scale=0.5: (jax.random.normal(
        jax.random.fold_in(KEY, i), (b, t, h, d)) * scale).astype(dtype)
    r, k, v = mk(1), mk(2), mk(3)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 4),
                                         (b, t, h, d))).astype(dtype)
    u = (jax.random.normal(jax.random.fold_in(KEY, 5), (h, d)) * 0.1)
    s0 = jax.random.normal(jax.random.fold_in(KEY, 6), (b, h, d, d)) * 0.1
    y, s = rwkv6_scan(r, k, v, w, u, s0, block_t=32, interpret=True)
    yr, sr = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), **_tol(dtype))


def test_rwkv6_chunking_equivalence():
    """State carry across time chunks must be exact."""
    b, t, h, d = 1, 64, 2, 32
    mk = lambda i: jax.random.normal(jax.random.fold_in(KEY, i), (b, t, h, d)) * 0.5
    r, k, v = mk(1), mk(2), mk(3)
    w = jax.nn.sigmoid(mk(4))
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (h, d)) * 0.1
    s0 = jnp.zeros((b, h, d, d))
    y1, s1 = rwkv6_scan(r, k, v, w, u, s0, block_t=16, interpret=True)
    y2, s2 = rwkv6_scan(r, k, v, w, u, s0, block_t=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,t,w", [(2, 128, 96), (1, 256, 64), (3, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(b, t, w, dtype):
    a = jax.nn.sigmoid(jax.random.normal(KEY, (b, t, w))).astype(dtype)
    bb = (jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, w)) * 0.5).astype(dtype)
    h0 = jax.random.normal(jax.random.fold_in(KEY, 2), (b, w)) * 0.5
    hs, hl = rglru_scan(a, bb, h0, block_t=32, block_w=32, interpret=True)
    hsr, hlr = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hsr), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), **_tol(dtype))


def test_rglru_state_continuation():
    """Scanning [0:T] == scanning [0:T/2] then [T/2:T] with carried state."""
    b, t, w = 1, 64, 32
    a = jax.nn.sigmoid(jax.random.normal(KEY, (b, t, w)))
    bb = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, w)) * 0.5
    h0 = jnp.zeros((b, w))
    full, _ = rglru_scan(a, bb, h0, block_t=32, block_w=32, interpret=True)
    h1, hmid = rglru_scan(a[:, :32], bb[:, :32], h0, block_t=32, block_w=32,
                          interpret=True)
    h2, _ = rglru_scan(a[:, 32:], bb[:, 32:], hmid, block_t=32, block_w=32,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(full),
                               np.concatenate([h1, h2], axis=1),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_non_causal():
    """Encoder-style dense attention exercises the unguarded tile path."""
    b, h, kv, s, d = 1, 2, 2, 128, 64
    q = jax.random.normal(KEY, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, kv, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, kv, s, d))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_window_prunes_but_matches():
    """Narrow window: most tiles are pruned at block level; numerics exact."""
    b, h, kv, s, d = 1, 2, 1, 512, 64
    q = jax.random.normal(KEY, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, kv, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, kv, s, d))
    out = flash_attention(q, k, v, causal=True, window=32, block_q=64,
                          block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)
