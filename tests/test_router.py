"""Multi-replica rollout fleet: ProxyRouter queue scheduling, GRPO-group /
session co-location, cross-replica abort→resume migration, fleet-wide
weight sync, and the fleet-aware AsyncController/pipeline surface.

Acceptance-criteria coverage:

* greedy parity — a 2-replica fleet produces byte-identical outputs to the
  single-proxy path (placement is an optimization, never semantics);
* GRPO groups land on ONE replica (COW prefix sharing is per-replica);
* a cross-replica resume after a weight sync resolves its handle exactly
  once with correctly stitched, version-tagged legs;
* ``audit_pages`` is clean on every replica after a churn sweep.
"""
import threading
import time

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.async_controller import AsyncController
from repro.core.llm_proxy import LLMProxy
from repro.core.rollout_client import RolloutClient
from repro.core.router import MultiEvent, ProxyRouter
from repro.core.sample_buffer import SampleBuffer
from repro.core.scheduler import RolloutProducer, expand_tasks
from repro.core.types import GenerationResult, RolloutTask, next_uid
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine


class FakeEngine:
    """Deterministic engine: each request emits 0,1,2,... one per step.
    Supports abort-with-retain + resume so the continuation/migration
    machinery can be exercised without a real model."""

    supports_retain = True

    def __init__(self, slots=2, max_total_len=10_000, step_sleep=0.001):
        self.slots = slots
        self.max_total_len = max_total_len
        self.step_sleep = step_sleep
        self.active = {}
        self.retained = {}
        self.added = []              # request ids seen by add_request
        self.resumed = []
        self.update_count = 0

    @property
    def num_free_slots(self):
        return self.slots - len(self.active)

    def add_request(self, rid, prompt, max_new):
        assert self.num_free_slots > 0
        self.added.append(rid)
        self.active[rid] = {"left": int(max_new), "toks": []}

    def abort(self, rid, retain=False):
        st = self.active.pop(rid)
        if retain:
            self.retained[rid] = st
        return GenerationResult(
            request_id=rid, task=None,
            tokens=np.asarray(st["toks"], np.int32),
            logprobs=np.zeros(len(st["toks"]), np.float32),
            version_started=-1, aborted=True, partial=True,
            resumable=retain)

    def can_resume(self, rid, max_new):
        return rid in self.retained and self.num_free_slots > 0

    def resume_request(self, old_rid, new_rid, max_new):
        del self.retained[old_rid]
        self.resumed.append(new_rid)
        self.active[new_rid] = {"left": int(max_new), "toks": []}

    def release_retained(self, rid):
        self.retained.pop(rid, None)

    def peek_tokens(self, rid, start=0):
        st = self.active.get(rid)
        return [] if st is None else list(st["toks"][start:])

    def step(self):
        if self.step_sleep:
            time.sleep(self.step_sleep)
        done = []
        for rid, st in list(self.active.items()):
            st["toks"].append(len(st["toks"]))
            st["left"] -= 1
            if st["left"] <= 0:
                done.append((rid, np.asarray(st["toks"], np.int32),
                             np.zeros(len(st["toks"]), np.float32)))
                del self.active[rid]
        return done

    def update_weights(self, params):
        self.update_count += 1


def _task(n=3, prompt=(1, 2), gid=-1, meta=None):
    return RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray(prompt, np.int32),
                       max_new_tokens=n, group_id=gid, meta=dict(meta or {}))


def _fake_fleet(n=2, **kw):
    engines = [FakeEngine(**kw) for _ in range(n)]
    proxies = [LLMProxy(e, name=f"p{i}") for i, e in enumerate(engines)]
    return engines, proxies, ProxyRouter(proxies)


# ---------------------------------------------------------------- routing
def test_least_loaded_placement():
    """Queue scheduling: each submission lands on the replica with the
    least outstanding decode tokens at that moment."""
    engines, proxies, router = _fake_fleet(slots=8)
    client = RolloutClient(router)
    h_long = client.submit(_task(100, prompt=[1] * 4))    # load 104 -> p0
    h_short = client.submit(_task(4, prompt=[1] * 4))     # load 8   -> p1
    h_next = client.submit(_task(4, prompt=[1] * 4))      # p1 (8+8 < 104)
    assert proxies[0].load() == 104
    assert proxies[1].load() == 16
    router.start()
    assert h_short.result(10).tokens is not None
    assert h_next.result(10).tokens is not None
    h_long.abort()
    h_long.result(10)
    router.stop()
    assert set(engines[1].added) >= {h_short.task.task_id,
                                     h_next.task.task_id}
    assert router.routed == 3
    assert router.load() == 0, "all load returned on completion/abort"


def test_load_accounting_lifecycle():
    """load() rises at submit and returns to zero after completion, abort
    (active AND never-admitted pending), and retained-release."""
    eng = FakeEngine(slots=1)
    proxy = LLMProxy(eng)
    client = RolloutClient(proxy)
    h1 = client.submit(_task(5, prompt=[1, 2, 3]))
    h2 = client.submit(_task(7, prompt=[1, 2, 3, 4]))
    assert proxy.load() == (3 + 5) + (4 + 7)
    proxy.start()
    h1.result(10)
    h2.abort()                         # may be active or pending when it lands
    h2.result(10)
    proxy.stop()
    assert proxy.load() == 0


def test_group_colocation():
    """All G candidates of a GRPO group land on ONE replica; distinct
    groups spread across the fleet."""
    engines, proxies, router = _fake_fleet(n=2, slots=8)
    router.start()
    client = RolloutClient(router)
    g1 = client.submit_group(expand_tasks(0, np.asarray([1, 2], np.int32),
                                          3, 20, replicate=True))
    g2 = client.submit_group(expand_tasks(1, np.asarray([1, 2], np.int32),
                                          3, 20, replicate=True))
    g1.results(10), g2.results(10)
    router.stop()
    on1 = {i for i, e in enumerate(engines)
           if any(h.task.task_id in e.added for h in g1.handles)}
    on2 = {i for i, e in enumerate(engines)
           if any(h.task.task_id in e.added for h in g2.handles)}
    assert len(on1) == 1 and len(on2) == 1, "each group on exactly one replica"
    assert on1 != on2, "groups spread across the fleet"


def test_num_return_sequences_group_colocates():
    """The non-replicated group encoding routes as ONE placement too."""
    engines, proxies, router = _fake_fleet(n=2, slots=8)
    router.start()
    client = RolloutClient(router)
    task, = expand_tasks(0, np.asarray([1, 2], np.int32), 3, 4,
                         replicate=False)
    gh = client.submit(task)
    results = gh.results(10)
    router.stop()
    assert len(results) == 3
    on = {i for i, e in enumerate(engines)
          if any(r.request_id in e.added for r in results)}
    assert len(on) == 1


def test_session_turns_follow_replica():
    """Agentic session turns stay co-located (the radix cache holding the
    conversation history is per-replica)."""
    engines, proxies, router = _fake_fleet(n=2, slots=4)
    router.start()
    client = RolloutClient(router)
    # skew the load so the session would OTHERWISE prefer replica 1 later
    sess = client.session(max_new_tokens=3, context_mode="turn")
    r1 = sess.turn(np.asarray([5, 6], np.int32)).result(10)
    ballast = client.submit(_task(500, prompt=[1] * 8))   # skews the loads
    r2 = sess.turn(np.asarray([7], np.int32)).result(10)
    r3 = sess.turn(np.asarray([8], np.int32)).result(10)
    ballast.abort()
    ballast.result(10)
    router.stop()
    turn_rids = {r.request_id for r in (r1, r2, r3)}
    on = {i for i, e in enumerate(engines) if turn_rids & set(e.added)}
    assert len(on) == 1, f"session turns split across replicas: {on}"
    assert turn_rids <= set(engines[on.pop()].added)


def test_can_accept_admission_feedback():
    """A replica whose engine can never fit the request is skipped —
    queued there it would block forever."""
    small = FakeEngine(slots=4, max_total_len=8)
    big = FakeEngine(slots=4, max_total_len=10_000)
    proxies = [LLMProxy(small, name="small"), LLMProxy(big, name="big")]
    router = ProxyRouter(proxies).start()
    client = RolloutClient(router)
    h = client.submit(_task(50, prompt=[1] * 6))   # 56 tokens > small's 8
    res = h.result(10)
    router.stop()
    assert not res.aborted and len(res.tokens) == 50
    assert h.task.task_id in big.added and h.task.task_id not in small.added
    with pytest.raises(ValueError, match="no replica"):
        ProxyRouter([LLMProxy(FakeEngine(max_total_len=4))]).generate(
            _task(50, prompt=[1] * 6), 0, lambda r: None)


# ----------------------------------------------------- migration (fakes)
def test_drain_migrates_resume_to_other_replica():
    """A retained abort victim on a DRAINING replica migrates: pages are
    released at home, the concatenated re-prefill lands on the other
    replica, and the handle resolves exactly once with stitched legs."""
    engines, proxies, router = _fake_fleet(n=2, slots=2)
    router.start()
    versions = [0]
    client = RolloutClient(router, version_fn=lambda: versions[0])
    h = client.submit(_task(40, prompt=[1, 2, 3]), version=0)
    fired = []
    h.add_done_callback(fired.append)
    deadline = time.monotonic() + 10
    while not any(e.active for e in engines) and time.monotonic() < deadline:
        time.sleep(0.005)
    home = 0 if engines[0].active else 1
    router.drain(home)
    versions[0] = 1
    router.abort_stale(min_version=1, retain=True)
    res = h.result(10)
    time.sleep(0.05)
    router.stop()
    assert len(fired) == 1 and fired[0] is res, "resolves exactly once"
    assert not res.aborted and len(res.tokens) == 40
    assert client.migrations == 1 and router.migrations == 1
    assert client.resumes == 0
    assert res.legs[0][0] == 0 and res.legs[-1][0] == 1, \
        "legs carry their policy versions"
    assert sum(n for _, n in res.legs) == 40
    assert not engines[home].retained, "parked pages released at home"
    other = 1 - home
    assert engines[other].added, "continuation re-prefilled on the target"


def test_resume_stays_home_when_balanced():
    """Without drain/overload, a retained abort resumes IN PLACE (page
    re-attach — the cheap path), never migrating."""
    engines, proxies, router = _fake_fleet(n=2, slots=2)
    router.start()
    client = RolloutClient(router, version_fn=lambda: 1)
    h = client.submit(_task(30, prompt=[1, 2]), version=0)
    deadline = time.monotonic() + 10
    while not any(e.active for e in engines) and time.monotonic() < deadline:
        time.sleep(0.005)
    home = 0 if engines[0].active else 1
    router.abort_stale(min_version=1, retain=True)
    res = h.result(10)
    router.stop()
    assert not res.aborted and len(res.tokens) == 30
    assert client.resumes == 1 and client.migrations == 0
    assert engines[home].resumed, "resumed on the home replica"


def test_migration_without_viable_target_falls_back_to_in_place_resume():
    """When no other replica can take the grown concatenated prompt, the
    migration attempt must NOT release the parked pages — the continuation
    falls back to resuming in place (even on a draining replica)."""
    big = FakeEngine(slots=2, max_total_len=10_000)
    small = FakeEngine(slots=2, max_total_len=4)   # can never take the concat
    proxies = [LLMProxy(big, name="big"), LLMProxy(small, name="small")]
    router = ProxyRouter(proxies).start()
    client = RolloutClient(router, version_fn=lambda: 1)
    h = client.submit(_task(30, prompt=[1] * 6), version=0)   # -> big
    deadline = time.monotonic() + 10
    while not big.active and time.monotonic() < deadline:
        time.sleep(0.005)
    router.drain(0)                        # force a migration attempt
    router.abort_stale(min_version=1, retain=True)
    res = h.result(10)
    router.stop()
    assert not res.aborted and len(res.tokens) == 30
    assert client.migrations == 0 and client.resumes == 1
    assert big.resumed, "fell back to the in-place page re-attach"
    assert not big.retained and not small.added


def test_prefer_resume_overload_threshold():
    """prefer_resume flips only past migrate_factor * min_load + margin."""
    engines, proxies, router = _fake_fleet(n=2, slots=8)
    router.migrate_factor = 1.0
    router.migrate_margin_tokens = 0
    client = RolloutClient(router)          # not started: loads are static
    h_home = client.submit(_task(100, prompt=[1] * 4))   # p0, load 104
    rid = h_home.task.task_id
    assert router.prefer_resume(rid, 10) is False, \
        "home carries 104 outstanding tokens vs 0: migrate"
    client.submit(_task(300, prompt=[1] * 4))            # p1, load 304
    assert router.prefer_resume(rid, 10) is True, \
        "home is now the less-loaded replica: resume in place"


# --------------------------------------------------- fleet weight sync
def test_fleet_staged_sync_acks_all_replicas():
    engines, proxies, router = _fake_fleet(n=3, slots=2)
    ev = router.update_weights_async("w")
    assert isinstance(ev, MultiEvent)
    assert ev.wait(5) and ev.is_set()
    assert all(e.update_count == 1 for e in engines)
    assert router.staged_weight_updates == 3


def test_multi_event_partial_not_set():
    e1, e2 = threading.Event(), threading.Event()
    ev = MultiEvent([e1, e2])
    e1.set()
    assert not ev.wait(0.05) and not ev.is_set()
    e2.set()
    assert ev.wait(1) and ev.is_set()


def test_controller_fleet_sync_and_stats():
    """AsyncController over a 2-replica fleet: overlapped sync stages on
    every replica before the version advances; StepStats records loss +
    fleet queue depth + per-replica active counts; the ack timeout is
    plumbed."""
    engines, proxies, router = _fake_fleet(n=2, slots=8)
    router.start()
    buf = SampleBuffer(batch_size=4, alpha=1)

    def prompts():
        i = 0
        while True:
            yield i, np.asarray([1, 2], np.int32)
            i += 1

    prod = RolloutProducer(router, buf, prompts(), group_size=1,
                           max_new_tokens=3, reward_fn=lambda s: 1.0)
    prod.start()
    ctrl = AsyncController(buf, proxies, lambda batch: {"loss": 1.5},
                           lambda: "weights", alpha=1,
                           weight_sync="overlapped",
                           weight_sync_timeout=17.0)
    try:
        stats = ctrl.train(3, timeout=60)
    finally:
        prod.stop()
        buf.close()
        router.stop()
    assert ctrl.weight_sync_timeout == 17.0
    assert len(stats) == 3
    assert all(s.loss == 1.5 for s in stats), "train_fn metrics recorded"
    assert all(len(s.active_per_replica) == 2 for s in stats)
    assert all(s.queue_depth >= 0 for s in stats)
    assert all(e.update_count == 3 for e in engines), \
        "every replica acked every staged sync"
    assert router.suspend_count == 0
    # both replicas actually served work under queue scheduling
    assert all(p.requests_completed > 0 for p in proxies)


# ------------------------------------------------------ real paged fleet
@pytest.fixture(scope="module")
def paged_setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _paged(api, params, **kw):
    base = dict(num_slots=4, max_total_len=64, page_size=8, prefill_chunk=8,
                eos_id=99, temperature=0.0)
    base.update(kw)
    return PagedDecodeEngine(api, params, **base)


def _paged_fleet(api, params, n, **kw):
    engines = [_paged(api, params, **kw) for _ in range(n)]
    proxies = [LLMProxy(e, name=f"paged_proxy_{i}")
               for i, e in enumerate(engines)]
    return engines, proxies, ProxyRouter(proxies)


@pytest.mark.timeout(240)
def test_fleet_greedy_parity_n2_vs_n1(paged_setup):
    """Acceptance: a 2-replica fleet is byte-identical to the single-proxy
    path under greedy decoding — routing is an optimization, never a
    semantic change."""
    cfg, api, params = paged_setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 30, n).astype(np.int32)
               for n in (4, 6, 9, 12, 5, 8)]

    def run_single():
        eng = _paged(api, params, num_slots=6)
        proxy = LLMProxy(eng).start()
        client = RolloutClient(proxy)
        handles = [client.submit(_task(8, p)) for p in prompts]
        out = [list(h.result(60).tokens) for h in handles]
        proxy.stop()
        eng.audit_pages()
        return out

    def run_fleet():
        engines, proxies, router = _paged_fleet(api, params, 2, num_slots=3)
        router.start()
        client = RolloutClient(router)
        handles = [client.submit(_task(8, p)) for p in prompts]
        out = [list(h.result(60).tokens) for h in handles]
        router.stop()
        for e in engines:
            e.audit_pages()
        # queue scheduling actually used both replicas
        assert all(p.requests_completed > 0 for p in proxies)
        return out

    assert run_single() == run_fleet()


@pytest.mark.timeout(240)
def test_cross_replica_resume_after_weight_sync(paged_setup):
    """Acceptance: a request aborted-with-retain by a fleet-wide weight
    sync on a DRAINING replica migrates to the other replica and resolves
    exactly once — greedy output identical to the uninterrupted run, legs
    version-tagged across the sync.  The router moves the parked pages
    across (page-transfer fast path), so the target resumes with ZERO
    re-prefill — no concatenated prompt is recomputed."""
    cfg, api, params = paged_setup
    prompt = np.asarray([2, 9, 4, 3, 7], np.int32)
    budget = 40

    ref = _paged(api, params)
    ref.add_request(0, prompt, budget)
    base = None
    while base is None:
        for _rid, toks, _ in ref.step():
            base = list(toks)

    engines, proxies, router = _paged_fleet(api, params, 2, num_slots=2)
    router.start()
    versions = [0]
    client = RolloutClient(router, version_fn=lambda: versions[0])
    h = client.submit(_task(budget, prompt), version=0)
    fired = []
    h.add_done_callback(fired.append)
    deadline = time.monotonic() + 30
    while (sum(e.total_tokens_decoded for e in engines) < 2
           and time.monotonic() < deadline):
        time.sleep(0.01)
    home = 0 if engines[0].slots else 1
    other = 1 - home
    prefill_other_before = engines[other].total_prefill_tokens
    # fleet-wide overlapped sync: stage on ALL replicas, version++, abort
    ev = router.update_weights_async(params)
    assert ev.wait(30)
    versions[0] = 1
    router.drain(home)                       # force the migration path
    router.abort_stale(min_version=1, retain=True)
    res = h.result(timeout=60)
    time.sleep(0.1)
    router.stop()
    assert len(fired) == 1 and fired[0] is res, "resolves exactly once"
    assert not res.aborted
    assert list(res.tokens) == base, \
        "migrated resume must preserve the greedy output"
    assert client.migrations == 1 and router.migrations == 1
    assert len(res.legs) >= 2
    assert res.legs[0][0] == 0 and res.legs[-1][0] == 1
    assert sum(n for _, n in res.legs) == budget
    assert engines[other].total_prefill_tokens == prefill_other_before, \
        "page transfer must make the migrated resume zero-re-prefill"
    assert engines[other].pages_transferred_in > 0
    assert engines[home].pages_transferred_out == \
        engines[other].pages_transferred_in
    assert router.pages_transferred == engines[other].pages_transferred_in
    assert router.transfer_bytes > 0
    assert not engines[home].retained, "home released the parked pages"
    assert not engines[other].retained, "target consumed the imported record"
    for e in engines:
        e.audit_pages()
    assert proxies[home].load() == 0 and proxies[other].load() == 0


@pytest.mark.timeout(240)
def test_home_map_clean_after_group_follower_promotion(paged_setup):
    """Regression: a group leader aborted-with-retain BEFORE its COW fork
    promotes a follower (the retain degrades — pages hand over, nothing
    parks).  The router's rid→replica map must not leak an entry for the
    promoted chain; ``fleet_audit`` asserts emptiness at quiescence."""
    cfg, api, params = paged_setup
    engines, proxies, router = _paged_fleet(api, params, 2, num_slots=3,
                                            prefill_chunk=4)
    client = RolloutClient(router)
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
    tasks = expand_tasks(0, prompt, 3, 12, replicate=True)
    gh = client.submit_group(tasks)
    leader_rid = gh.handles[0].task.task_id
    # abort the leader mid-prefill, before any follower forks: the engine
    # promotes the first follower onto the leader's pages.
    router.abort(leader_rid, retain=True)
    router.start()
    for h in gh.handles[1:]:
        res = h.result(60)
        assert not res.aborted and len(res.tokens) == 12
    ab = gh.handles[0].result(60)
    # the retain degraded (pages handed to the follower, nothing parked) so
    # the client continuation re-prefilled the leader — it still completes
    assert not ab.aborted and len(ab.tokens) == 12
    assert ab.legs[0] == (0, 0) and client.reprefills == 1
    time.sleep(0.1)
    router.stop()
    router.fleet_audit()                 # map empty, engines audit clean
    assert router.load() == 0


# ------------------------------------------------------------- pipeline
def test_pipeline_fleet_build_and_rollout():
    """num_rollout_replicas=2 shards slots across replicas behind a router
    and the producer rolls out through it end-to-end;
    num_rollout_replicas=1 keeps the exact single-proxy construction."""
    from repro.launch.pipeline import PipelineSettings, build_rlvr_pipeline
    MODEL = tiny("qwen3-4b", vocab_size=32)
    s1 = PipelineSettings(async_generation_ratio=1, rollout_batch_size=4,
                          num_return_sequences_in_group=2, num_slots=4,
                          max_new_tokens=4, max_seq_len=32, page_size=8,
                          prefill_chunk=8)
    pipe1 = build_rlvr_pipeline(MODEL, s1)
    assert pipe1.router is None and len(pipe1.proxies) == 1
    assert pipe1.rollout_target is pipe1.proxy
    assert pipe1.producer.proxy is pipe1.proxy
    pipe1.shutdown()

    s2 = PipelineSettings(async_generation_ratio=1, rollout_batch_size=4,
                          num_return_sequences_in_group=2, num_slots=4,
                          max_new_tokens=4, max_seq_len=32, page_size=8,
                          prefill_chunk=8, num_rollout_replicas=2,
                          weight_sync_timeout=33.0)
    pipe = build_rlvr_pipeline(MODEL, s2)
    assert pipe.router is not None and len(pipe.engines) == 2
    assert all(e.num_slots == 2 for e in pipe.engines), "slots sharded"
    assert pipe.rollout_target is pipe.router
    assert pipe.controller.proxies == pipe.proxies
    assert pipe.controller.weight_sync_timeout == 33.0
    for p in pipe.proxies:
        p.start()
    pipe.producer.start()
    try:
        batch = pipe.buffer.get_batch(4, timeout=120)
    finally:
        pipe.shutdown()
    assert len(batch) == 4
    for b in batch:
        assert len(np.asarray(b.response_tokens)) > 0
        assert b.reward is not None
    for e in pipe.engines:
        e.audit_pages()


# ------------------------------------------------------------ slow sweep
@pytest.mark.slow
@pytest.mark.timeout(600)
def test_fleet_churn_audit_pages_clean(paged_setup):
    """Churn sweep over a 2-replica fleet: interleaved submits, retained
    aborts (with in-place resumes AND drained migrations), fleet weight
    syncs.  Every handle resolves exactly once and audit_pages is clean on
    every replica at the end."""
    cfg, api, params = paged_setup
    engines, proxies, router = _paged_fleet(api, params, 2, num_slots=3,
                                            prefix_cache=True)
    router.start()
    versions = [0]
    client = RolloutClient(router, version_fn=lambda: versions[0])
    rng = np.random.default_rng(3)
    resolved = []
    handles = []
    for wave in range(6):
        for _ in range(4):
            p = rng.integers(1, 30, int(rng.integers(3, 12))).astype(np.int32)
            h = client.submit(_task(int(rng.integers(6, 16)), p),
                              version=versions[0])
            h.add_done_callback(resolved.append)
            handles.append(h)
        time.sleep(0.05)
        if wave % 2 == 0:
            ev = router.update_weights_async(params)
            assert ev.wait(30)
            versions[0] += 1
            if wave == 2:
                router.drain(0)
            router.abort_stale(min_version=versions[0], retain=True)
            if wave == 4:
                router.undrain(0)
    for h in handles:
        res = h.result(timeout=120)
        assert sum(n for _, n in res.legs) == len(res.tokens)
    time.sleep(0.2)
    router.stop()
    assert len(resolved) == len(handles), "every handle resolves exactly once"
    for i, e in enumerate(engines):
        assert not e.retained, f"replica {i} leaked retained pages"
        e.audit_pages()
    assert router.load() == 0
