"""Tier-1 smoke: the default pipeline rolls out through the paged engine.

The heavyweight end-to-end training runs live in test_system.py (slow
tier); this file keeps a fast blocking check that `launch/pipeline.py`
builds the PAGED engine by default for attention families, the producer's
group submissions flow end-to-end, and the slot engine stays selectable.
"""
import jax
import numpy as np
import pytest

from conftest import tiny
from repro.launch.pipeline import (PipelineSettings, build_rlvr_pipeline,
                                   make_rollout_engine)
from repro.models import get_api
from repro.rollout.engine import DecodeEngine
from repro.rollout.paged_engine import PagedDecodeEngine

pytestmark = pytest.mark.timeout(240)

MODEL = tiny("qwen3-4b", vocab_size=32)


def test_default_pipeline_is_paged_and_rolls_out():
    s = PipelineSettings(async_generation_ratio=1, rollout_batch_size=4,
                         num_return_sequences_in_group=2, num_slots=4,
                         max_new_tokens=4, max_seq_len=32, page_size=8,
                         prefill_chunk=8)
    pipe = build_rlvr_pipeline(MODEL, s)
    assert isinstance(pipe.engine, PagedDecodeEngine)
    pipe.proxy.start()
    pipe.producer.start()
    try:
        batch = pipe.buffer.get_batch(4, timeout=120)
    finally:
        pipe.shutdown()
    assert len(batch) == 4
    for b in batch:
        assert len(np.asarray(b.response_tokens)) > 0
        assert b.reward is not None
        assert len(np.asarray(b.logprobs)) == len(np.asarray(b.response_tokens))
    # the producer submitted GRPO groups, the engine forked them (COW)
    assert pipe.engine.total_groups_forked >= 1
    pipe.engine.audit_pages()


def test_engine_selection():
    api = get_api(MODEL)
    params = api.init(jax.random.PRNGKey(0))
    assert isinstance(make_rollout_engine(api, params, PipelineSettings()),
                      PagedDecodeEngine)
    assert isinstance(
        make_rollout_engine(api, params,
                            PipelineSettings(rollout_engine="slot")),
        DecodeEngine)
    with pytest.raises(ValueError, match="rollout_engine"):
        make_rollout_engine(api, params,
                            PipelineSettings(rollout_engine="bogus"))


def test_engine_selection_recurrent_family_falls_back_to_slot():
    cfg = tiny("rwkv6-3b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    assert isinstance(make_rollout_engine(api, params, PipelineSettings()),
                      DecodeEngine)
