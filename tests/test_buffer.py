"""SampleBuffer freshness invariants (the paper's §4.3), property-based."""
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sample_buffer import SampleBuffer, StaleSampleError
from repro.core.types import Sample, next_uid


def mk_sample(version: int) -> Sample:
    return Sample(sample_id=next_uid(), prompt_id=0, replica_idx=0,
                  prompt_tokens=np.zeros(2, np.int32),
                  response_tokens=np.zeros(2, np.int32),
                  logprobs=np.zeros(2, np.float32), version_started=version)


@given(alpha=st.integers(0, 4), batch=st.integers(1, 8),
       steps=st.integers(1, 12), data=st.data())
@settings(max_examples=60, deadline=None)
def test_staleness_never_exceeds_alpha(alpha, batch, steps, data):
    """Random interleaving of producer starts / completions / train steps:
    every consumed sample satisfies version_gap <= alpha."""
    buf = SampleBuffer(batch_size=batch, alpha=alpha)
    pending = []  # versions of claimed-but-unfinished generations
    consumed_gaps = []
    for _ in range(steps):
        # producers claim as many slots as the gate allows (random subset)
        claims = data.draw(st.integers(0, 3 * batch))
        for _ in range(claims):
            v = buf.try_begin_generation()
            if v is None:
                break
            pending.append(v)
        # random completion order (long-tail inversion!)
        data.draw(st.randoms(use_true_random=False)).shuffle(pending)
        ncomplete = data.draw(st.integers(0, len(pending)))
        for _ in range(ncomplete):
            buf.put(mk_sample(pending.pop()))
        # trainer consumes if a full batch is ready
        if buf.occupancy() - 0 >= batch and len(buf._samples) >= batch:
            got = buf.get_batch(batch, block=False)
            v_now = buf.version
            consumed_gaps.extend(v_now - s.version_started for s in got)
            v = buf.advance_version()
            # emulate AsyncController.abort_stale: in-flight generations that
            # would violate alpha are ABORTed and recomputed under the new
            # policy (re-initiated at the current version)
            pending[:] = [pv if v - pv <= alpha else v for pv in pending]
    assert all(g <= alpha for g in consumed_gaps)
    # occupancy bound: (1+alpha) * batch
    assert buf.occupancy() <= (1 + alpha) * batch


def test_alpha_zero_is_synchronous():
    """alpha=0: exactly one batch may be initiated per version."""
    buf = SampleBuffer(batch_size=4, alpha=0)
    versions = [buf.try_begin_generation() for _ in range(6)]
    assert versions[:4] == [0, 0, 0, 0] and versions[4:] == [None, None]
    for _ in range(4):
        buf.put(mk_sample(0))
    got = buf.get_batch(4)
    assert len(got) == 4
    buf.advance_version()
    assert buf.try_begin_generation() == 1


def test_consumption_is_oldest_version_first():
    buf = SampleBuffer(batch_size=2, alpha=2)
    for _ in range(6):
        buf.try_begin_generation()
    # completion order inverted: newer versions finish first
    buf.put(mk_sample(0))
    buf.advance_version()   # v1
    buf.put(mk_sample(1))
    buf.put(mk_sample(1))
    buf.put(mk_sample(0))
    got = buf.get_batch(2, block=False)
    assert [s.version_started for s in got] == [0, 0]


def test_strict_mode_raises_on_stale_put():
    buf = SampleBuffer(batch_size=2, alpha=1)
    v = buf.try_begin_generation()
    buf.advance_version()
    buf.advance_version()  # now v0 sample is 2 behind with alpha=1
    with pytest.raises(StaleSampleError):
        buf.put(mk_sample(v))


def test_reclaim_returns_reservation():
    buf = SampleBuffer(batch_size=2, alpha=0)
    assert buf.try_begin_generation() == 0
    assert buf.try_begin_generation() == 0
    assert buf.try_begin_generation() is None
    buf.reclaim(1)
    assert buf.try_begin_generation() == 0


def test_blocking_get_batch_wakes_on_put():
    buf = SampleBuffer(batch_size=2, alpha=1)
    out = {}

    def consumer():
        out["batch"] = buf.get_batch(2, timeout=5)

    t = threading.Thread(target=consumer)
    t.start()
    buf.try_begin_generation()
    buf.try_begin_generation()
    buf.put(mk_sample(0))
    buf.put(mk_sample(0))
    t.join(timeout=5)
    assert len(out["batch"]) == 2


def test_capacity_property():
    buf = SampleBuffer(batch_size=8, alpha=2.5)
    assert buf.capacity == 28
