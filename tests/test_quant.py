"""Quantized rollout subsystem: quantize-on-sync weights, int8 KV pages,
TIS engine-mismatch cap, and the mixed-precision batch accounting.

The paged-engine tests all run greedy (temperature=0) so byte-identity is a
meaningful check: under kv_quant=int8 every KV position is quantized exactly
once at write time, so abort→resume, COW group forks, and prefix-cache hits
must reproduce an uninterrupted run exactly — both paths read the same
quantized pages through the same per-page scales.
"""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.algos.grpo import rl_loss
from repro.algos.off_policy import LossConfig, engine_mismatch_weight
from repro.core.async_controller import AsyncController
from repro.core.llm_proxy import LLMProxy
from repro.core.types import RolloutTask, next_uid
from repro.kernels import ref as kref
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.models import get_api, paged
from repro.quant import core as quant
from repro.rollout.engine import DecodeEngine
from repro.rollout.paged_engine import PagedDecodeEngine

CFG = tiny("qwen3-4b")


@pytest.fixture(scope="module")
def api_params():
    api = get_api(CFG)
    return api, api.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------- primitives

def test_quantize_params_structure_and_skip_set(api_params):
    _, params = api_params
    q = quant.quantize_params(params, "int8")
    assert quant.is_quantized_tree(q)
    # embeddings / norm gains stay full precision (outliers + cheap)
    assert not isinstance(q["embed"], quant.QuantLeaf)
    assert q["embed"].dtype == params["embed"].dtype
    blk = q["blocks"]
    assert isinstance(blk["attn"]["wq"], quant.QuantLeaf)
    assert blk["attn"]["wq"].codes.dtype == jnp.int8
    assert not isinstance(blk["ln1"]["scale"], quant.QuantLeaf)


@pytest.mark.parametrize("mode,tol", [("int8", 0.02), ("fp8", 0.08)])
def test_quantize_roundtrip_error(api_params, mode, tol):
    _, params = api_params
    deq = quant.dequantize_params(quant.quantize_params(params, mode))
    w = params["blocks"]["attn"]["wq"]
    w2 = deq["blocks"]["attn"]["wq"]
    assert w2.dtype == w.dtype
    err = np.abs(np.asarray(w2, np.float32) - np.asarray(w, np.float32))
    assert err.max() <= tol * np.abs(np.asarray(w, np.float32)).max()


def test_quantize_off_is_identity(api_params):
    _, params = api_params
    assert quant.quantize_params(params, "off") is params
    assert not quant.is_quantized_tree(params)
    # dequantizing a plain tree is a leaf-identity traversal
    deq = quant.dequantize_params(params)
    assert all(a is b for a, b in zip(jax.tree_util.tree_leaves(deq),
                                      jax.tree_util.tree_leaves(params)))


def test_quant_leaf_is_jit_transparent(api_params):
    _, params = api_params
    q = quant.quantize_params(params, "int8")

    @jax.jit
    def f(p):
        return quant.dequantize_params(p)["blocks"]["attn"]["wq"].sum()

    assert np.isfinite(float(f(q)))


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 3, 2, 16), jnp.bfloat16)
    codes, scale = paged.quantize_kv(x)
    assert codes.dtype == jnp.int8 and scale.shape == (5, 3, 2)
    deq = codes.astype(jnp.float32) * scale[..., None]
    err = np.abs(deq - np.asarray(x, np.float32))
    assert err.max() <= np.abs(np.asarray(x, np.float32)).max() / 100


def test_unknown_modes_rejected(api_params):
    api, params = api_params
    with pytest.raises(ValueError):
        quant.quantize_params(params, "int4")
    with pytest.raises(ValueError):
        PagedDecodeEngine(api, params, quant_mode="int4")
    with pytest.raises(ValueError):
        PagedDecodeEngine(api, params, kv_quant="fp8")
    with pytest.raises(ValueError):
        DecodeEngine(api, params, quant_mode="nope")


# ------------------------------------------------------------ paged engine

def _drain(eng, out):
    for _ in range(500):
        for rid, toks, _ in eng.step():
            out[rid] = toks.tolist()
        if not eng.slots:
            return out
    raise AssertionError("engine did not drain")


def _make_engine(api, params, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_total_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("temperature", 0.0)
    return PagedDecodeEngine(api, params, **kw)


PROMPT = (np.arange(1, 19) % 13 + 3).astype(np.int32)


def test_engine_quant_matches_fake_quantized_params(api_params):
    """Dequant-inside-jit == running the off engine on an explicitly
    fake-quantized (quantize→dequantize on host) parameter tree."""
    api, params = api_params
    e_q = _make_engine(api, params, quant_mode="int8")
    fake = quant.dequantize_params(quant.quantize_params(params, "int8"))
    e_f = _make_engine(api, fake)
    for e in (e_q, e_f):
        e.add_request(1, PROMPT, 10)
    a = _drain(e_q, {})
    b = _drain(e_f, {})
    assert a == b


@pytest.mark.parametrize("kw", [
    {"kv_quant": "int8"},
    {"quant_mode": "int8", "kv_quant": "int8"},
])
def test_abort_resume_byte_identical(api_params, kw):
    api, params = api_params

    def plain():
        eng = _make_engine(api, params, prefix_cache=True, **kw)
        eng.add_request(1, PROMPT, 12)
        return _drain(eng, {})[1]

    def interrupted():
        eng = _make_engine(api, params, prefix_cache=True, **kw)
        eng.add_request(1, PROMPT, 12)
        for _ in range(8):
            eng.step()
        r = eng.abort(1, retain=True)
        assert r.resumable
        eng.audit_pages()
        pre = r.tokens.tolist()
        eng.resume_request(1, 2, 12 - len(pre))
        out = _drain(eng, {})
        eng.audit_pages()
        return pre + out[2]

    assert plain() == interrupted()


def test_group_fork_parity_kv_int8(api_params):
    """COW followers under int8 KV pages: forked tail pages carry their
    scales, so greedy followers reproduce the leader exactly."""
    api, params = api_params
    eng = _make_engine(api, params, kv_quant="int8")
    eng.submit_group([1, 2, 3], PROMPT, 12)
    out = _drain(eng, {})
    eng.audit_pages()
    assert out[1] == out[2] == out[3]
    single = _make_engine(api, params, kv_quant="int8")
    single.add_request(9, PROMPT, 12)
    assert _drain(single, {})[9] == out[1]


def test_prefix_cache_hit_dequantizes_retained_scales(api_params):
    """A cache-hit admission aliases previously written int8 pages; their
    per-page scales must come along — greedy output matches a cold engine."""
    api, params = api_params
    warm = _make_engine(api, params, kv_quant="int8", prefix_cache=True)
    warm.add_request(1, PROMPT, 10)
    first = _drain(warm, {})[1]
    warm.add_request(2, PROMPT, 10)        # same prompt: page-aligned hit
    second = _drain(warm, {})[2]
    assert warm.cache_hits >= 1 and warm.cache_hit_tokens > 0
    warm.audit_pages()
    cold = _make_engine(api, params, kv_quant="int8", prefix_cache=False)
    cold.add_request(3, PROMPT, 10)
    assert _drain(cold, {})[3] == second == first


def test_audit_clean_under_churn_kv_int8(api_params):
    """fork + evict-under-pressure + retain/release churn with int8 pages:
    the refcount/scale bookkeeping must stay exact."""
    api, params = api_params
    eng = _make_engine(api, params, kv_quant="int8", prefix_cache=True,
                       num_slots=6, num_pages=24)
    rng = np.random.default_rng(0)
    rid = 0
    for round_ in range(4):
        rid += 10
        eng.submit_group([rid, rid + 1, rid + 2], PROMPT, 8)
        solo = rid + 3
        eng.add_request(solo, rng.integers(1, 60, 11).astype(np.int32), 8)
        for _ in range(6):
            eng.step()
        eng.audit_pages()
        r = eng.abort(solo, retain=True)
        eng.audit_pages()
        if r.resumable and round_ % 2 == 0:
            eng.resume_request(solo, solo + 5, 4)
        elif r.resumable:
            eng.release_retained(solo)
        _drain(eng, {})
        eng.audit_pages()
    assert eng.cache_evicted_pages >= 0   # churn may or may not evict
    eng.audit_pages()


def test_kernel_interpret_matches_ref_kv_int8(api_params):
    """The quantized Pallas decode kernel (interpret mode) drives the engine
    to the same greedy tokens as the pure-JAX gather path."""
    api, params = api_params
    outs = []
    for impl in ("ref", "kernel_interpret"):
        eng = _make_engine(api, params, kv_quant="int8", attn_impl=impl)
        eng.add_request(1, PROMPT, 8)
        outs.append(_drain(eng, {})[1])
    assert outs[0] == outs[1]


def test_paged_decode_attention_int8_parity_fast():
    """Tier-1 kernel/oracle parity at one small shape (the full sweep is
    slow-tier in test_kernels.py)."""
    b, h, kv, d, page_size, pages_per_seq = 2, 4, 2, 32, 16, 2
    num_pages = 1 + b * pages_per_seq
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (b, h, d))
    kf = jax.random.normal(jax.random.fold_in(key, 1),
                           (num_pages, page_size, kv, d))
    vf = jax.random.normal(jax.random.fold_in(key, 2),
                           (num_pages, page_size, kv, d))
    kp, ks = paged.quantize_kv(kf)
    vp, vs = paged.quantize_kv(vf)
    bt = jnp.arange(1, 1 + b * pages_per_seq, dtype=jnp.int32).reshape(b, -1)
    lengths = jnp.asarray([page_size * pages_per_seq, 19], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lengths,
                                 k_scales=ks, v_scales=vs, interpret=True)
    expected = kref.paged_decode_attention_ref(q, kp, vp, bt, lengths,
                                               k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)
    fp = kref.paged_decode_attention_ref(q, kf, vf, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fp),
                               rtol=0.05, atol=0.05)


# -------------------------------------------------- quantize-on-sync + meta

def test_update_weights_requantizes(api_params):
    api, params = api_params
    eng = _make_engine(api, params, quant_mode="int8")
    assert quant.is_quantized_tree(eng.params)
    eng.update_weights(params)
    assert quant.is_quantized_tree(eng.params)
    assert eng.total_weight_syncs_quantized == 1
    # mode change applies at the NEXT sync, with full-precision source
    eng.set_quant_mode("off")
    assert quant.is_quantized_tree(eng.params)   # unchanged until sync
    eng.update_weights(params)
    assert not quant.is_quantized_tree(eng.params)
    assert eng.total_weight_syncs_quantized == 1


def test_slot_engine_quantize_on_sync(api_params):
    api, params = api_params
    eng = DecodeEngine(api, params, num_slots=2, max_total_len=32,
                       temperature=0.0, quant_mode="int8")
    assert quant.is_quantized_tree(eng.params)
    eng.add_request(1, PROMPT[:8], 6)
    out = {}
    for _ in range(50):
        for rid, toks, _ in eng.step():
            out[rid] = toks.tolist()
        if not eng.slots:
            break
    assert len(out[1]) == 6
    eng.set_quant_mode("fp8")
    eng.update_weights(params)
    assert quant.is_quantized_tree(eng.params)


def test_proxy_stamps_quant_mode_and_stepstats_mix(api_params):
    """Samples record the engine's quant_mode at admission; after a mid-run
    set_quant_mode change StepStats reports the mixed-precision batch."""
    api, params = api_params
    eng = _make_engine(api, params, num_slots=2)
    proxy = LLMProxy(eng).start()
    results, lock = [], threading.Lock()

    def submit():
        t = RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                        prompt_tokens=PROMPT[:6], max_new_tokens=3)
        proxy.generate(t, version=0,
                       callback=lambda r: (lock.acquire(), results.append(r),
                                           lock.release()))
        return t

    t1 = submit()
    deadline = time.monotonic() + 10
    while len(results) < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    eng.set_quant_mode("int8")     # engine-side knob; applies to stamps now
    ev = proxy.update_weights_async(params)  # requantizes under the new mode
    assert ev.wait(timeout=10)
    t2 = submit()
    while len(results) < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    proxy.stop()
    assert len(results) == 2
    stamps = {r.task.task_id: r.task.meta["quant_mode"] for r in results}
    assert stamps[t1.task_id] == "off" and stamps[t2.task_id] == "int8"

    # the controller surfaces the batch's precision mix
    class _S:
        def __init__(self, meta):
            self.meta = meta
    mix = AsyncController._quant_mix(
        [_S({"quant_mode": "off"}), _S({"quant_mode": "int8"}),
         _S({"quant_mode": "int8"}), _S({})])
    assert mix == {"off": 2, "int8": 2}


# --------------------------------------------------------------------- TIS

def test_tis_clip_tightens_cap():
    lp_t = jnp.array([[0.0, -1.0, -2.0]])
    lp_r = jnp.array([[-3.0, -1.0, -0.5]])
    base = engine_mismatch_weight(lp_t, lp_r, 5.0)
    assert float(base[0, 0]) == 5.0
    for w in (engine_mismatch_weight(lp_t, lp_r, 5.0, tis_clip=2.0),
              engine_mismatch_weight(lp_t, lp_r, None, tis_clip=2.0)):
        assert float(w.max()) <= 2.0
        # below the cap the ratio passes through unchanged
        np.testing.assert_allclose(np.asarray(w[0, 1:]),
                                   np.asarray(base[0, 1:]), rtol=1e-6)
    # a tis_clip looser than the cap defers to the cap
    loose = engine_mismatch_weight(lp_t, lp_r, 5.0, tis_clip=10.0)
    np.testing.assert_allclose(np.asarray(loose), np.asarray(base))


def test_rl_loss_applies_tis_clip():
    lp_t = jnp.array([[0.0, -1.0, -2.0]])
    lp_r = jnp.array([[-3.0, -1.0, -0.5]])
    batch = {"old_logprobs": lp_r, "prox_logprobs": lp_r,
             "ref_logprobs": lp_r, "advantages": jnp.ones((1, 3)),
             "mask": jnp.ones((1, 3)), "is_positive": jnp.ones((1,))}
    l_cap, _ = rl_loss(lp_t, batch, LossConfig())
    l_tis, _ = rl_loss(lp_t, batch, LossConfig(tis_clip=2.0))
    # cap=None + tis_clip still applies the correction
    l_only, _ = rl_loss(lp_t, batch,
                        LossConfig(engine_mismatch_cap=None, tis_clip=2.0))
    l_off, _ = rl_loss(lp_t, batch, LossConfig(engine_mismatch_cap=None))
    assert float(l_tis) == float(l_only) != float(l_cap)
    assert float(l_off) != float(l_only)


def test_pipeline_threads_quant_knobs(api_params):
    from repro.launch.pipeline import PipelineSettings, make_rollout_engine
    api, params = api_params
    s = PipelineSettings(rollout_quant="int8", kv_quant="int8", tis_clip=2.0,
                         max_seq_len=64)
    eng = make_rollout_engine(api, params, s)
    assert eng.quant_mode == "int8" and eng.kv_quant == "int8"
    with pytest.raises(ValueError, match="paged engine"):
        make_rollout_engine(api, params,
                            dataclasses.replace(s, rollout_engine="slot"))
    slot = make_rollout_engine(api, params, dataclasses.replace(
        s, rollout_engine="slot", kv_quant="off"))
    assert slot.quant_mode == "int8"
