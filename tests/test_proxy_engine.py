"""LLMProxy command loop + DecodeEngine slot semantics."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.core.llm_proxy import LLMProxy
from repro.core.types import GenerationResult, RolloutTask, next_uid
from repro.models import get_api
from repro.rollout.engine import DecodeEngine


class FakeEngine:
    """Deterministic engine: each request emits `n` tokens, one per step."""

    def __init__(self, slots=2):
        self.slots = slots
        self.active = {}
        self.weights_version = 0

    @property
    def num_free_slots(self):
        return self.slots - len(self.active)

    def add_request(self, rid, prompt, max_new):
        assert self.num_free_slots > 0
        self.active[rid] = {"left": int(max_new), "toks": []}

    def abort(self, rid):
        st = self.active.pop(rid)
        return GenerationResult(request_id=rid, task=None,
                                tokens=np.asarray(st["toks"], np.int32),
                                logprobs=np.zeros(len(st["toks"]), np.float32),
                                version_started=-1, aborted=True, partial=True)

    def step(self):
        time.sleep(0.001)  # realistic decode-step latency
        done = []
        for rid, st in list(self.active.items()):
            st["toks"].append(len(st["toks"]))
            st["left"] -= 1
            if st["left"] <= 0:
                done.append((rid, np.asarray(st["toks"], np.int32),
                             np.zeros(len(st["toks"]), np.float32)))
                del self.active[rid]
        return done

    def update_weights(self, params):
        self.weights_version = params


def _task(n=3):
    return RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.zeros(2, np.int32), max_new_tokens=n)


def test_proxy_completes_requests_and_queues_beyond_slots():
    eng = FakeEngine(slots=2)
    proxy = LLMProxy(eng).start()
    results = []
    lock = threading.Lock()
    for _ in range(5):
        proxy.generate(_task(3), version=0,
                       callback=lambda r: (lock.acquire(), results.append(r),
                                           lock.release()))
    deadline = time.monotonic() + 10
    while len(results) < 5 and time.monotonic() < deadline:
        time.sleep(0.01)
    proxy.stop()
    assert len(results) == 5
    assert all(list(r.tokens) == [0, 1, 2] for r in results)


def test_proxy_abort_returns_partial():
    eng = FakeEngine(slots=1)
    proxy = LLMProxy(eng).start()
    results = []
    t = _task(10_000)
    proxy.generate(t, version=0, callback=results.append)
    time.sleep(0.2)
    proxy.abort(t.task_id)
    deadline = time.monotonic() + 5
    while not results and time.monotonic() < deadline:
        time.sleep(0.01)
    proxy.stop()
    assert results and results[0].aborted and results[0].partial
    assert len(results[0].tokens) > 0


def test_proxy_abort_stale_only_hits_old_versions():
    eng = FakeEngine(slots=2)
    proxy = LLMProxy(eng).start()
    results = []
    t_old, t_new = _task(10_000), _task(10_000)
    proxy.generate(t_old, version=0, callback=results.append)
    proxy.generate(t_new, version=3, callback=results.append)
    time.sleep(0.2)
    proxy.abort_stale(min_version=2)
    deadline = time.monotonic() + 5
    while not results and time.monotonic() < deadline:
        time.sleep(0.01)
    time.sleep(0.1)
    proxy.stop()
    assert len(results) == 1
    assert results[0].request_id == t_old.task_id and results[0].aborted


def test_proxy_suspend_resume_weight_sync():
    eng = FakeEngine(slots=1)
    proxy = LLMProxy(eng).start()
    proxy.generate(_task(10_000), version=0, callback=lambda r: None)
    time.sleep(0.1)
    proxy.suspend()
    steps_at_suspend = proxy.steps_executed
    proxy.update_weights("v1")
    time.sleep(0.15)
    assert proxy.steps_executed == steps_at_suspend  # loop is parked
    assert eng.weights_version == "v1"
    proxy.resume()
    time.sleep(0.15)
    assert proxy.steps_executed > steps_at_suspend
    proxy.stop()


# ---------------------------------------------------------------------------
# real JAX engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def test_engine_greedy_matches_manual_decode(engine_setup):
    cfg, api, params = engine_setup
    eng = DecodeEngine(api, params, num_slots=2, max_total_len=32,
                       eos_id=99, temperature=0.0, prefill_bucket=None)
    prompt = np.asarray([1, 5, 7], np.int32)
    eng.add_request(0, prompt, 6)
    results = {}
    while not results:
        for rid, toks, _lps in eng.step():
            results[rid] = toks
    got = results[0]

    # manual greedy loop through the api
    cache = api.init_cache(1, 32)
    logits, cache = api.prefill(params, {"tokens": prompt[None, :]}, cache)
    tok = int(jnp.argmax(logits[0]))  # (B, V) last-position logits
    manual = [tok]
    for t in range(len(prompt), len(prompt) + 5):
        logits, cache = api.decode_step(params, jnp.asarray([tok]),
                                        jnp.asarray([t], jnp.int32), cache)
        tok = int(jnp.argmax(logits[0]))
        manual.append(tok)
    assert list(got) == manual


def test_engine_slot_reuse_and_isolation(engine_setup):
    """Two requests with identical prompts through different slot histories
    must produce identical greedy outputs (no cross-slot contamination)."""
    cfg, api, params = engine_setup
    eng = DecodeEngine(api, params, num_slots=2, max_total_len=32,
                       eos_id=99, temperature=0.0, prefill_bucket=8)
    p1 = np.asarray([1, 5, 7], np.int32)
    p2 = np.asarray([2, 9, 4, 3], np.int32)
    results = {}
    eng.add_request(0, p1, 5)
    eng.add_request(1, p2, 5)
    while len(results) < 2:
        for rid, toks, _ in eng.step():
            results[rid] = list(toks)
    # rerun p1 alone in a reused slot
    eng.add_request(2, p1, 5)
    while len(results) < 3:
        for rid, toks, _ in eng.step():
            results[rid] = list(toks)
    assert results[2] == results[0]


def test_engine_abort_frees_slot(engine_setup):
    cfg, api, params = engine_setup
    eng = DecodeEngine(api, params, num_slots=1, max_total_len=32, eos_id=99)
    eng.add_request(0, np.asarray([1, 2], np.int32), 20)
    assert eng.num_free_slots == 0
    eng.step()
    partial = eng.abort(0)
    assert partial.aborted and eng.num_free_slots == 1
    eng.add_request(1, np.asarray([3], np.int32), 3)
    done = []
    while not done:
        done = eng.step()
    assert done[0][0] == 1


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_engine_fuzz_against_reference(engine_setup):
    """Property: under RANDOM interleavings of add/step/abort, every
    completed request's greedy output equals decoding it alone."""
    import numpy as np

    cfg, api, params = engine_setup

    def solo(prompt, budget):
        eng = DecodeEngine(api, params, num_slots=1, max_total_len=32,
                           eos_id=99, temperature=0.0, prefill_bucket=8)
        eng.add_request(0, prompt, budget)
        while True:
            for _rid, toks, _ in eng.step():
                return list(toks)

    rng = np.random.default_rng(0)
    eng = DecodeEngine(api, params, num_slots=3, max_total_len=32,
                       eos_id=99, temperature=0.0, prefill_bucket=8)
    prompts = {}
    results = {}
    aborted = set()
    rid = 0
    for _ in range(120):
        op = rng.random()
        if op < 0.3 and eng.num_free_slots > 0:
            p = rng.integers(1, cfg.vocab_size, rng.integers(2, 6)).astype(np.int32)
            budget = int(rng.integers(2, 7))
            prompts[rid] = (p, budget)
            eng.add_request(rid, p, budget)
            rid += 1
        elif op < 0.4 and eng.req_to_slot:
            victim = int(rng.choice(list(eng.req_to_slot)))
            eng.abort(victim)
            aborted.add(victim)
        else:
            for r, toks, _ in eng.step():
                results[r] = list(toks)
    for r, toks in results.items():
        if r in aborted:
            continue
        p, budget = prompts[r]
        assert toks == solo(p, budget), f"request {r} diverged"
