"""SLO layer: admission control, priority preemption, and the watchdog.

Acceptance-criteria coverage:

* requests carry priority + deadline_ms end to end; the pending queue is
  priority-ordered (FIFO within a class — uniform priorities unchanged);
* admission control resolves over-bound / expired submissions immediately
  with a typed ``Rejected`` (never silent queueing), and sheds queued
  lower-priority work to make room at the total bound;
* preemption pauses a low-priority decode with its pages parked (slots
  freed, pages kept) and resumes it at ZERO re-prefill cost — on the real
  paged engine the stitched output is byte-identical to an uninterrupted
  greedy run;
* the watchdog enforces deadlines with exactly-once timeout resolution
  (partial sample, ``timed_out=True``, pages released), sheds expired
  queued work, aborts stalled decodes, and defers detected long-tails so
  they never block batch completion.
"""
import threading

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.llm_proxy import LLMProxy
from repro.core.rollout_client import RolloutClient
from repro.core.router import ProxyRouter
from repro.core.slo import SLOConfig, without_admission
from repro.core.types import (PRIORITY_HIGH, PRIORITY_LOW, PRIORITY_NORMAL,
                              Rejected, RolloutTask, next_uid)
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine
from test_router import FakeEngine, _task


def _ptask(n=3, prompt=(1, 2), priority=PRIORITY_NORMAL, deadline_ms=None,
           meta=None):
    return RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray(prompt, np.int32),
                       max_new_tokens=n, group_id=-1, meta=dict(meta or {}),
                       priority=priority, deadline_ms=deadline_ms)


def _round_clock():
    """Injectable deterministic clock: a mutable round counter read as
    seconds, so lockstep tests express deadlines in rounds."""
    box = [0.0]
    return box, (lambda: box[0])


def _drain(proxy, max_steps=500):
    """Lockstep-drive the proxy until idle (commands included)."""
    for _ in range(max_steps):
        ran = proxy.step_once()
        if not ran and proxy.num_pending == 0 and proxy.num_active == 0 \
                and proxy._commands.empty():
            return
    raise AssertionError("proxy did not drain")


# -------------------------------------------------------- priority ordering
def test_priority_queue_ordering():
    """A high-priority arrival overtakes queued lower-priority work; FIFO
    is preserved within a class."""
    eng = FakeEngine(slots=1, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig(preempt=False))
    done = []
    for pr, tag in ((PRIORITY_LOW, "lowA"), (PRIORITY_LOW, "lowB"),
                    (PRIORITY_HIGH, "high"), (PRIORITY_NORMAL, "norm")):
        t = _ptask(2, priority=pr)
        proxy.generate(t, 0, (lambda t_: lambda r: done.append(t_))(tag))
    _drain(proxy)
    assert done == ["high", "norm", "lowA", "lowB"]


def test_uniform_priority_is_plain_fifo():
    eng = FakeEngine(slots=1, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig(preempt=False))
    done = []
    for tag in "abc":
        proxy.generate(_ptask(2), 0,
                       (lambda t_: lambda r: done.append(t_))(tag))
    _drain(proxy)
    assert done == ["a", "b", "c"]


# -------------------------------------------------------------- preemption
def test_preemption_pauses_low_for_high():
    """abort-with-retain as a preemption primitive: the low-priority decode
    is paused (pages parked), the high-priority request admits immediately,
    and the victim's continuation resumes to its full budget."""
    eng = FakeEngine(slots=1, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig())
    client = RolloutClient(proxy)
    h_low = client.submit(_ptask(10, priority=PRIORITY_LOW))
    for _ in range(4):
        proxy.step_once()
    assert proxy.num_active == 1
    h_high = client.submit(_ptask(2, priority=PRIORITY_HIGH))
    done_order = []
    h_low.add_done_callback(lambda r: done_order.append("low"))
    h_high.add_done_callback(lambda r: done_order.append("high"))
    _drain(proxy)
    assert done_order == ["high", "low"]
    assert proxy.preemptions == 1
    res = h_low.result(0)
    assert not res.aborted
    assert sum(n for _, n in res.legs) == 10, "stitched to the full budget"
    assert len(res.legs) == 2, "one preemption leg + the resumed leg"
    assert h_high.result(0).tokens is not None
    assert not eng.retained, "victim's parked pages reclaimed on resume"


def test_no_preemption_within_same_class():
    eng = FakeEngine(slots=1, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig())
    client = RolloutClient(proxy)
    h_a = client.submit(_ptask(6, priority=PRIORITY_LOW))
    for _ in range(2):
        proxy.step_once()
    first_active = list(eng.active)
    client.submit(_ptask(6, priority=PRIORITY_LOW))
    for _ in range(2):
        proxy.step_once()
    assert list(eng.active) == first_active, "equal priority never preempts"
    assert proxy.preemptions == 0
    _drain(proxy)
    assert h_a.result(0).tokens is not None


def test_preemption_requires_page_coverage():
    """Preempting frees a SLOT, never pages: when the engine reports it
    cannot cover the arrival's pages, the queue head waits instead of
    uselessly evicting a victim."""
    eng = FakeEngine(slots=1, step_sleep=0)
    eng.can_cover_pages = lambda prompt_len, max_new: False
    proxy = LLMProxy(eng, slo=SLOConfig())
    client = RolloutClient(proxy)
    client.submit(_ptask(8, priority=PRIORITY_LOW))
    for _ in range(2):
        proxy.step_once()
    client.submit(_ptask(2, priority=PRIORITY_HIGH))
    for _ in range(3):
        proxy.step_once()
    assert proxy.preemptions == 0
    assert proxy.num_pending == 1, "head stays queued until pages free up"


# ------------------------------------------------------- admission control
def test_expired_submission_rejected():
    box, clock = _round_clock()
    proxy = LLMProxy(FakeEngine(slots=1, step_sleep=0),
                     slo=SLOConfig(clock=clock))
    client = RolloutClient(proxy)
    box[0] = 10.0
    t = _ptask(4, deadline_ms=2000)
    t.meta["deadline_at"] = 5.0          # stamped at an earlier submission
    h = client.submit(t)
    res = h.result(1)
    assert isinstance(res, Rejected) and res.reason == "expired"
    assert res.aborted
    assert proxy.rejected == 1 and proxy.deadline_misses == 1
    assert proxy.num_pending == 0, "never silently queued"


def test_queue_full_per_class_rejection():
    proxy = LLMProxy(FakeEngine(slots=0, step_sleep=0),
                     slo=SLOConfig(queue_limit_per_class=2))
    client = RolloutClient(proxy)
    kept = [client.submit(_ptask(3)) for _ in range(2)]
    proxy.step_once()                    # move commands into the queue
    h_over = client.submit(_ptask(3))
    res = h_over.result(1)
    assert isinstance(res, Rejected) and res.reason == "queue_full"
    assert proxy.rejected == 1
    # another class still has room
    h_high = client.submit(_ptask(3, priority=PRIORITY_HIGH))
    proxy.step_once()
    assert proxy.pending_by_priority == {PRIORITY_NORMAL: 2, PRIORITY_HIGH: 1}
    for h in kept + [h_high]:
        assert not h.done()


def test_total_bound_sheds_lowest_for_higher_priority():
    """At the total bound a high-priority arrival is admitted by shedding
    the newest queued request of the lowest class — typed ``shed``, not a
    silent drop, and never the other way around."""
    proxy = LLMProxy(FakeEngine(slots=0, step_sleep=0),
                     slo=SLOConfig(queue_limit_total=2))
    client = RolloutClient(proxy)
    h_lowA = client.submit(_ptask(3, priority=PRIORITY_LOW))
    h_lowB = client.submit(_ptask(3, priority=PRIORITY_LOW))
    proxy.step_once()
    h_high = client.submit(_ptask(3, priority=PRIORITY_HIGH))
    proxy.step_once()                    # processes SHED + the new ADD
    res = h_lowB.result(1)
    assert isinstance(res, Rejected) and res.reason == "shed"
    assert not h_lowA.done() and not h_high.done()
    assert proxy.pending_by_priority == {PRIORITY_LOW: 1, PRIORITY_HIGH: 1}
    # a low-priority arrival at the bound has nothing to outrank: rejected
    h_lowC = client.submit(_ptask(3, priority=PRIORITY_LOW))
    assert isinstance(h_lowC.result(1), Rejected)


# ---------------------------------------------------------------- watchdog
def test_deadline_timeout_exactly_once():
    """An active request past its deadline is force-resolved exactly once:
    partial tokens, ``timed_out=True``, pages released, no continuation."""
    box, clock = _round_clock()
    eng = FakeEngine(slots=1, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig(clock=clock))
    client = RolloutClient(proxy)
    resolved = []
    h = client.submit(_ptask(100, deadline_ms=3000))
    h.add_done_callback(resolved.append)
    for _ in range(5):
        proxy.step_once()
    assert proxy.num_active == 1
    box[0] = 4.0                         # past the 3.0 deadline
    for _ in range(3):
        proxy.step_once()
    res = h.result(1)
    assert res.timed_out and res.aborted and res.partial
    assert len(res.tokens) > 0, "partial sample delivered"
    assert len(resolved) == 1, "exactly-once resolution"
    assert proxy.deadline_misses == 1
    assert proxy.num_active == 0 and proxy.num_pending == 0
    assert not eng.retained and not eng.active, "pages released"


def test_pending_expired_work_is_shed():
    box, clock = _round_clock()
    proxy = LLMProxy(FakeEngine(slots=0, step_sleep=0),
                     slo=SLOConfig(clock=clock))
    client = RolloutClient(proxy)
    h = client.submit(_ptask(4, deadline_ms=2000))
    proxy.step_once()
    assert proxy.num_pending == 1
    box[0] = 3.0
    proxy.step_once()
    res = h.result(1)
    assert isinstance(res, Rejected) and res.reason == "expired"
    assert proxy.deadline_misses == 1 and proxy.num_pending == 0


def test_stall_watchdog_times_out_stuck_decode():
    """A decode making no progress for stall_timeout_s is resolved
    ``timed_out`` (stuck engine / hung tool call)."""
    class FrozenEngine(FakeEngine):
        def step(self):
            return []                    # decodes nothing, forever

    box, clock = _round_clock()
    eng = FrozenEngine(slots=1, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig(clock=clock, stall_timeout_s=5.0))
    client = RolloutClient(proxy)
    h = client.submit(_ptask(10))
    proxy.step_once()
    assert proxy.num_active == 1
    box[0] = 3.0
    proxy.step_once()                    # under the stall grace: keeps waiting
    assert proxy.num_active == 1
    box[0] = 6.0
    proxy.step_once()
    res = h.result(1)
    assert res.timed_out and res.aborted
    assert proxy.stall_aborts == 1 and proxy.deadline_misses == 0


def test_long_tail_defer_unblocks_queue():
    """RollPacker-style tail taming: a decode that hit the defer threshold
    while work queues is parked (retain) so the queue drains; its
    continuation resumes later and still reaches the full budget.  The
    lineage tag bounds it to ONE defer."""
    eng = FakeEngine(slots=1, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig(defer_after_tokens=4,
                                        defer_min_remaining=2))
    client = RolloutClient(proxy)
    h_tail = client.submit(_ptask(30))
    for _ in range(6):
        proxy.step_once()
    h_short = client.submit(_ptask(2))
    order = []
    h_tail.add_done_callback(lambda r: order.append("tail"))
    h_short.add_done_callback(lambda r: order.append("short"))
    _drain(proxy)
    assert order == ["short", "tail"], "the tail never blocked completion"
    assert proxy.long_tail_defers == 1, "deferred at most once per lineage"
    res = h_tail.result(0)
    assert not res.aborted and sum(n for _, n in res.legs) == 30


# -------------------------------------------------------- client sessions
def test_session_carries_priority_and_deadline():
    eng = FakeEngine(slots=2, step_sleep=0)
    proxy = LLMProxy(eng, slo=SLOConfig(preempt=False))
    client = RolloutClient(proxy)
    sess = client.session(max_new_tokens=3, priority=PRIORITY_HIGH,
                          deadline_ms=60_000)
    h = sess.turn([1, 2, 3])
    t = threading.Thread(target=lambda: _drain(proxy))
    t.start()
    res = h.result(10)
    t.join()
    assert not res.aborted
    assert h.task.priority == PRIORITY_HIGH
    assert h.task.meta.get("deadline_at") is not None


# ----------------------------------------------------- router front door
def test_router_front_door_admission_and_depths():
    """Fleet-wide bounds live at the router: replicas behind it carry an
    admission-stripped copy, so admitted work is never double-rejected,
    and ``queue_depth_by_class``/counters aggregate over the fleet."""
    slo = SLOConfig(queue_limit_per_class=3)
    engines = [FakeEngine(slots=0, step_sleep=0) for _ in range(2)]
    proxies = [LLMProxy(e, name=f"p{i}", slo=without_admission(slo))
               for i, e in enumerate(engines)]
    router = ProxyRouter(proxies, slo=slo)
    client = RolloutClient(router)
    kept = [client.submit(_ptask(4)) for _ in range(3)]
    for p in proxies:
        p.step_once()
    assert router.queue_depth_by_class == {PRIORITY_NORMAL: 3}
    h_over = client.submit(_ptask(4))
    res = h_over.result(1)
    assert isinstance(res, Rejected) and res.reason == "queue_full"
    assert router.rejected == 1
    for h in kept:
        assert not h.done(), "admitted work untouched by the rejection"


def test_router_expired_group_rejected_per_member():
    slo = SLOConfig()
    engines = [FakeEngine(slots=2, step_sleep=0)]
    proxies = [LLMProxy(engines[0], slo=without_admission(slo))]
    router = ProxyRouter(proxies, slo=slo)
    results = []
    tasks = [_ptask(3, deadline_ms=1000) for _ in range(3)]
    for t in tasks:
        t.meta["deadline_at"] = -1.0     # already past
    ids = router.generate_group(tasks, 0, results.append)
    assert ids == [t.task_id for t in tasks]
    assert len(results) == 3
    assert all(isinstance(r, Rejected) and r.reason == "expired"
               for r in results)
    assert router.rejected == 3


# --------------------------------------------------------- real paged engine
@pytest.fixture(scope="module")
def paged_api():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def _paged(api, params, **kw):
    kw.setdefault("num_slots", 1)
    kw.setdefault("max_total_len", 64)
    return PagedDecodeEngine(api, params, page_size=8, prefill_chunk=8,
                             eos_id=99, temperature=0.0, num_pages=24, **kw)


@pytest.mark.timeout(240)
def test_paged_preempt_resume_zero_reprefill(paged_api):
    """On the real engine: preempting a greedy decode and resuming it from
    its parked pages costs ZERO re-prefilled prefix tokens and produces
    byte-identical output to an uninterrupted run."""
    api, params = paged_api
    rng = np.random.default_rng(3)
    p_low = rng.integers(1, 30, 6).astype(np.int32)
    p_high = rng.integers(1, 30, 4).astype(np.int32)
    budget_low, budget_high = 12, 3

    ref_eng = _paged(api, params)
    ref_proxy = LLMProxy(ref_eng)
    h = RolloutClient(ref_proxy).submit(_task(budget_low, p_low))
    _drain(ref_proxy)
    ref = list(h.result(0).tokens)

    eng = _paged(api, params)
    proxy = LLMProxy(eng, slo=SLOConfig())
    client = RolloutClient(proxy)
    h_low = client.submit(_ptask(budget_low, prompt=p_low,
                                 priority=PRIORITY_LOW))
    for _ in range(6):                   # prefill + a few decode steps
        proxy.step_once()
    h_high = client.submit(_ptask(budget_high, prompt=p_high,
                                  priority=PRIORITY_HIGH))
    _drain(proxy, max_steps=2000)
    res_low = h_low.result(0)
    assert proxy.preemptions == 1
    assert not res_low.aborted
    out = list(res_low.tokens)
    assert out == ref, "preempt+resume must preserve greedy output"
    assert client.reprefills == 0, "resume re-attached pages, no re-prefill"
    assert eng.total_prefill_tokens == len(p_low) + len(p_high), \
        "zero re-prefilled prefix tokens"
    assert h_high.result(0).tokens is not None
    eng.audit_pages()


@pytest.mark.timeout(240)
def test_paged_timeout_releases_pages(paged_api):
    """Deadline timeout on the real engine frees the victim's pages (plain
    abort, nothing parked) and the pool audits clean."""
    api, params = paged_api
    box, clock = _round_clock()
    eng = _paged(api, params, num_slots=2)
    proxy = LLMProxy(eng, slo=SLOConfig(clock=clock))
    client = RolloutClient(proxy)
    free0 = eng.pages_free
    h = client.submit(_ptask(40, prompt=np.asarray([1, 2, 3, 4], np.int32),
                             deadline_ms=5000))
    for _ in range(6):
        proxy.step_once()
    assert proxy.num_active == 1
    box[0] = 6.0
    proxy.step_once()
    res = h.result(1)
    assert res.timed_out and len(res.tokens) > 0
    assert proxy.num_active == 0
    assert eng.pages_free == free0, "timed-out request released its pages"
    eng.audit_pages()
