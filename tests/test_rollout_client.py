"""RolloutClient handle/session API: proxy-owned abort→resume continuation,
streaming, group handles, first-class agentic sessions, and the
non-blocking (overlapped) weight-sync path.

Acceptance-criteria coverage:

* no ``resumed_tokens`` meta threading outside the client layer — resumes
  are transparent and handles resolve exactly once;
* an agentic EnvManager run on the paged engine resumes retained pages
  across a weight sync (asserted via prefill counters);
* ``weight_sync="overlapped"`` keeps rollout stepping during
  ``update_weights`` (no suspend) with greedy parity vs blocking mode.
"""
import threading
import time

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.llm_proxy import LLMProxy
from repro.core.async_controller import AsyncController
from repro.core.rollout_client import GroupHandle, RolloutClient
from repro.core.sample_buffer import SampleBuffer, StaleSampleError
from repro.core.scheduler import RolloutProducer, collect_rollout, expand_tasks
from repro.core.types import GenerationResult, RolloutTask, next_uid
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine


class FakeEngine:
    """Deterministic engine: each request emits 0,1,2,... one per step."""

    def __init__(self, slots=2):
        self.slots = slots
        self.active = {}
        self.weights_version = 0
        self.update_count = 0

    @property
    def num_free_slots(self):
        return self.slots - len(self.active)

    def add_request(self, rid, prompt, max_new):
        assert self.num_free_slots > 0
        self.active[rid] = {"left": int(max_new), "toks": []}

    def peek_tokens(self, rid, start=0):
        st = self.active.get(rid)
        return [] if st is None else list(st["toks"][start:])

    def abort(self, rid):
        st = self.active.pop(rid)
        return GenerationResult(request_id=rid, task=None,
                                tokens=np.asarray(st["toks"], np.int32),
                                logprobs=np.zeros(len(st["toks"]), np.float32),
                                version_started=-1, aborted=True, partial=True)

    def step(self):
        time.sleep(0.001)
        done = []
        for rid, st in list(self.active.items()):
            st["toks"].append(len(st["toks"]))
            st["left"] -= 1
            if st["left"] <= 0:
                done.append((rid, np.asarray(st["toks"], np.int32),
                             np.zeros(len(st["toks"]), np.float32)))
                del self.active[rid]
        return done

    def update_weights(self, params):
        self.weights_version = params
        self.update_count += 1


def _task(n=3, prompt=(1, 2)):
    return RolloutTask(task_id=next_uid(), prompt_id=0, replica_idx=0,
                       prompt_tokens=np.asarray(prompt, np.int32),
                       max_new_tokens=n)


# ------------------------------------------------------------------ handles
def test_handle_result_blocks_until_done():
    proxy = LLMProxy(FakeEngine()).start()
    client = RolloutClient(proxy)
    h = client.submit(_task(4))
    res = h.result(timeout=10)
    proxy.stop()
    assert h.done() and not res.aborted
    assert list(res.tokens) == [0, 1, 2, 3]
    assert res.legs == [(0, 4)]
    assert res.version_started == 0


def test_handle_result_timeout():
    proxy = LLMProxy(FakeEngine()).start()
    client = RolloutClient(proxy)
    h = client.submit(_task(100_000))
    with pytest.raises(TimeoutError):
        h.result(timeout=0.05)
    h.abort()
    proxy.stop()


def test_handle_abort_cancels_and_resolves_aborted():
    proxy = LLMProxy(FakeEngine(slots=1)).start()
    client = RolloutClient(proxy)
    h = client.submit(_task(100_000))
    time.sleep(0.05)
    h.abort()                       # retain=False => cancel for good
    res = h.result(timeout=10)
    proxy.stop()
    assert res.aborted and res.partial
    assert len(res.tokens) > 0
    assert client.num_inflight == 0


def test_abort_of_pending_unadmitted_request_still_resolves():
    """Cancelling a handle whose request is queued behind a full engine
    (never admitted) must still resolve it — the proxy fires an empty
    aborted result for pending drops."""
    proxy = LLMProxy(FakeEngine(slots=1)).start()
    client = RolloutClient(proxy)
    h1 = client.submit(_task(100_000))
    h2 = client.submit(_task(10))          # queued: the only slot is busy
    time.sleep(0.05)
    h2.abort()
    res2 = h2.result(timeout=10)
    h1.abort()
    h1.result(timeout=10)
    proxy.stop()
    assert res2.aborted and len(res2.tokens) == 0


def test_handle_resolves_exactly_once_across_continuation_legs():
    """abort_stale interrupts; the client transparently re-admits; the
    handle's done-callback fires exactly once, with the stitched result."""
    proxy = LLMProxy(FakeEngine(slots=1)).start()
    client = RolloutClient(proxy, version_fn=lambda: 7)
    h = client.submit(_task(50), version=0)
    fired = []
    h.add_done_callback(fired.append)
    time.sleep(0.02)                # a few tokens decode
    proxy.abort_stale(min_version=5)
    res = h.result(timeout=10)
    time.sleep(0.05)
    proxy.stop()
    assert len(fired) == 1 and fired[0] is res
    assert not res.aborted
    # FakeEngine restarts its counter per leg: stitched = 0..k-1, 0, 1, ...
    toks = list(res.tokens)
    assert len(toks) == 50 and toks[0] == 0 and 0 in toks[1:]
    assert len(res.legs) >= 2, "multi-leg result"
    assert res.legs[0][0] == 0 and res.legs[-1][0] == 7, \
        "legs carry their policy versions"
    assert res.version_started == 7, "final result tagged with last leg"
    assert sum(n for _, n in res.legs) == 50
    assert client.reprefills >= 1   # FakeEngine has no retain support


def test_handle_abort_retain_readmits_transparently():
    """handle.abort(retain=True) is an interrupt, not a cancel: the request
    is re-admitted and the handle resolves once with the full response."""
    proxy = LLMProxy(FakeEngine(slots=1)).start()
    client = RolloutClient(proxy)
    h = client.submit(_task(30))
    time.sleep(0.02)
    h.abort(retain=True)
    res = h.result(timeout=10)
    proxy.stop()
    assert not res.aborted and len(res.tokens) == 30
    assert len(res.legs) >= 2


def test_group_handle_results():
    proxy = LLMProxy(FakeEngine(slots=4)).start()
    client = RolloutClient(proxy)
    tasks = expand_tasks(0, np.asarray([1, 2], np.int32), 3, 5,
                         replicate=True)
    gh = client.submit_group(tasks)
    results = gh.results(timeout=10)
    proxy.stop()
    assert gh.done() and len(results) == 3
    assert all(list(r.tokens) == [0, 1, 2, 3, 4] for r in results)
    assert len({r.task.replica_idx for r in results}) == 3


def test_stream_yields_incremental_chunks():
    proxy = LLMProxy(FakeEngine(slots=2)).start()
    client = RolloutClient(proxy)
    h = client.submit(_task(20), stream=True)
    chunks = list(h.stream())
    res = h.result(timeout=10)
    proxy.stop()
    assert len(chunks) >= 2, "tokens must arrive incrementally"
    assert list(np.concatenate(chunks)) == list(res.tokens)


def test_stream_after_resolution_returns_final_chunk():
    proxy = LLMProxy(FakeEngine()).start()
    client = RolloutClient(proxy)
    h = client.submit(_task(4))
    h.result(timeout=10)
    chunks = list(h.stream())
    proxy.stop()
    assert len(chunks) == 1 and list(chunks[0]) == [0, 1, 2, 3]


def test_stream_after_resolution_clamps_to_budget_and_consumes():
    """Regression: a budget-overrun multi-leg handle must stream exactly
    the clamped tokens, once (second stream() yields nothing new)."""
    class _P:
        def __init__(self):
            self.cbs = {}

        def generate(self, task, version, cb, **kw):
            self.cbs[task.task_id] = cb
            return task.task_id

        def generate_resumed(self, task, version, cb, resume_from, **kw):
            self.cbs[task.task_id] = cb
            return task.task_id

        def release_retained(self, rid):
            pass

    p = _P()
    client = RolloutClient(p)
    t = _task(4)
    h = client.submit(t, version=0)
    p.cbs[t.task_id](GenerationResult(
        request_id=t.task_id, task=t, tokens=np.asarray([5, 6, 7], np.int32),
        logprobs=np.zeros(3, np.float32), version_started=0, aborted=True,
        partial=True, resumable=True))
    leg2_rid = next(r for r in p.cbs if r != t.task_id)
    p.cbs[leg2_rid](GenerationResult(
        request_id=leg2_rid, task=t, tokens=np.asarray([8, 9], np.int32),
        logprobs=np.zeros(2, np.float32), version_started=0, aborted=True,
        partial=True, resumable=True))          # 5 decoded > budget 4
    res = h.result(0)
    assert list(res.tokens) == [5, 6, 7, 8]
    assert [list(c) for c in h.stream()] == [[5, 6, 7, 8]]
    assert list(h.stream()) == [], "stream is consumed, not replayed"


def test_stream_rejected_for_expanded_tasks():
    client = RolloutClient(proxy=None)
    task, = expand_tasks(0, np.asarray([1, 2], np.int32), 3, 4,
                         replicate=False)
    with pytest.raises(ValueError, match="stream"):
        client.submit(task, stream=True)
    proxy = LLMProxy(FakeEngine(slots=4))
    with pytest.raises(ValueError, match="stream_cb"):
        proxy.generate(task, 0, lambda r: None, stream_cb=lambda t: None)


# --------------------------------------------- num_return_sequences parity
def test_client_expands_num_return_sequences_to_group_handle():
    proxy = LLMProxy(FakeEngine(slots=4)).start()
    client = RolloutClient(proxy)
    task, = expand_tasks(0, np.asarray([1, 2], np.int32), 3, 4,
                         replicate=False)
    assert task.meta["num_return_sequences"] == 3
    h = client.submit(task)
    assert isinstance(h, GroupHandle)
    results = h.results(timeout=10)
    proxy.stop()
    assert len(results) == 3
    assert len({r.task.task_id for r in results}) == 3
    assert all(r.task.group_id == task.group_id for r in results)
    assert all("num_return_sequences" not in r.task.meta for r in results)


def test_proxy_honors_num_return_sequences():
    """The raw proxy also expands the non-replicated encoding: one ADD
    yields G results keyed to one group id."""
    proxy = LLMProxy(FakeEngine(slots=4)).start()
    task, = expand_tasks(0, np.asarray([1, 2], np.int32), 3, 4,
                         replicate=False)
    results = []
    lock = threading.Lock()

    def cb(r):
        with lock:
            results.append(r)

    rids = proxy.generate(task, version=0, callback=cb)
    assert isinstance(rids, list) and len(rids) == 3
    deadline = time.monotonic() + 10
    while len(results) < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    proxy.stop()
    assert len(results) == 3
    assert {r.task.replica_idx for r in results} == {0, 1, 2}
    assert all(r.task.group_id == task.group_id for r in results)


@pytest.mark.timeout(240)
def test_non_replicate_end_to_end_parity_paged():
    """replicate=False must yield exactly G samples per prompt through the
    paged engine, byte-identical (greedy) to the replicate=True path."""
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompts = [(i, rng.integers(1, 30, 6).astype(np.int32)) for i in range(2)]

    def run(replicate):
        eng = PagedDecodeEngine(api, params, num_slots=8, max_total_len=32,
                                page_size=8, prefill_chunk=8, eos_id=99,
                                temperature=0.0)
        proxy = LLMProxy(eng).start()
        out = collect_rollout(proxy, iter(prompts), num_groups=2,
                              group_size=3, max_new_tokens=4,
                              reward_fn=lambda s: 1.0, replicate=replicate,
                              timeout=120)
        proxy.stop()
        return out

    a, b = run(True), run(False)
    assert len(a) == len(b) == 6
    for out in (a, b):
        gids = {}
        for s in out:
            gids.setdefault(s.group_id, []).append(s)
        assert all(len(g) == 3 for g in gids.values()), \
            "every group must assemble exactly G samples"
    key = lambda s: (s.prompt_id, s.replica_idx)
    for sa, sb in zip(sorted(a, key=key), sorted(b, key=key), strict=True):
        assert list(sa.response_tokens) == list(sb.response_tokens)


# ------------------------------------------------------ paged continuation
@pytest.fixture(scope="module")
def paged_setup():
    cfg = tiny("qwen3-4b", vocab_size=32)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _paged(api, params, **kw):
    base = dict(num_slots=4, max_total_len=64, page_size=8, prefill_chunk=8,
                eos_id=99, temperature=0.0)
    base.update(kw)
    return PagedDecodeEngine(api, params, **base)


@pytest.mark.timeout(240)
def test_paged_resume_across_weight_sync_zero_reprefill(paged_setup):
    """A client-submitted request aborted-with-retain across a staged
    weight sync re-attaches its pages: ZERO additional prefill tokens and
    the greedy output equals the uninterrupted run."""
    cfg, api, params = paged_setup
    prompt = np.asarray([2, 9, 4, 3], np.int32)
    budget = 40

    ref = _paged(api, params)
    ref.add_request(0, prompt, budget)
    base = None
    while base is None:
        for _rid, toks, _ in ref.step():
            base = list(toks)

    eng = _paged(api, params)
    proxy = LLMProxy(eng).start()
    client = RolloutClient(proxy, version_fn=lambda: 1)
    h = client.submit(_task(budget, prompt), version=0)
    deadline = time.monotonic() + 30
    while eng.total_tokens_decoded < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    prefill_before = eng.total_prefill_tokens
    ev = proxy.update_weights_async(params)      # overlapped sync, no suspend
    assert ev.wait(30)
    proxy.abort_stale(min_version=1, retain=True)
    res = h.result(timeout=60)
    proxy.stop()
    assert not res.aborted
    assert list(res.tokens) == base, "resume must preserve greedy output"
    assert client.resumes == 1 and client.reprefills == 0
    assert eng.total_prefill_tokens == prefill_before, \
        "retained-page resume must not re-prefill anything"
    assert proxy.suspend_count == 0
    assert not eng.retained
    eng.audit_pages()


@pytest.mark.timeout(240)
def test_env_manager_session_resumes_across_weight_sync(paged_setup):
    """Acceptance: an agentic EnvManager run on the paged engine resumes
    retained pages across a weight sync — the trajectory survives, nothing
    re-prefills, and the turn's legs span both policy versions."""
    from repro.core.env_manager import EnvManagerPool
    from repro.envs.base import BaseEnv

    class OneStepEnv(BaseEnv):
        def __init__(self, env_id):
            pass

        def reset(self):
            return np.asarray([11, 12, 13, 14, 15, 16, 17, 18], np.int32)

        def step(self, action):
            return np.asarray([21] * 8, np.int32), 1.0, True, {}

    cfg, api, params = paged_setup
    eng = _paged(api, params, num_slots=2)
    proxy = LLMProxy(eng).start()
    buf = SampleBuffer(batch_size=1, alpha=4)
    pool = EnvManagerPool(OneStepEnv, proxy, buf, num_env_groups=1,
                          group_size=1, max_steps=2, max_new_tokens=32,
                          target_trajectories=1)
    pool.start()
    deadline = time.monotonic() + 60
    while eng.total_tokens_decoded < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert eng.total_tokens_decoded >= 2, "turn never started decoding"
    prefill_before = eng.total_prefill_tokens
    # the controller's overlapped sync: staged swap, version++, abort stale
    ev = proxy.update_weights_async(params)
    assert ev.wait(30)
    new_v = buf.advance_version()
    proxy.abort_stale(min_version=new_v, retain=True)
    batch = buf.get_batch(1, timeout=120)
    pool.stop()
    proxy.stop()
    assert len(batch) == 1, "trajectory must survive the weight sync"
    assert pool.client.resumes >= 1, "retained pages must be re-attached"
    assert eng.total_prefill_tokens == prefill_before, \
        "the in-flight turn must not re-prefill after the sync"
    mgr = pool.managers[0]
    assert mgr.client is pool.client


# --------------------------------------------------------------- sessions
def test_session_context_and_version_tags():
    proxy = LLMProxy(FakeEngine(slots=2)).start()
    versions = [3]
    client = RolloutClient(proxy, version_fn=lambda: versions[0])
    sess = client.session(max_new_tokens=4, context_mode="full",
                          max_context_tokens=48)
    r1 = sess.turn(np.asarray([5, 6], np.int32)).result(timeout=10)
    versions[0] = 4
    r2 = sess.turn(np.asarray([7, 8], np.int32)).result(timeout=10)
    proxy.stop()
    assert sess.turn_versions == [3, 4]
    assert len(sess.context) == 4            # obs, action, obs, action
    np.testing.assert_array_equal(sess.context[0], [5, 6])
    np.testing.assert_array_equal(sess.context[1], r1.tokens)
    # turn 2's prompt is the full conversation + the new observation
    assert r2.task.meta["turn"] == 1
    np.testing.assert_array_equal(
        r2.task.prompt_tokens,
        np.concatenate([np.asarray([5, 6]), np.asarray(r1.tokens),
                        np.asarray([7, 8])]))


def test_session_validation():
    client = RolloutClient(proxy=None)
    with pytest.raises(ValueError, match="context_mode"):
        client.session(max_new_tokens=4, context_mode="bogus")
    with pytest.raises(ValueError, match="max_context_tokens"):
        client.session(max_new_tokens=4, context_mode="full")


def test_session_turn_mode_prompt_is_bare_observation():
    proxy = LLMProxy(FakeEngine(slots=2)).start()
    client = RolloutClient(proxy)
    sess = client.session(max_new_tokens=3, context_mode="turn")
    sess.turn(np.asarray([5, 6], np.int32)).result(timeout=10)
    r2 = sess.turn(np.asarray([9], np.int32)).result(timeout=10)
    proxy.stop()
    np.testing.assert_array_equal(r2.task.prompt_tokens, [9])
    assert len(sess.context) == 4, "context is tracked even in turn mode"


# ------------------------------------------------- overlapped weight sync
def test_overlapped_weight_sync_never_suspends_and_keeps_stepping():
    eng = FakeEngine(slots=2)
    proxy = LLMProxy(eng).start()
    client = RolloutClient(proxy)
    h = client.submit(_task(100_000))
    time.sleep(0.05)
    steps_before = proxy.steps_executed
    ev = proxy.update_weights_async("v1")
    assert ev.wait(10)
    time.sleep(0.05)
    steps_after = proxy.steps_executed
    h.abort()
    proxy.stop()
    assert eng.weights_version == "v1"
    assert proxy.suspend_count == 0, "overlapped sync must not suspend"
    assert steps_after > steps_before, "rollout must keep advancing"
    assert proxy.staged_weight_updates == 1


def _controller_fixture(weight_sync, alpha=1):
    eng = FakeEngine(slots=8)
    proxy = LLMProxy(eng).start()
    buf = SampleBuffer(batch_size=4, alpha=alpha)

    def prompts():
        i = 0
        while True:
            yield i, np.asarray([1, 2], np.int32)
            i += 1

    prod = RolloutProducer(proxy, buf, prompts(), group_size=1,
                           max_new_tokens=3, reward_fn=lambda s: 1.0)
    prod.start()
    ctrl = AsyncController(buf, [proxy], lambda batch: {},
                           lambda: "weights", alpha=alpha,
                           weight_sync=weight_sync)
    return eng, proxy, buf, prod, ctrl


@pytest.mark.parametrize("weight_sync", ["blocking", "overlapped"])
def test_controller_weight_sync_modes(weight_sync):
    eng, proxy, buf, prod, ctrl = _controller_fixture(weight_sync)
    try:
        stats = ctrl.train(3, timeout=60)
    finally:
        prod.stop()
        buf.close()
        proxy.stop()
    assert len(stats) == 3
    assert all(s.staleness_max <= 1 for s in stats), \
        "staleness accounting must hold in both modes"
    assert eng.update_count == 3 and eng.weights_version == "weights"
    if weight_sync == "overlapped":
        assert proxy.suspend_count == 0, "no global suspend barrier"
    else:
        assert proxy.suspend_count == 3


def test_controller_rejects_unknown_weight_sync():
    with pytest.raises(ValueError, match="weight_sync"):
        AsyncController(SampleBuffer(1), [], lambda b: {}, lambda: None,
                        weight_sync="bogus")


@pytest.mark.timeout(240)
def test_overlapped_vs_blocking_greedy_parity(paged_setup):
    """Same params swapped mid-flight by either mode: greedy outputs are
    identical (the staged swap happens between engine steps, exactly like
    the barrier — it just doesn't stop the world)."""
    cfg, api, params = paged_setup
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)

    def run(mode):
        eng = _paged(api, params, num_slots=2)
        proxy = LLMProxy(eng).start()
        client = RolloutClient(proxy)
        h = client.submit(_task(24, prompt))
        deadline = time.monotonic() + 30
        while eng.total_tokens_decoded < 2 and time.monotonic() < deadline:
            time.sleep(0.005)
        if mode == "blocking":
            proxy.suspend()
            proxy.update_weights(params)
            proxy.resume()
        else:
            assert proxy.update_weights_async(params).wait(30)
        res = h.result(timeout=60)
        proxy.stop()
        return list(res.tokens), proxy.suspend_count

    toks_b, susp_b = run("blocking")
    toks_o, susp_o = run("overlapped")
    assert toks_b == toks_o
    assert susp_b == 1 and susp_o == 0


# ------------------------------------------------------- buffer lock fix
def test_get_batch_strict_check_uses_consume_time_version():
    """Regression (lock-dropped staleness check): a concurrent
    advance_version between consumption and the strict re-check must not
    fail a batch that was admissible when consumed.  Eviction by an
    advance that wins the race (TimeoutError) is fine; StaleSampleError
    for an admissible batch is the bug."""
    from repro.core.types import Sample

    for _ in range(30):
        buf = SampleBuffer(batch_size=1, alpha=0, strict=True)
        buf.try_begin_generation()
        buf.put(Sample(sample_id=0, prompt_id=0, replica_idx=0,
                       prompt_tokens=np.zeros(1, np.int32),
                       response_tokens=np.zeros(1, np.int32),
                       logprobs=np.zeros(1, np.float32), version_started=0))
        start = threading.Barrier(3)
        errors = []

        def consume():
            start.wait()
            try:
                buf.get_batch(1, timeout=0.05)
            except StaleSampleError as e:
                errors.append(e)
            except TimeoutError:
                pass               # advance won the race and evicted: fine

        def advance():
            start.wait()
            buf.advance_version()

        t1 = threading.Thread(target=consume)
        t2 = threading.Thread(target=advance)
        t1.start(), t2.start()
        start.wait()
        t1.join(), t2.join()
        assert not errors, f"admissible batch failed the strict check: {errors}"
