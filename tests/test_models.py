"""Per-architecture smoke tests (deliverable f) + prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.algos import LossConfig
from repro.configs import REGISTRY, list_archs
from repro.models import get_api
from repro.train.optimizer import OptConfig
from repro.train.trainer import make_train_state, make_train_step

ALL_ARCHS = list_archs()


def make_batch(cfg, b, s, key):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = (jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.num_image_tokens, cfg.d_model))
            * 0.1).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = (jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.encoder_frames, cfg.d_model))
            * 0.1).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch, rng_key):
    """Reduced variant of the same family: one forward, shapes + finiteness."""
    cfg = REGISTRY[arch].smoke()
    assert cfg.num_layers <= max(2, len(cfg.block_pattern or ())) and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    api = get_api(cfg)
    params = api.init(rng_key)
    b, s = 2, 16
    batch = make_batch(cfg, b, s, jax.random.fold_in(rng_key, 7))
    logits, aux = api.apply(params, batch)
    expect_s = s + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.slow
@pytest.mark.timeout(120)
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, rng_key):
    """One RL train step on the reduced variant: finite loss, params move."""
    cfg = tiny(arch)
    api = get_api(cfg)
    state = make_train_state(api, rng_key)
    step = make_train_step(api, LossConfig(pg_variant="ppo"),
                           OptConfig(learning_rate=1e-2, warmup_steps=1),
                           remat=True, moe_mode="dense" if cfg.is_moe else "ep")
    b, s = 2, 16
    key = jax.random.fold_in(rng_key, 3)
    batch = make_batch(cfg, b, s, key)
    tok_s = batch["tokens"].shape[1]
    mask = jnp.zeros((b, tok_s)).at[:, tok_s // 2:].set(1.0)
    lp = -jnp.abs(jax.random.normal(key, (b, tok_s)))
    batch.update(mask=mask, advantages=mask * 0.5, old_logprobs=lp,
                 prox_logprobs=lp, ref_logprobs=lp,
                 is_positive=jnp.ones((b,)))
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    before = jax.tree_util.tree_leaves(state["params"])[0]
    after = jax.tree_util.tree_leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


@pytest.mark.slow
@pytest.mark.timeout(120)
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_full(arch, rng_key):
    """Engine paths == teacher-forcing forward, token by token."""
    cfg = tiny(arch)
    api = get_api(cfg)
    params = api.init(jax.random.fold_in(rng_key, hash(arch) % 1000))
    b, s = 2, 12
    batch = make_batch(cfg, b, s, jax.random.fold_in(rng_key, 11))
    mm = "dense" if cfg.is_moe else "ep"
    full, _ = api.apply(params, batch, moe_mode=mm)
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0

    p = s - 4
    cache = api.init_cache(b, s + 4)
    lp, cache = api.prefill(params, dict(batch, tokens=batch["tokens"][:, :p]),
                            cache, moe_mode=mm)
    assert lp.shape == (b, cfg.vocab_size)  # last-position logits only
    np.testing.assert_allclose(np.asarray(lp),
                               np.asarray(full[:, off + p - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(p, s):
        lg, cache = api.decode_step(params, batch["tokens"][:, t],
                                    jnp.full((b,), t + off, jnp.int32), cache,
                                    moe_mode=mm)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, off + t]),
                                   rtol=2e-2, atol=2e-2)


def test_sliding_window_restricts_attention(rng_key):
    """SWA arch must differ from full attention beyond the window."""
    cfg = tiny("h2o-danube-3-4b", sliding_window=4)
    cfg_full = dataclasses.replace(cfg, sliding_window=None)
    api, api_full = get_api(cfg), get_api(cfg_full)
    params = api.init(rng_key)
    batch = make_batch(cfg, 1, 16, rng_key)
    lw, _ = api.apply(params, batch)
    lf, _ = api_full.apply(params, batch)
    # first `window` positions identical, later positions diverge
    np.testing.assert_allclose(np.asarray(lw[:, :4]), np.asarray(lf[:, :4]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(lw[:, -1] - lf[:, -1]).max()) > 1e-4


def test_moe_capacity_vs_dense_agree_with_headroom(rng_key):
    cfg = tiny("dbrx-132b", capacity_factor=8.0)
    api = get_api(cfg)
    params = api.init(rng_key)
    batch = make_batch(cfg, 2, 16, rng_key)
    ld, _ = api.apply(params, batch, moe_mode="dense")
    le, _ = api.apply(params, batch, moe_mode="ep")
    np.testing.assert_allclose(np.asarray(ld), np.asarray(le), atol=1e-2)


def test_moe_load_balance_loss_bounds(rng_key):
    cfg = tiny("qwen3-moe-235b-a22b")
    api = get_api(cfg)
    params = api.init(rng_key)
    batch = make_batch(cfg, 2, 32, rng_key)
    _, aux = api.apply(params, batch, moe_mode="ep")
    # E * sum(f_e * P_e) >= 1 with equality at perfect balance
    assert float(aux["load_balance_loss"]) >= 0.99
