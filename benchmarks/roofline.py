"""Roofline analysis (deliverable g): per (arch x shape x mesh) —

  compute    = FLOPs / (chips x 197 TFLOP/s bf16)
  memory     = bytes  / (chips x 819 GB/s HBM)
  collective = collective bytes / (50 GB/s ICI per chip)

Numerators come from two sources, both reported:
  * HLO: compiled.cost_analysis() from the dry-run JSONs (per-device —
    NOTE: XLA's cost analysis does not multiply `while` trip counts, so
    scan-over-layers bodies are counted once; the analytic model corrects
    for this and the HLO/analytic ratio is reported per row).
  * analytic: 6*N_active*D (+ attention quadratic terms) and a first-
    principles HBM-traffic model (params + optimizer + KV-cache streams).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import REGISTRY, SHAPES
from repro.models import get_api

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

_COLL_KEYS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")


# ---------------------------------------------------------------------------
# analytic workload model
# ---------------------------------------------------------------------------

def param_counts(cfg) -> Dict[str, float]:
    api = get_api(cfg)
    shapes = jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = active = embed = 0.0
    moe_scale = (cfg.num_experts_per_tok / cfg.num_experts) if cfg.is_moe else 1.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1.0
        for d in leaf.shape:
            n *= d
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        total += n
        if "embed" in name:
            embed += n
            continue
        if "/moe/w_" in name or name.endswith("moe/w_gate") \
                or "moe/w_up" in name or "moe/w_down" in name:
            active += n * moe_scale
        else:
            active += n
    return {"total": total, "active_nonembed": active, "embed": embed}


def _attn_layers(cfg):
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        per = sum(1 for k in cfg.block_pattern if k == "attn")
        groups = cfg.num_layers // len(cfg.block_pattern)
        return per * groups + sum(
            1 for k in cfg.block_pattern[: cfg.num_layers
                                         - groups * len(cfg.block_pattern)]
            if k == "attn")
    return cfg.num_layers


def analytic_flops(cfg, shape) -> float:
    """Global model FLOPs per step (MODEL_FLOPS in the deliverable)."""
    counts = param_counts(cfg)
    n_act = counts["active_nonembed"]
    b, s = shape.global_batch, shape.seq_len
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    la = _attn_layers(cfg)

    if shape.kind == "train":
        tokens = b * s
        core = 6.0 * n_act * tokens
        eff_s = min(s, cfg.sliding_window or s)
        attn = 3.0 * (4.0 * b * s * eff_s * 0.5 * hq * hd) * la
        return core + attn
    if shape.kind == "prefill":
        tokens = b * s
        eff_s = min(s, cfg.sliding_window or s)
        return 2.0 * n_act * tokens + 4.0 * b * s * eff_s * 0.5 * hq * hd * la
    # decode: one token per sequence
    eff_s = min(s, cfg.sliding_window or s)
    return 2.0 * n_act * b + 4.0 * b * eff_s * hq * hd * la


def analytic_bytes(cfg, shape, cache_bytes: float) -> float:
    """Global HBM traffic per step (bytes): parameter/optimizer streams +
    cache streams.  Activation traffic assumed fused/secondary."""
    counts = param_counts(cfg)
    n = counts["total"]
    if shape.kind == "train":
        # params bf16 r + grads bf16 w + master/m/v fp32 r+w + new params w
        return 2 * n + 2 * n + 3 * (4 + 4) * n + 2 * n
    if shape.kind == "prefill":
        return 2 * n + cache_bytes  # write the cache once
    # decode: stream weights (active experts only for MoE) + read cache
    moe_scale = (cfg.num_experts_per_tok / cfg.num_experts) if cfg.is_moe else 1.0
    # per decoded token every *active* weight is read once
    w = 2 * (counts["active_nonembed"] + counts["embed"] * 0.01)
    return w * 1.0 + cache_bytes  # cache read per step


def cache_nbytes(cfg, shape) -> float:
    api = get_api(cfg)
    tree = jax.eval_shape(lambda: api.init_cache(shape.global_batch, shape.seq_len))
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(tree):
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def _advice(dominant: str, shape_kind: str, arch: str) -> str:
    if dominant == "collective":
        return "reduce resharding: align layouts across sharded ops / overlap collectives with compute"
    if dominant == "memory":
        if shape_kind == "decode":
            return "decode is HBM-bound (the paper's premise): shrink KV via GQA/window/quantization or batch more requests per weight read"
        return "increase arithmetic intensity: larger per-chip batch or fused optimizer"
    return "compute-bound: good; next lever is MXU utilization (tile alignment) and causal-block skipping"


def run() -> None:
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline.error", 0, "no dry-run records; run repro.launch.dryrun")
        return
    rows = []
    for path in files:
        rec = json.load(open(path))
        if rec.get("status") != "ok" or "shape" not in rec:
            continue  # skipped combos / pools-mode records
        arch, shape_name, mesh = rec["arch"], rec["shape"], rec["mesh"]
        cfg = REGISTRY[arch]
        shape = SHAPES[shape_name]
        chips = rec["devices"]

        model_flops = analytic_flops(cfg, shape)
        cbytes = cache_nbytes(cfg, shape)
        model_bytes = analytic_bytes(cfg, shape, cbytes)
        hlo_flops_dev = rec["flops"]
        hlo_bytes_dev = rec["bytes_accessed"]
        coll_dev = sum(rec["collectives"].get(k, 0.0) for k in _COLL_KEYS)

        t_compute = model_flops / (chips * PEAK_FLOPS)
        t_memory = model_bytes / (chips * HBM_BW)
        t_coll = coll_dev / ICI_BW
        terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        ratio = model_flops / max(hlo_flops_dev * chips, 1.0)

        rows.append(dict(arch=arch, shape=shape_name, mesh=mesh, chips=chips,
                         t_compute=t_compute, t_memory=t_memory, t_coll=t_coll,
                         dominant=dominant, model_flops=model_flops,
                         hlo_flops_dev=hlo_flops_dev,
                         hlo_bytes_dev=hlo_bytes_dev,
                         flops_ratio=ratio,
                         peak_gib=rec["memory"]["peak_bytes"] / 2**30,
                         advice=_advice(dominant, shape.kind, arch)))
        emit(f"roofline.{arch}.{shape_name}.{mesh}.compute_s", t_compute, "")
        emit(f"roofline.{arch}.{shape_name}.{mesh}.memory_s", t_memory, "")
        emit(f"roofline.{arch}.{shape_name}.{mesh}.collective_s", t_coll,
             f"dominant={dominant};model/hlo_flops={ratio:.2f};"
             f"peakGiB={rows[-1]['peak_gib']:.1f}")

    out = os.path.join(DRYRUN_DIR, "..", "roofline_table.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    emit("roofline.rows", len(rows), f"table at {os.path.normpath(out)}")


if __name__ == "__main__":
    run()
