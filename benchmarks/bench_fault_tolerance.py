"""Fault tolerance: kill a replica mid-workload, measure what survives.

At fleet scale, replica crashes are routine; the elastic ``ProxyRouter``
answers them by failing every in-flight handle on the dead replica over
through the client's abort→resume path (re-admit the concatenated prefix
on a survivor).  This benchmark quantifies the cost of one crash on the
REAL rollout stack — N ``PagedDecodeEngine`` + ``LLMProxy`` replicas
behind ``FaultyProxy`` wrappers and a router, driven in deterministic
lockstep (makespan in *rounds* = parallel hardware time):

* run the long-tail workload crash-free → baseline makespan;
* rerun it, killing 1 replica 25% into the baseline makespan → fault
  makespan.  The kill round and victim are fixed per seed, so both runs
  are exactly reproducible.

Measured per seed:

* **recovered vs lost work** — every handle must resolve with its full
  budget and (greedy decoding) byte-identical output to the crash-free
  run: completed samples lost = 0 by construction or the bench fails.
  The only waste is ``lost_tokens`` — decode progress of the victim's
  in-flight requests at the kill, re-computed on survivors.
* **makespan degradation** — (fault − base) / base rounds.  Killing 1 of
  N replicas a quarter of the way in re-spreads ~3/4 of the work over
  N−1 replicas, so degradation should stay ≤ 2/N (the acceptance bound:
  ~2x the victim's fair share of the remaining work).

Emits BENCH_fault_tolerance.json.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.core.faults import wrap_fleet
from repro.core.llm_proxy import LLMProxy
from repro.core.rollout_client import RolloutClient
from repro.core.router import ProxyRouter
from repro.core.types import RolloutTask, next_uid
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine

NUM_REPLICAS = 4
NUM_REQUESTS = 32
SLOTS_PER_REPLICA = 2
PAGE_SIZE = 16
PREFILL_CHUNK = 16
MAX_TOTAL_LEN = 80
# same long-tail regime as bench_queue_scheduling: the tail carries most
# of the decode work, so a crash that orphans a tail request is the
# expensive case worth measuring.
BUDGETS = [2] * 20 + [8] * 6 + [40] * 6
PROMPT_LENGTHS = [8, 12, 16, 20]
SEEDS = (0, 1)
KILL_FRACTION = 0.25          # kill 25% into the baseline makespan
DEGRADATION_BOUND = 2.0 / NUM_REPLICAS


def _workload(seed: int):
    rng = np.random.default_rng(seed)
    budgets = np.array(BUDGETS)
    rng.shuffle(budgets)
    prompts = [rng.integers(1, 60, PROMPT_LENGTHS[i % len(PROMPT_LENGTHS)])
               .astype(np.int32) for i in range(NUM_REQUESTS)]
    return [(prompts[i], int(budgets[i])) for i in range(NUM_REQUESTS)]


def _fleet(api, params):
    engines = [PagedDecodeEngine(api, params, num_slots=SLOTS_PER_REPLICA,
                                 max_total_len=MAX_TOTAL_LEN,
                                 page_size=PAGE_SIZE,
                                 prefill_chunk=PREFILL_CHUNK, eos_id=9999,
                                 temperature=0.0)
               for _ in range(NUM_REPLICAS)]
    proxies = wrap_fleet([LLMProxy(e, name=f"ft_proxy_{i}")
                          for i, e in enumerate(engines)])
    return engines, proxies, ProxyRouter(proxies)


def _run(api, params, workload, *, kill_round=None, victim=None):
    """Drive the workload in lockstep; optionally crash ``victim`` at
    ``kill_round``.  Queue-scheduled dispatch keeps at most one request
    per LIVE fleet slot in flight.  Returns a result dict."""
    engines, proxies, router = _fleet(api, params)
    client = RolloutClient(router)
    handles = {}
    todo = list(enumerate(workload))
    rounds = 0
    busy = 0
    completed_at_kill = None
    t0 = time.perf_counter()
    while todo or not all(h.done() for h in handles.values()):
        if kill_round is not None and rounds == kill_round:
            completed_at_kill = router.requests_completed
            proxies[victim].kill()
            router.probe_health()       # detect + fail over, this round
        alive_slots = router.replicas_alive * SLOTS_PER_REPLICA
        submitted = False
        while todo and (sum(not h.done() for h in handles.values())
                        < alive_slots):
            i, (prompt, budget) = todo.pop(0)
            handles[i] = client.submit(RolloutTask(
                task_id=next_uid(), prompt_id=i, replica_idx=0,
                prompt_tokens=prompt, max_new_tokens=budget))
            submitted = True
        stepped = False
        for p in proxies:
            if p.step_once():
                busy += 1
                stepped = True
        assert stepped or submitted, \
            "fleet idle with undone handles (lost request?)"
        rounds += 1
    wall = time.perf_counter() - t0
    outputs = {}
    for i, h in handles.items():
        res = h.result(0)
        assert not res.aborted, f"handle {i} surfaced an abort"
        assert len(res.tokens) == workload[i][1], f"handle {i} short budget"
        outputs[i] = list(res.tokens)
    router.fleet_audit()
    completed = router.requests_completed
    router.stop()
    return {
        "rounds": rounds, "busy_steps": busy, "wall_s": wall,
        "outputs": outputs, "completed": completed,
        "completed_at_kill": completed_at_kill,
        "failovers": router.failovers, "lost_tokens": router.lost_tokens,
        "replicas_alive": router.replicas_alive,
    }


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    results = {"workload": {
        "num_replicas": NUM_REPLICAS, "num_requests": NUM_REQUESTS,
        "budgets": BUDGETS, "prompt_lengths": PROMPT_LENGTHS,
        "slots_per_replica": SLOTS_PER_REPLICA, "seeds": list(SEEDS),
        "kill_fraction": KILL_FRACTION,
        "degradation_bound": DEGRADATION_BOUND,
    }}
    degradations = []
    for seed in SEEDS:
        workload = _workload(seed)
        base = _run(api, params, workload)
        kill_round = max(1, int(base["rounds"] * KILL_FRACTION))
        victim = int(np.random.default_rng(seed).integers(NUM_REPLICAS))
        fault = _run(api, params, workload, kill_round=kill_round,
                     victim=victim)
        assert fault["replicas_alive"] == NUM_REPLICAS - 1
        assert fault["failovers"] >= 1 or fault["lost_tokens"] == 0
        identical = fault["outputs"] == base["outputs"]
        assert identical, "failover must preserve greedy outputs"
        # zero completed samples lost: everything finished before the kill
        # stays finished; the total completes the whole workload.
        samples_lost = NUM_REQUESTS - len(fault["outputs"])
        degradation = (fault["rounds"] - base["rounds"]) / base["rounds"]
        degradations.append(degradation)
        results[f"seed_{seed}"] = {
            "base_makespan_rounds": base["rounds"],
            "fault_makespan_rounds": fault["rounds"],
            "kill_round": kill_round, "victim": victim,
            "completed_at_kill": fault["completed_at_kill"],
            "failovers": fault["failovers"],
            "lost_tokens_recomputed": fault["lost_tokens"],
            "samples_lost": samples_lost,
            "makespan_degradation": degradation,
            "outputs_identical": bool(identical),
            "extra_busy_steps": fault["busy_steps"] - base["busy_steps"],
        }
        emit(f"fault_tolerance.seed{seed}.base_makespan_rounds",
             base["rounds"], "")
        emit(f"fault_tolerance.seed{seed}.fault_makespan_rounds",
             fault["rounds"],
             f"degradation={degradation:.3f} failovers={fault['failovers']} "
             f"lost_tokens={fault['lost_tokens']}")
    mean_deg = float(np.mean(degradations))
    within = mean_deg <= DEGRADATION_BOUND
    results["makespan_degradation_mean"] = mean_deg
    results["within_bound"] = bool(within)
    emit("fault_tolerance.makespan_degradation_mean", mean_deg,
         f"bound={DEGRADATION_BOUND:.2f} ok={within}")
    assert within, (f"makespan degradation {mean_deg:.3f} exceeds "
                    f"2/N={DEGRADATION_BOUND:.2f}")
    flush_json("BENCH_fault_tolerance.json", results)


if __name__ == "__main__":
    run()
