"""Microbenchmarks: decode-engine step latency, buffer ops, proxy overhead
(name, us_per_call, derived)."""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import REGISTRY
from repro.core.sample_buffer import SampleBuffer
from repro.core.types import Sample
from repro.models import get_api
from repro.rollout.engine import DecodeEngine


def _timeit(fn, n=50, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    for slots in (4, 16, 64):
        eng = DecodeEngine(api, params, num_slots=slots, max_total_len=64,
                           eos_id=9999)
        for i in range(slots):
            eng.add_request(i, np.asarray([1, 2, 3], np.int32), 60)
        us = _timeit(eng.step, n=30)
        emit(f"engine.decode_step.slots{slots}", us,
             f"us_per_token={us / slots:.1f}")

    buf = SampleBuffer(batch_size=64, alpha=4)

    def put_get():
        for _ in range(64):
            buf.try_begin_generation()
            buf.put(Sample(sample_id=0, prompt_id=0, replica_idx=0,
                           prompt_tokens=np.zeros(4, np.int32),
                           response_tokens=np.zeros(4, np.int32),
                           logprobs=np.zeros(4, np.float32),
                           version_started=buf.version))
        buf.get_batch(64)
        buf.advance_version()

    emit("buffer.put_get_batch64", _timeit(put_get, n=20), "")


if __name__ == "__main__":
    run()
