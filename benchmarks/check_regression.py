"""Bench-regression gate: fast re-runs vs the checked-in BENCH_*.json.

Re-executes the FAST configurations of the two headline rollout benchmarks
(queue scheduling at N=2, prefix cache) and compares their key speedup
metrics against the committed baselines:

* ``BENCH_queue_scheduling.json`` → ``replicas_2.queue_over_static_speedup``
* ``BENCH_prefix_cache.json``     → ``shared_preamble.prefill_tokens_ratio``
                                    and ``agentic_multi_turn.prefill_tokens_ratio``
* ``BENCH_slo.json``              → ``p99_high_speedup_mean`` (high-priority
                                    p99 latency, preemptive SLO vs FIFO)
* ``BENCH_quant.json``            → ``effective_kv_capacity_ratio`` (int8 KV
                                    pages per byte vs bf16; pure dtype math)
* ``BENCH_page_transfer.json``    → ``cache_routing.prefill_tokens_ratio``
                                    (fleet-global cache-aware routing vs
                                    load-only; migrated-resume re-prefill
                                    must stay exactly zero)

All these metrics are DETERMINISTIC (lockstep makespan rounds / prefill
token counts — never wall clock), so a fresh run should reproduce the
baseline exactly; a drop > ``--threshold`` (default 15%) means a real
behavioral regression in placement or caching, and the script exits 1.
Run by the non-blocking ``bench-regression`` CI job:

  PYTHONPATH=src:. python benchmarks/check_regression.py
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

from benchmarks import bench_page_transfer as pt
from benchmarks import bench_prefix_cache as pc
from benchmarks import bench_quant as bq
from benchmarks import bench_queue_scheduling as qs
from benchmarks import bench_slo as slo
from repro.configs import REGISTRY
from repro.models import get_api


def _api_params():
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def fresh_queue_speedup() -> float:
    """bench_queue_scheduling's N=2 point only (the fast config)."""
    api, params = _api_params()
    statics, queues = [], []
    for seed in qs.SEEDS:
        workload = qs._workload(seed)
        rs, _, _, out_s = qs._run(api, params, workload, 2, mode="static")
        rq, _, _, out_q = qs._run(api, params, workload, 2, mode="queue")
        assert out_s == out_q, "placement changed greedy outputs"
        statics.append(rs)
        queues.append(rq)
    return float(np.mean(statics) / np.mean(queues))


def fresh_prefix_ratios() -> tuple:
    """bench_prefix_cache's two prefill-reduction ratios (already fast)."""
    api, params = _api_params()
    rng = np.random.default_rng(0)
    pre = rng.integers(1, 60, pc.PRE_LEN).astype(np.int32)
    prompts = [np.concatenate([pre,
                               rng.integers(1, 60, pc.SFX_LEN).astype(np.int32)])
               for _ in range(pc.NUM_PROMPTS)]
    on, _ = pc._shared_preamble(api, params, prompts, cached=True)
    off, _ = pc._shared_preamble(api, params, prompts, cached=False)
    a_on, _ = pc._agentic_sim(api, params, cached=True)
    a_off, _ = pc._agentic_sim(api, params, cached=False)
    return (off["prefill_tokens"] / on["prefill_tokens"],
            a_off["prefill_tokens"] / a_on["prefill_tokens"])


def fresh_slo_ratio() -> float:
    """bench_slo's high-priority p99 speedup (same config, one seed)."""
    api, params = _api_params()
    ratios = []
    for seed in slo.SEEDS:
        lows, highs = slo._workload(seed)
        fifo = slo._run(api, params, lows, highs, "fifo")
        sl = slo._run(api, params, lows, highs, "slo")
        assert sl["outputs"] == fifo["outputs"], \
            "SLO scheduling changed greedy outputs"
        assert sl["deadline_misses"] == 0 and sl["reprefills"] == 0
        ratios.append(slo._p99(fifo["latencies"]["high"])
                      / slo._p99(sl["latencies"]["high"]))
    return float(np.mean(ratios))


def fresh_kv_capacity_ratio() -> float:
    """bench_quant's effective KV-capacity ratio (analytic, instant)."""
    w = bq.kv_page_bytes
    ps, nkv, hd = (bq.PAGE_SIZE, 2, 32)        # the bench's smoke geometry
    return w(ps, nkv, hd, "off") / w(ps, nkv, hd, "int8")


def fresh_page_transfer_ratio() -> float:
    """bench_page_transfer's routing comparison; the migrated-resume leg is
    a hard invariant (exactly zero re-prefilled tokens), asserted here."""
    api, params = _api_params()
    prompts = pt._workload(np.random.default_rng(0))
    aware, out_aware = pt._cache_routing(api, params, prompts,
                                         cache_aware=True)
    load, out_load = pt._cache_routing(api, params, prompts,
                                       cache_aware=False)
    assert out_aware == out_load, "cache-aware routing changed greedy outputs"
    mig = pt._migrated_resume(api, params)
    assert mig["reprefill_tokens"] == 0 and mig["output_identical"], \
        "migrated resume must stay zero-re-prefill and byte-identical"
    return load["prefill_tokens"] / aware["prefill_tokens"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional drop vs baseline")
    args = ap.parse_args()

    with open("BENCH_queue_scheduling.json") as f:
        base_qs = json.load(f)
    with open("BENCH_prefix_cache.json") as f:
        base_pc = json.load(f)
    with open("BENCH_slo.json") as f:
        base_slo = json.load(f)
    with open("BENCH_quant.json") as f:
        base_quant = json.load(f)
    with open("BENCH_page_transfer.json") as f:
        base_pt = json.load(f)

    queue_speedup = fresh_queue_speedup()
    preamble_ratio, agentic_ratio = fresh_prefix_ratios()
    slo_ratio = fresh_slo_ratio()
    kv_capacity = fresh_kv_capacity_ratio()
    page_transfer_ratio = fresh_page_transfer_ratio()
    checks = [
        ("queue_scheduling.replicas_2.queue_over_static_speedup",
         queue_speedup, base_qs["replicas_2"]["queue_over_static_speedup"]),
        ("prefix_cache.shared_preamble.prefill_tokens_ratio",
         preamble_ratio, base_pc["shared_preamble"]["prefill_tokens_ratio"]),
        ("prefix_cache.agentic_multi_turn.prefill_tokens_ratio",
         agentic_ratio, base_pc["agentic_multi_turn"]["prefill_tokens_ratio"]),
        ("slo.p99_high_speedup_mean",
         slo_ratio, base_slo["p99_high_speedup_mean"]),
        ("quant.effective_kv_capacity_ratio",
         kv_capacity, base_quant["effective_kv_capacity_ratio"]),
        ("page_transfer.cache_routing.prefill_tokens_ratio",
         page_transfer_ratio,
         base_pt["cache_routing"]["prefill_tokens_ratio"]),
    ]

    failed = False
    for name, fresh, baseline in checks:
        drop = (baseline - fresh) / baseline if baseline else 0.0
        ok = drop <= args.threshold
        failed |= not ok
        print(f"{'OK  ' if ok else 'FAIL'} {name}: fresh={fresh:.4f} "
              f"baseline={baseline:.4f} drop={drop * 100:+.1f}% "
              f"(threshold {args.threshold * 100:.0f}%)")
    if failed:
        print("bench regression detected: speedup dropped beyond threshold")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
