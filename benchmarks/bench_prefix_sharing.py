"""COW prefix sharing for GRPO groups: grouped vs independent submission.

The GRPO-group workload (§5.1 prompt replication): each prompt is decoded by
G candidates.  Independent submission prefills the SAME prompt G times and
stores G identical KV copies; ``submit_group`` prefills it once and forks G
decode lanes whose block tables alias the shared prefix pages (copy-on-write
— only the partial tail page is duplicated).  Three axes, measured:

* prefill tokens computed  (grouped ≈ 1/G of independent)
* peak pages in use        (grouped reclaims ~(G-1)/G of the prompt KV)
* decode-step throughput   (same fused step; grouped frees it from prefill)

Greedy decoding lets us additionally assert the outputs are byte-identical
per lane — sharing is an optimization, never a semantic change.

Emits BENCH_prefix_sharing.json.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine

NUM_PROMPTS = 8
GROUP_SIZE = 8
PAGE_SIZE = 16
PREFILL_CHUNK = 16
BUDGET = 12
MAX_TOTAL_LEN = 96
# mixed prompt lengths: page-aligned and partial-tail cases
PROMPT_LENGTHS = [16, 24, 33, 40, 47, 56, 64, 79]


def _make_engine(api, params):
    num_slots = NUM_PROMPTS * GROUP_SIZE
    return PagedDecodeEngine(api, params, num_slots=num_slots,
                             max_total_len=MAX_TOTAL_LEN, page_size=PAGE_SIZE,
                             prefill_chunk=PREFILL_CHUNK, eos_id=9999,
                             temperature=0.0)


def _prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(1, 60, n).astype(np.int32) for n in PROMPT_LENGTHS]


def _run(api, params, prompts, *, grouped: bool):
    eng = _make_engine(api, params)
    rid = 0
    for prompt in prompts:
        rids = list(range(rid, rid + GROUP_SIZE))
        rid += GROUP_SIZE
        if grouped:
            eng.submit_group(rids, prompt, BUDGET)
        else:
            for r in rids:
                eng.add_request(r, prompt, BUDGET)
    want = NUM_PROMPTS * GROUP_SIZE
    results = {}
    t0 = time.perf_counter()
    while len(results) < want:
        for r, toks, lps in eng.step():
            results[r] = list(toks)
    wall = time.perf_counter() - t0
    eng.audit_pages()
    assert eng.pages_free == eng.num_pages - 1, "leaked pages"
    return {
        "wall_s": wall,
        "prefill_tokens": eng.total_prefill_tokens,
        "peak_pages_in_use": eng.peak_pages_in_use,
        "decode_tokens": eng.total_tokens_decoded,
        "decode_tok_per_s": eng.total_tokens_decoded / wall,
    }, results


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompts = _prompts()

    results = {}
    outputs = {}
    for name, grouped in (("independent", False), ("grouped_cow", True)):
        stats, outs = _run(api, params, prompts, grouped=grouped)
        results[name] = stats
        outputs[name] = outs
        emit(f"prefix_sharing.{name}.prefill_tokens", stats["prefill_tokens"],
             f"peak_pages={stats['peak_pages_in_use']}")

    identical = all(outputs["independent"][r] == outputs["grouped_cow"][r]
                    for r in outputs["independent"])
    prefill_ratio = (results["independent"]["prefill_tokens"]
                     / results["grouped_cow"]["prefill_tokens"])
    pages_ratio = (results["independent"]["peak_pages_in_use"]
                   / results["grouped_cow"]["peak_pages_in_use"])
    tput_ratio = (results["grouped_cow"]["decode_tok_per_s"]
                  / results["independent"]["decode_tok_per_s"])
    results["prefill_tokens_ratio"] = prefill_ratio
    results["peak_pages_ratio"] = pages_ratio
    results["decode_tput_ratio_grouped_over_independent"] = tput_ratio
    results["outputs_identical"] = bool(identical)
    results["workload"] = {
        "num_prompts": NUM_PROMPTS, "group_size": GROUP_SIZE,
        "prompt_lengths": PROMPT_LENGTHS, "budget": BUDGET,
        "page_size": PAGE_SIZE, "max_total_len": MAX_TOTAL_LEN,
    }
    emit("prefix_sharing.prefill_tokens_ratio", prefill_ratio,
         f"pages_ratio={pages_ratio:.2f}x identical={identical}")
    flush_json("BENCH_prefix_sharing.json", results)


if __name__ == "__main__":
    run()
