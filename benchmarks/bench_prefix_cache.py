"""Automatic cross-prompt prefix caching: radix-tree cache on vs off.

Two workloads the GRPO-group COW sharing of PR 2 cannot touch:

* **shared system prompt** — N DISTINCT prompts carrying the same
  48-token preamble (system prompt / few-shot block).  With the cache the
  preamble's pages are computed once and aliased by every later request;
  without it every admission re-prefills the full prompt.
* **multi-turn agentic sim** — a conversation resubmitted turn after turn
  (prompt_t = conversation_{t-1} + action + new observation), the EnvManager
  ``context_mode="full"`` pattern.  With the cache each turn only prefills
  the new suffix (incremental prefill); without it prefill grows
  quadratically with turn count.

Greedy decoding additionally asserts byte-identical outputs — caching is an
optimization, never a semantic change — and ``audit_pages`` runs after every
phase.  Emits BENCH_prefix_cache.json.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine

NUM_PROMPTS = 8
PRE_LEN = 48            # shared preamble (3 pages)
SFX_LEN = 16            # distinct per-prompt suffix
BUDGET = 12
PAGE_SIZE = 16
PREFILL_CHUNK = 16
MAX_TOTAL_LEN = 160
NUM_TURNS = 4
OBS_LEN = 12


def _make_engine(api, params, *, prefix_cache: bool):
    return PagedDecodeEngine(api, params, num_slots=NUM_PROMPTS,
                             max_total_len=MAX_TOTAL_LEN, page_size=PAGE_SIZE,
                             prefill_chunk=PREFILL_CHUNK, eos_id=9999,
                             temperature=0.0, prefix_cache=prefix_cache)


def _drain(eng, want):
    results = {}
    while len(results) < want:
        for rid, toks, lps in eng.step():
            results[rid] = list(toks)
    return results


def _shared_preamble(api, params, prompts, *, cached: bool):
    eng = _make_engine(api, params, prefix_cache=cached)
    t0 = time.perf_counter()
    for rid, p in enumerate(prompts):
        eng.add_request(rid, p, BUDGET)
    outs = _drain(eng, len(prompts))
    wall = time.perf_counter() - t0
    eng.audit_pages()
    return {
        "wall_s": wall,
        "prefill_tokens": eng.total_prefill_tokens,
        "cache_hit_tokens": eng.cache_hit_tokens,
        "cache_hits": eng.cache_hits,
        "cache_ext_hits": eng.cache_ext_hits,
        "peak_pages_in_use": eng.peak_pages_in_use,
    }, outs


def _agentic_sim(api, params, *, cached: bool):
    """One simulated multi-turn trajectory: resubmit the growing
    conversation each turn (greedy actions feed the next prompt)."""
    rng = np.random.default_rng(1)
    eng = _make_engine(api, params, prefix_cache=cached)
    convo = rng.integers(1, 60, OBS_LEN).astype(np.int32)
    submitted = 0
    t0 = time.perf_counter()
    for turn in range(NUM_TURNS):
        eng.add_request(turn, convo, BUDGET)
        submitted += len(convo)
        action = np.asarray(_drain(eng, 1)[turn], np.int32)
        obs = rng.integers(1, 60, OBS_LEN).astype(np.int32)
        convo = np.concatenate([convo, action, obs])
    wall = time.perf_counter() - t0
    eng.audit_pages()
    return {
        "wall_s": wall,
        "prompt_tokens_submitted": submitted,
        "prefill_tokens": eng.total_prefill_tokens,
        "cache_hit_tokens": eng.cache_hit_tokens,
    }, convo


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    pre = rng.integers(1, 60, PRE_LEN).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(1, 60, SFX_LEN).astype(np.int32)])
               for _ in range(NUM_PROMPTS)]

    results = {}
    on, outs_on = _shared_preamble(api, params, prompts, cached=True)
    off, outs_off = _shared_preamble(api, params, prompts, cached=False)
    identical = all(outs_on[r] == outs_off[r] for r in outs_off)
    ratio = off["prefill_tokens"] / on["prefill_tokens"]
    total_prompt_tokens = sum(len(p) for p in prompts)
    results["shared_preamble"] = {
        "cache_on": on, "cache_off": off,
        "prefill_tokens_ratio": ratio,
        # fraction of submitted prompt tokens served from cached pages
        "cache_hit_rate": on["cache_hit_tokens"] / total_prompt_tokens,
        "outputs_identical": bool(identical),
    }
    emit("prefix_cache.shared_preamble.prefill_tokens_ratio", ratio,
         f"on={on['prefill_tokens']} off={off['prefill_tokens']} "
         f"identical={identical}")

    a_on, convo_on = _agentic_sim(api, params, cached=True)
    a_off, convo_off = _agentic_sim(api, params, cached=False)
    a_identical = convo_on.tolist() == convo_off.tolist()
    a_ratio = a_off["prefill_tokens"] / a_on["prefill_tokens"]
    results["agentic_multi_turn"] = {
        "cache_on": a_on, "cache_off": a_off,
        "prefill_tokens_ratio": a_ratio,
        "outputs_identical": bool(a_identical),
    }
    emit("prefix_cache.agentic.prefill_tokens_ratio", a_ratio,
         f"on={a_on['prefill_tokens']} off={a_off['prefill_tokens']} "
         f"identical={a_identical}")

    results["workload"] = {
        "num_prompts": NUM_PROMPTS, "preamble_len": PRE_LEN,
        "suffix_len": SFX_LEN, "budget": BUDGET, "page_size": PAGE_SIZE,
        "num_turns": NUM_TURNS, "obs_len": OBS_LEN,
        "max_total_len": MAX_TOTAL_LEN,
    }
    assert identical and a_identical, "cache changed greedy outputs"
    assert ratio >= 2.0, f"shared-preamble prefill reduction below 2x: {ratio}"
    flush_json("BENCH_prefix_cache.json", results)


if __name__ == "__main__":
    run()
