"""Slot engine vs paged engine under a mixed-length continuous workload.

The workload models RL rollout serving (§4.2/§5.1): N concurrent requests
with widely mixed prompt lengths, a fresh request admitted the moment one
finishes.  Two pathologies of the seed slot engine show up directly:

* **prefill stall** — every admission prefills the whole prompt at batch=1
  while ALL active slots sit idle; we clock that stall explicitly.
* **shape churn** — each distinct (bucketed) prompt length lowers a new
  prefill executable; mixed lengths mean recurrent compile stalls.  The
  paged engine's chunked prefill is ONE static shape co-scheduled with
  decode inside the same jitted step, so nothing ever stalls the batch.

Emits BENCH_paged_engine.json:
    decode_tok_per_s        decode tokens / total wall-clock
    prefill_stall_s         wall-clock during which decode was blocked
    speedup                 paged / slot decode throughput
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.models import get_api
from repro.rollout.engine import DecodeEngine
from repro.rollout.paged_engine import PagedDecodeEngine

CONCURRENCY = 16
NUM_REQUESTS = 48
MAX_TOTAL_LEN = 320
BUDGET = 24
# mixed prompt lengths, heavy-tailed like RLVR+agentic traffic
PROMPT_LENGTHS = [8, 16, 24, 40, 56, 88, 120, 168, 232, 288]


def _requests(rng):
    reqs = []
    for i in range(NUM_REQUESTS):
        plen = PROMPT_LENGTHS[i % len(PROMPT_LENGTHS)]
        budget = min(BUDGET, MAX_TOTAL_LEN - plen)
        reqs.append((i, rng.integers(1, 60, plen).astype(np.int32), budget))
    return reqs


def _run_workload(make_engine):
    """Continuous batching: keep CONCURRENCY requests in flight; returns
    (wall_s, stall_s, decode_tokens).  ``stall_s`` is time spent in
    add_request (slot engine: full batch=1 prefill; paged: bookkeeping)."""
    eng = make_engine()
    rng = np.random.default_rng(0)
    pending = _requests(rng)[::-1]
    done = 0
    stall = 0.0
    t0 = time.perf_counter()
    while done < NUM_REQUESTS:
        while pending and eng.num_free_slots > 0 and \
                getattr(eng, "can_admit", lambda p, m: True)(
                    len(pending[-1][1]), pending[-1][2]):
            rid, prompt, budget = pending.pop()
            ta = time.perf_counter()
            eng.add_request(rid, prompt, budget)
            stall += time.perf_counter() - ta
        done += len(eng.step())
    wall = time.perf_counter() - t0
    return wall, stall, eng.total_tokens_decoded


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    def slot_engine():
        return DecodeEngine(api, params, num_slots=CONCURRENCY,
                            max_total_len=MAX_TOTAL_LEN, eos_id=9999,
                            temperature=0.0)

    def paged_engine():
        return PagedDecodeEngine(api, params, num_slots=CONCURRENCY,
                                 max_total_len=MAX_TOTAL_LEN, page_size=32,
                                 prefill_chunk=32, eos_id=9999,
                                 temperature=0.0)

    results = {}
    for name, make in (("slot", slot_engine), ("paged", paged_engine)):
        wall, stall, tokens = _run_workload(make)
        tput = tokens / wall
        results[name] = {
            "wall_s": wall,
            "prefill_stall_s": stall,
            "decode_tokens": tokens,
            "decode_tok_per_s": tput,
        }
        emit(f"paged_bench.{name}.decode_tok_per_s", tput,
             f"stall_s={stall:.3f}")

    speedup = (results["paged"]["decode_tok_per_s"]
               / results["slot"]["decode_tok_per_s"])
    stall_ratio = (results["slot"]["prefill_stall_s"]
                   / max(results["paged"]["prefill_stall_s"], 1e-9))
    results["speedup_decode_tok_per_s"] = speedup
    results["prefill_stall_ratio_slot_over_paged"] = stall_ratio
    results["workload"] = {
        "concurrency": CONCURRENCY, "num_requests": NUM_REQUESTS,
        "prompt_lengths": PROMPT_LENGTHS, "budget": BUDGET,
        "max_total_len": MAX_TOTAL_LEN,
    }
    emit("paged_bench.speedup", speedup,
         f"stall_ratio={stall_ratio:.1f}x")
    flush_json("BENCH_paged_engine.json", results)


if __name__ == "__main__":
    run()
