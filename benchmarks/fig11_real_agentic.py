"""Fig 11 analogue: REAL pipeline (not simulator) — env-level async +
redundant environment rollout measured on the actual EnvManagerPool /
LLMProxy / DecodeEngine stack with latency-injected environments.

The paper measures end-to-end hours on SWE/ALFWorld; here we measure
wall-clock rollout-step time on CPU with scaled-down latencies, comparing
exact-capacity env pools against redundant pools under fail-slow injection
(paper: redundant rollout gives an extra 7-16%).
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit
from repro.configs import REGISTRY
from repro.envs.sim_envs import LatencyEnv
from repro.launch.pipeline import PipelineSettings, build_agentic_pipeline


def model_cfg():
    return dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=64, num_heads=4,
        head_dim=16, num_kv_heads=2, d_ff=128, vocab_size=64)


def run_pool(num_env_groups: int, group_size: int, steps: int = 2):
    s = PipelineSettings(async_generation_ratio=1, pg_variant="tis",
                         rollout_batch_size=8, num_slots=8, max_new_tokens=3,
                         max_seq_len=48, learning_rate=1e-3)

    def make_env(eid):
        return LatencyEnv(eid, mu=0.03, sigma=0.02, max_steps=3,
                          p_fail_slow=0.25, fail_slow_factor=6.0)

    pipe = build_agentic_pipeline(model_cfg(), s, make_env=make_env,
                                  num_env_groups=num_env_groups,
                                  group_size=group_size, max_env_steps=3)
    t0 = time.time()
    stats = pipe.run(num_steps=steps, timeout=300)
    wall = (time.time() - t0) / max(len(stats), 1)
    return wall


def run() -> None:
    t_exact = run_pool(4, 2)        # 8 envs == batch 8 (no redundancy)
    t_red = run_pool(6, 2)          # 12 envs > batch 8 (redundant)
    emit("fig11.real.exact_capacity.s_per_step", t_exact, "8 envs, batch 8")
    emit("fig11.real.redundant.s_per_step", t_red,
         f"12 envs, batch 8; speedup={t_exact / t_red:.2f}")


if __name__ == "__main__":
    run()
