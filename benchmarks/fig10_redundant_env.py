"""Fig 10: redundant environment rollout heatmap (num_env_groups x
group_size at fixed target batch 256, Gaussian latency mu=10 sigma=5).

Paper claims: more groups beats bigger groups; redundancy absorbs fail-slow
/ fail-stop; e.g. 32x8 -> 36x12 gives ~5x.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import simulator as S


def step(groups, gsize, reps=3):
    ts = []
    for i in range(reps):
        cfg = S.AgenticConfig(rollout_batch_size=256, num_env_groups=groups,
                              group_size=gsize, k_slots=96, turns=5,
                              env_latency_mu=10.0, env_latency_sigma=5.0,
                              env_async=True, p_fail_slow=0.05,
                              fail_slow_factor=8.0)
        ts.append(S.simulate_agentic_step(np.random.default_rng(i), cfg))
    return float(np.mean(ts))


def run() -> None:
    base = step(32, 8)
    emit("fig10.32x8.baseline", base, "exact-capacity baseline")
    for groups in (32, 34, 36):
        for gsize in (8, 9, 11, 12):
            if groups * gsize < 256:
                continue
            t = step(groups, gsize)
            emit(f"fig10.{groups}x{gsize}.step_time", t,
                 f"speedup={base / t:.2f}")
    # groups-vs-size at equal redundancy budget
    t_groups = step(40, 8)   # +25% via groups
    t_size = step(32, 10)    # +25% via group size
    emit("fig10.redundancy_via_groups", t_groups,
         f"speedup={base / t_groups:.2f}")
    emit("fig10.redundancy_via_group_size", t_size,
         f"speedup={base / t_size:.2f};groups_better="
         f"{t_groups <= t_size}")


if __name__ == "__main__":
    run()
