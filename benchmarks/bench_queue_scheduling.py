"""Queue scheduling vs static partitioning on a multi-replica rollout fleet.

The paper's §4.3 claim: dispatching each prompt individually to the
least-loaded inference worker (queue scheduling) eliminates the long-tail
straggler problem of statically partitioning the batch across workers.
This benchmark reproduces that comparison on the REAL rollout stack — N
``PagedDecodeEngine`` + ``LLMProxy`` replicas, the submission path going
through ``ProxyRouter`` (queue scheduling) or a fixed round-robin
pre-assignment (static partitioning) — under a long-tail mixed-length
workload (a few generations are ~7x longer than the median).

Replicas are driven in deterministic lockstep via ``LLMProxy.step_once``:
every round, each replica with admitted work executes exactly one fused
engine step.  Makespan in *rounds* is therefore the fleet's parallel
hardware time (what wall-clock would measure on N real accelerators),
independent of how many CPU cores this host happens to have.  Greedy
decoding additionally lets us assert the outputs are placement-invariant.

Emits BENCH_queue_scheduling.json.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, flush_json
from repro.configs import REGISTRY
from repro.core.llm_proxy import LLMProxy
from repro.core.router import ProxyRouter
from repro.core.rollout_client import RolloutClient
from repro.core.types import RolloutTask, next_uid
from repro.models import get_api
from repro.rollout.paged_engine import PagedDecodeEngine

NUM_REQUESTS = 48
SLOTS_PER_REPLICA = 2
PAGE_SIZE = 16
PREFILL_CHUNK = 16
MAX_TOTAL_LEN = 80
# long-tail budget mix (median 2, tail 24x): the tail carries ~75% of the
# total decode work (the paper's think-mode regime), so which replica a
# tail request queues on decides the makespan — the regime where dispatch
# policy matters (§4.3).  The queue is deep relative to the slots (48
# requests on 2-slot replicas) so placement determines waiting time, not
# just decode time.
BUDGETS = [2] * 32 + [8] * 8 + [48] * 8
PROMPT_LENGTHS = [8, 12, 16, 20]
SEEDS = (0, 1, 2)


def _workload(seed: int):
    rng = np.random.default_rng(seed)
    budgets = np.array(BUDGETS)
    rng.shuffle(budgets)
    prompts = [rng.integers(1, 60, PROMPT_LENGTHS[i % len(PROMPT_LENGTHS)])
               .astype(np.int32) for i in range(NUM_REQUESTS)]
    return [(prompts[i], int(budgets[i])) for i in range(NUM_REQUESTS)]


def _fleet(api, params, n):
    engines = [PagedDecodeEngine(api, params, num_slots=SLOTS_PER_REPLICA,
                                 max_total_len=MAX_TOTAL_LEN,
                                 page_size=PAGE_SIZE,
                                 prefill_chunk=PREFILL_CHUNK, eos_id=9999,
                                 temperature=0.0)
               for _ in range(n)]
    return engines, [LLMProxy(e, name=f"bench_proxy_{i}")
                     for i, e in enumerate(engines)]


def _run(api, params, workload, n, *, mode: str):
    """Run the workload under one placement policy, driving the fleet in
    lockstep.  ``static`` pre-partitions the batch round-robin across the
    replicas (the baseline the paper's queue scheduling replaces);
    ``queue`` dispatches each prompt through the ProxyRouter only when the
    fleet has a free slot, landing it on the least-loaded replica AT THAT
    MOMENT — the straggler replica chewing on long-tail generations keeps
    its slots busy and stops receiving new work.  Returns
    (makespan_rounds, per-replica busy steps, wall, outputs by index)."""
    engines, proxies = _fleet(api, params, n)
    handles = {}
    rounds = 0
    busy = [0] * n
    t0 = time.perf_counter()
    if mode == "queue":
        client = RolloutClient(ProxyRouter(proxies))
        todo = list(enumerate(workload))
        while todo or not all(h.done() for h in handles.values()):
            # dispatch gate: keep at most one request per fleet slot in
            # flight, so every placement sees the loads as they are NOW
            submitted = False
            while todo and (sum(not h.done() for h in handles.values())
                            < n * SLOTS_PER_REPLICA):
                i, (prompt, budget) = todo.pop(0)
                handles[i] = client.submit(RolloutTask(
                    task_id=next_uid(), prompt_id=i, replica_idx=0,
                    prompt_tokens=prompt, max_new_tokens=budget))
                submitted = True
            stepped = False
            for j, p in enumerate(proxies):
                if p.step_once():
                    busy[j] += 1
                    stepped = True
            assert stepped or submitted, \
                "fleet idle with undone handles (lost request?)"
            rounds += 1
    else:                           # static round-robin partitioning
        clients = [RolloutClient(p) for p in proxies]
        for i, (prompt, budget) in enumerate(workload):
            handles[i] = clients[i % n].submit(RolloutTask(
                task_id=next_uid(), prompt_id=i, replica_idx=0,
                prompt_tokens=prompt, max_new_tokens=budget))
        while not all(h.done() for h in handles.values()):
            stepped = False
            for j, p in enumerate(proxies):
                if p.step_once():
                    busy[j] += 1
                    stepped = True
            assert stepped, "fleet idle with undone handles (lost request?)"
            rounds += 1
    wall = time.perf_counter() - t0
    for e in engines:
        e.audit_pages()
    outputs = {i: list(h.result(0).tokens) for i, h in handles.items()}
    return rounds, busy, wall, outputs


def run() -> None:
    cfg = dataclasses.replace(
        REGISTRY["qwen3-4b"].smoke(), num_layers=2, d_model=128, num_heads=4,
        head_dim=32, num_kv_heads=2, d_ff=256, vocab_size=64)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    results = {"workload": {
        "num_requests": NUM_REQUESTS, "budgets": BUDGETS,
        "prompt_lengths": PROMPT_LENGTHS, "slots_per_replica":
        SLOTS_PER_REPLICA, "seeds": list(SEEDS),
    }}
    for n in (2, 4, 8):
        static_rounds, queue_rounds = [], []
        imbalance = {"static": [], "queue": []}
        identical = True
        for seed in SEEDS:
            workload = _workload(seed)
            rs, busy_s, _, out_s = _run(api, params, workload, n,
                                        mode="static")
            rq, busy_q, _, out_q = _run(api, params, workload, n,
                                        mode="queue")
            static_rounds.append(rs)
            queue_rounds.append(rq)
            imbalance["static"].append(max(busy_s) / max(1, min(busy_s)))
            imbalance["queue"].append(max(busy_q) / max(1, min(busy_q)))
            identical &= out_s == out_q
        mean_s = float(np.mean(static_rounds))
        mean_q = float(np.mean(queue_rounds))
        speedup = mean_s / mean_q
        results[f"replicas_{n}"] = {
            "static_makespan_rounds": static_rounds,
            "queue_makespan_rounds": queue_rounds,
            "static_makespan_mean": mean_s,
            "queue_makespan_mean": mean_q,
            "queue_over_static_speedup": speedup,
            "busy_imbalance_static": imbalance["static"],
            "busy_imbalance_queue": imbalance["queue"],
            "outputs_identical": bool(identical),
        }
        emit(f"queue_scheduling.n{n}.static_makespan_rounds", mean_s, "")
        emit(f"queue_scheduling.n{n}.queue_makespan_rounds", mean_q,
             f"speedup={speedup:.2f} identical={identical}")
    flush_json("BENCH_queue_scheduling.json", results)


if __name__ == "__main__":
    run()
